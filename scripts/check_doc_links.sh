#!/usr/bin/env bash
# Fail if the architecture docs reference repo paths that do not exist —
# keeps docs/ARCHITECTURE.md / docs/DETERMINISM.md honest as modules move.
# Run from anywhere; CI runs it in the lint job.
set -euo pipefail
cd "$(dirname "$0")/.."

status=0
for doc in docs/ARCHITECTURE.md docs/DETERMINISM.md; do
    # Path-like references into the source tree, trailing punctuation
    # stripped (e.g. "rust/src/plan/mod.rs." at a sentence end).
    refs=$(grep -oE '(rust|docs|scripts|examples)/[A-Za-z0-9_./-]+' "$doc" \
        | sed -E 's/[.,:;)]+$//' | sort -u)
    for ref in $refs; do
        if [ ! -e "$ref" ]; then
            echo "ERROR: $doc references nonexistent path: $ref" >&2
            status=1
        fi
    done
done

if [ "$status" -eq 0 ]; then
    echo "doc links OK"
fi
exit $status
