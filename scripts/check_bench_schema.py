#!/usr/bin/env python3
"""Validate a freshly produced BENCH_scale.json and pin its deterministic
virtual history against the committed copy.

Usage: check_bench_schema.py <fresh.json> <committed.json>

The fresh file is what `cargo bench --bench scale` just wrote (usually to
/tmp via CFEL_BENCH_SCALE_OUT); the committed file is the repo's
BENCH_scale.json. Two checks:

1. Schema — both files carry the scale-bench shape: top-level keys
   {bench, threads, history, history_digest, samples, note}; each history
   entry {lane, virtual_s, virtual_s_bits, events} with virtual_s_bits a
   16-hex-digit string (the exact f64 bit pattern — f64 JSON round-trips
   can lose bits, the string never does); each sample at least
   {name, iters, mean_s, median_s, p10_s, p90_s}. The fresh file must
   have non-empty history and samples; the committed file may have empty
   samples until the scale-record CI job fills them.

2. History pin — for every lane name present in BOTH files, the fresh
   virtual_s_bits and events must equal the committed ones. The virtual
   clock is pure IEEE-754 arithmetic, so these are machine-independent:
   any divergence is a determinism regression, not noise. Lanes only in
   one file (e.g. the 1M lanes skipped by CFEL_SCALE_MAX_DEVICES in the
   smoke run) are ignored.
"""

import json
import sys

TOP_KEYS = {"bench", "threads", "history", "history_digest", "samples", "note"}
HISTORY_KEYS = {"lane", "virtual_s", "virtual_s_bits", "events"}
SAMPLE_KEYS = {"name", "iters", "mean_s", "median_s", "p10_s", "p90_s"}


def fail(msg):
    sys.exit(f"check_bench_schema: FAIL: {msg}")


def check_shape(doc, path, require_nonempty):
    missing = TOP_KEYS - doc.keys()
    if missing:
        fail(f"{path}: missing top-level keys {sorted(missing)}")
    if doc["bench"] != "scale":
        fail(f"{path}: bench is {doc['bench']!r}, expected 'scale'")
    for h in doc["history"]:
        miss = HISTORY_KEYS - h.keys()
        if miss:
            fail(f"{path}: history entry {h.get('lane')!r} missing {sorted(miss)}")
        bits = h["virtual_s_bits"]
        if not (isinstance(bits, str) and len(bits) == 16):
            fail(f"{path}: lane {h['lane']!r}: virtual_s_bits {bits!r} is not 16 hex digits")
        try:
            int(bits, 16)
        except ValueError:
            fail(f"{path}: lane {h['lane']!r}: virtual_s_bits {bits!r} is not hex")
    for s in doc["samples"]:
        miss = SAMPLE_KEYS - s.keys()
        if miss:
            fail(f"{path}: sample {s.get('name')!r} missing {sorted(miss)}")
    if require_nonempty:
        if not doc["history"]:
            fail(f"{path}: fresh run recorded no history lanes")
        if not doc["samples"]:
            fail(f"{path}: fresh run recorded no samples")


def main():
    if len(sys.argv) != 3:
        fail("usage: check_bench_schema.py <fresh.json> <committed.json>")
    fresh_path, committed_path = sys.argv[1], sys.argv[2]
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(committed_path) as f:
        committed = json.load(f)

    check_shape(fresh, fresh_path, require_nonempty=True)
    check_shape(committed, committed_path, require_nonempty=False)

    pinned = {h["lane"]: h for h in committed["history"]}
    compared = 0
    for h in fresh["history"]:
        want = pinned.get(h["lane"])
        if want is None:
            continue
        if h["virtual_s_bits"] != want["virtual_s_bits"] or h["events"] != want["events"]:
            fail(
                f"lane {h['lane']!r}: virtual history diverged from the committed pin "
                f"(fresh bits={h['virtual_s_bits']} events={h['events']}, "
                f"committed bits={want['virtual_s_bits']} events={want['events']}) — "
                f"the virtual clock is deterministic, so this is a regression"
            )
        compared += 1

    print(
        f"check_bench_schema: OK: {len(fresh['history'])} history lanes, "
        f"{len(fresh['samples'])} samples, {compared} lanes pinned against "
        f"{committed_path}"
    )


if __name__ == "__main__":
    main()
