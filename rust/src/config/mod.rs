//! Experiment configuration: the single declarative description of a CFEL
//! run (system shape, algorithm, hyper-parameters, data scheme, backend,
//! fault injection), with presets for every paper experiment and JSON
//! load/save for the CLI.

use std::path::PathBuf;

use crate::aggregation::policy::{AggregationPolicy, DeadlineDrop, FullBarrier, SemiSync};
use crate::compression::Compressor;
use crate::error::{CfelError, Result};
use crate::netsim::StragglerSpec;
use crate::plan::Plan;
use crate::scenario::{CapabilityProfiles, Scenario};
use crate::util::json::Json;

/// Uniform rejection for two spellings of the same knob being set at
/// once (`deadline_s` vs `agg_policy`, `algorithm` vs `plan`). Shared by
/// config-level validation and the CLI layer so every such conflict reads
/// the same way.
pub fn conflicting_options(primary: &str, other: &str, why: &str) -> CfelError {
    CfelError::Config(format!(
        "{primary} conflicts with {other} ({why}); set exactly one"
    ))
}

/// Which canned federation plan drives the run (paper §6.1). Each
/// variant names a `Plan` constructor (`plan::canned`); the coordinator
/// executes the plan through one shared interpreter, and `--plan` /
/// [`ExperimentConfig::plan`] replaces the canned schedule entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgorithmKind {
    /// CE-FedAvg (Algorithm 1): intra-cluster FedAvg + inter-cluster gossip.
    CeFedAvg,
    /// Cloud FedAvg: qτ local epochs then one cloud aggregation.
    FedAvg,
    /// Hier-FAvg: q−1 edge aggregations then one cloud aggregation.
    HierFAvg,
    /// Local-Edge: independent clusters, no inter-cluster cooperation.
    LocalEdge,
}

impl AlgorithmKind {
    pub fn parse(s: &str) -> Result<AlgorithmKind> {
        match s {
            "ce-fedavg" | "cefedavg" | "ce" => Ok(AlgorithmKind::CeFedAvg),
            "fedavg" | "cloud" => Ok(AlgorithmKind::FedAvg),
            "hier-favg" | "hierfavg" | "hier" => Ok(AlgorithmKind::HierFAvg),
            "local-edge" | "localedge" | "local" => Ok(AlgorithmKind::LocalEdge),
            _ => Err(CfelError::Config(format!("unknown algorithm {s:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::CeFedAvg => "ce-fedavg",
            AlgorithmKind::FedAvg => "fedavg",
            AlgorithmKind::HierFAvg => "hier-favg",
            AlgorithmKind::LocalEdge => "local-edge",
        }
    }

    pub fn all() -> [AlgorithmKind; 4] {
        [
            AlgorithmKind::CeFedAvg,
            AlgorithmKind::FedAvg,
            AlgorithmKind::HierFAvg,
            AlgorithmKind::LocalEdge,
        ]
    }
}

/// How per-round latency is estimated (`netsim`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LatencyMode {
    /// The paper's closed-form Eq. 8 (fast default; no deadlines).
    #[default]
    ClosedForm,
    /// Per-device discrete-event simulation (`netsim::event`) — required
    /// for reporting deadlines and per-device timing.
    EventDriven,
}

impl LatencyMode {
    pub fn parse(s: &str) -> Result<LatencyMode> {
        match s {
            "closed-form" | "closed" | "eq8" => Ok(LatencyMode::ClosedForm),
            "event" | "event-driven" => Ok(LatencyMode::EventDriven),
            _ => Err(CfelError::Config(format!(
                "unknown latency mode {s:?} (closed-form | event)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LatencyMode::ClosedForm => "closed-form",
            LatencyMode::EventDriven => "event",
        }
    }
}

/// Declarative edge-round close policy (`aggregation::policy`); the
/// coordinator instantiates the matching [`AggregationPolicy`] object.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AggPolicyKind {
    /// Wait for every report (paper semantics; works in both latency
    /// modes — in closed-form mode it is the only valid policy).
    FullBarrier,
    /// Close at `min(deadline, latest report)`, dropping late devices
    /// from Eq. 6 (requires the event-driven latency mode).
    DeadlineDrop {
        /// Per-edge-round reporting deadline T_dl, simulated seconds.
        deadline_s: f64,
    },
    /// FedBuff-style semi-sync: close at the K-th report or `timeout_s`,
    /// keep late reports and merge them stale with a `1/(1+s)^a` discount
    /// (`a` = the config's `staleness_exp`). Requires event-driven mode.
    SemiSync {
        /// Reports per cluster needed to close an edge phase.
        k: usize,
        /// Hard cutoff in simulated seconds; `f64::INFINITY` disables it.
        timeout_s: f64,
    },
}

impl AggPolicyKind {
    /// Parse `full` | `deadline:<T>` | `kofn:<K>:<timeout>` (timeout may
    /// be `inf`).
    pub fn parse(s: &str) -> Result<AggPolicyKind> {
        let bad = || {
            CfelError::Config(format!(
                "unknown aggregation policy {s:?} \
                 (full | deadline:<seconds> | kofn:<K>:<timeout_seconds|inf>)"
            ))
        };
        if matches!(s, "full" | "full-barrier" | "barrier") {
            return Ok(AggPolicyKind::FullBarrier);
        }
        if let Some(dl) = s.strip_prefix("deadline:") {
            return Ok(AggPolicyKind::DeadlineDrop {
                deadline_s: dl.parse().map_err(|_| bad())?,
            });
        }
        if let Some(rest) = s.strip_prefix("kofn:") {
            let (k, timeout) = rest.split_once(':').ok_or_else(bad)?;
            let timeout_s = match timeout {
                "inf" | "none" => f64::INFINITY,
                t => t.parse().map_err(|_| bad())?,
            };
            return Ok(AggPolicyKind::SemiSync {
                k: k.parse().map_err(|_| bad())?,
                timeout_s,
            });
        }
        Err(bad())
    }

    pub fn name(&self) -> String {
        match self {
            AggPolicyKind::FullBarrier => "full".into(),
            AggPolicyKind::DeadlineDrop { deadline_s } => format!("deadline:{deadline_s}"),
            AggPolicyKind::SemiSync { k, timeout_s } => {
                if timeout_s.is_finite() {
                    format!("kofn:{k}:{timeout_s}")
                } else {
                    format!("kofn:{k}:inf")
                }
            }
        }
    }

    /// Instantiate the runtime policy object. `staleness_exp` is the
    /// polynomial discount exponent applied by semi-sync stale merges
    /// (ignored by the other two policies).
    pub fn build(&self, staleness_exp: f64) -> Box<dyn AggregationPolicy> {
        match *self {
            AggPolicyKind::FullBarrier => Box::new(FullBarrier),
            AggPolicyKind::DeadlineDrop { deadline_s } => {
                Box::new(DeadlineDrop { deadline_s })
            }
            AggPolicyKind::SemiSync { k, timeout_s } => {
                Box::new(SemiSync { k, timeout_s, staleness_exp })
            }
        }
    }
}

/// Which round-boundary controller adapts the run (`control`). The
/// coordinator instantiates the matching [`Controller`](crate::control::Controller)
/// object; `Static` is the default and leaves every round untouched.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ControllerKind {
    /// Never adapts — bit-identical to running without a controller.
    #[default]
    Static,
    /// Refit per-cluster semi-sync K/timeout each round from the
    /// empirical report-time quantiles of a sliding telemetry window.
    /// Requires the event-driven latency mode.
    AdaptiveSemiSync {
        /// Rounds of telemetry pooled per fit (>= 1).
        window: usize,
    },
    /// Floating aggregation point (arXiv:2203.13950): swap `cloud` ↔
    /// `gossip(π)` steps and migrate the aggregator anchor when cloud
    /// backhaul bandwidth or roster churn crosses hysteresis thresholds.
    FloatingAggregation {
        /// Decentralize when `b_d2c` falls below `threshold` × its
        /// baseline, in (0, 1].
        threshold: f64,
    },
}

impl ControllerKind {
    /// Parse `static` | `adaptive[:<window>]` | `floating[:<threshold>]`.
    pub fn parse(s: &str) -> Result<ControllerKind> {
        let bad = || {
            CfelError::Config(format!(
                "unknown controller {s:?} \
                 (static | adaptive:<window_rounds> | floating:<threshold>)"
            ))
        };
        if s == "static" {
            return Ok(ControllerKind::Static);
        }
        if let Some(rest) = s.strip_prefix("adaptive") {
            let window = match rest.strip_prefix(':') {
                Some(w) => w.parse().map_err(|_| bad())?,
                None if rest.is_empty() => 5,
                None => return Err(bad()),
            };
            return Ok(ControllerKind::AdaptiveSemiSync { window });
        }
        if let Some(rest) = s.strip_prefix("floating") {
            let threshold = match rest.strip_prefix(':') {
                Some(t) => t.parse().map_err(|_| bad())?,
                None if rest.is_empty() => 0.5,
                None => return Err(bad()),
            };
            return Ok(ControllerKind::FloatingAggregation { threshold });
        }
        Err(bad())
    }

    pub fn name(&self) -> String {
        match self {
            ControllerKind::Static => "static".into(),
            ControllerKind::AdaptiveSemiSync { window } => format!("adaptive:{window}"),
            ControllerKind::FloatingAggregation { threshold } => format!("floating:{threshold}"),
        }
    }
}

/// Secure-aggregation tier for device→edge uploads (`secagg`). Enabling
/// it rewrites every plain `edge(E)` phase of the resolved plan to
/// `edge(E)@masked` (see [`ExperimentConfig::resolved_plan`]); the
/// trainer then runs the pairwise-masking protocol so the edge server
/// only ever sees masked sums, never an individual device's update.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SecaggMode {
    /// No masking — plans and cost models are untouched (the default).
    #[default]
    Off,
    /// Mask+unmask the raw f32 bit patterns in place: a protocol
    /// identity that exercises the full pairwise-mask machinery with
    /// zero quantization error and zero charged cost, pinned
    /// bitwise-identical to `Off` (`rust/tests/secagg_equivalence.rs`).
    Lossless,
    /// Fixed-point encode device updates at `bits` fractional bits,
    /// mask, and aggregate under wrapping integer arithmetic; mask
    /// compute and upload inflation are charged in both latency
    /// estimators. `bits` must lie in `1..=secagg::MAX_BITS`.
    Mask(u32),
}

impl SecaggMode {
    /// Parse `off` | `lossless` | `mask:<bits>`.
    pub fn parse(s: &str) -> Result<SecaggMode> {
        let bad = || {
            CfelError::Config(format!(
                "unknown secagg mode {s:?} (off | lossless | mask:<bits 1..={}>)",
                crate::secagg::MAX_BITS
            ))
        };
        match s {
            "off" | "none" => return Ok(SecaggMode::Off),
            "lossless" => return Ok(SecaggMode::Lossless),
            _ => {}
        }
        if let Some(b) = s.strip_prefix("mask:") {
            let bits: u32 = b.parse().map_err(|_| bad())?;
            if !(1..=crate::secagg::MAX_BITS).contains(&bits) {
                return Err(bad());
            }
            return Ok(SecaggMode::Mask(bits));
        }
        Err(bad())
    }

    pub fn name(&self) -> String {
        match self {
            SecaggMode::Off => "off".into(),
            SecaggMode::Lossless => "lossless".into(),
            SecaggMode::Mask(bits) => format!("mask:{bits}"),
        }
    }
}

/// How the federated data is generated/partitioned (paper §6.1 + Fig. 5).
#[derive(Debug, Clone, PartialEq)]
pub enum DataScheme {
    /// FEMNIST path: per-writer generation, Dirichlet(label_alpha) labels.
    FemnistWriters { label_alpha: f64 },
    /// CIFAR path: balanced pool + Dirichlet(alpha) device split.
    PoolDirichlet { alpha: f64 },
    /// IID pool split (sanity baseline).
    PoolIid,
    /// Fig. 5 cluster-IID: IID across clusters, 2-shard skew within.
    ClusterIid,
    /// Fig. 5 cluster-non-IID: C labels per cluster, 2-shard skew within.
    ClusterNonIid { c_labels: usize },
}

impl DataScheme {
    pub fn parse(s: &str) -> Result<DataScheme> {
        if let Some(a) = s.strip_prefix("writers:") {
            return Ok(DataScheme::FemnistWriters {
                label_alpha: a.parse().map_err(|_| bad_scheme(s))?,
            });
        }
        if let Some(a) = s.strip_prefix("dirichlet:") {
            return Ok(DataScheme::PoolDirichlet {
                alpha: a.parse().map_err(|_| bad_scheme(s))?,
            });
        }
        if let Some(c) = s.strip_prefix("cluster-noniid:") {
            return Ok(DataScheme::ClusterNonIid {
                c_labels: c.parse().map_err(|_| bad_scheme(s))?,
            });
        }
        match s {
            "iid" => Ok(DataScheme::PoolIid),
            "cluster-iid" => Ok(DataScheme::ClusterIid),
            _ => Err(bad_scheme(s)),
        }
    }

    pub fn name(&self) -> String {
        match self {
            DataScheme::FemnistWriters { label_alpha } => format!("writers:{label_alpha}"),
            DataScheme::PoolDirichlet { alpha } => format!("dirichlet:{alpha}"),
            DataScheme::PoolIid => "iid".into(),
            DataScheme::ClusterIid => "cluster-iid".into(),
            DataScheme::ClusterNonIid { c_labels } => format!("cluster-noniid:{c_labels}"),
        }
    }
}

fn bad_scheme(s: &str) -> CfelError {
    CfelError::Config(format!(
        "unknown data scheme {s:?} (writers:<a> | dirichlet:<a> | iid | cluster-iid | cluster-noniid:<C>)"
    ))
}

/// Which execution backend runs the train/eval steps.
#[derive(Debug, Clone, PartialEq)]
pub enum BackendKind {
    /// Pure-Rust mock MLP (fast; no artifacts needed).
    Mock { hidden: usize },
    /// PJRT + AOT HLO artifacts (`make artifacts`).
    Pjrt { model: String, artifacts_dir: Option<PathBuf> },
}

/// Fault injection (Table 1 fault-tolerance experiment).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpec {
    /// Edge server `cluster` dies at the start of `at_round`: its devices
    /// are lost; CE-FedAvg reroutes gossip over the surviving graph.
    KillCluster { at_round: usize, cluster: usize },
    /// The central aggregator (cloud, or the hub edge server) dies at
    /// `at_round`: FedAvg / Hier-FAvg lose all global aggregation.
    KillAggregator { at_round: usize },
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// Canned schedule selector; [`ExperimentConfig::resolved_plan`] maps
    /// it to the matching `Plan` constructor unless `plan` overrides it.
    pub algorithm: AlgorithmKind,
    /// Explicit federation plan (`--plan`); replaces the canned plan the
    /// `algorithm` field names. `validate` rejects setting both (the same
    /// sugar/primary contract as `deadline_s` vs `agg_policy`).
    pub plan: Option<Plan>,
    /// Explicit world description (`--scenario`); replaces the flat world
    /// knobs (`n_devices`/`n_clusters` split, `heterogeneity`,
    /// `stragglers`, `topology`), which are sugar lowering into a static
    /// [`Scenario`] via [`ExperimentConfig::resolved_scenario`].
    pub scenario: Option<Scenario>,
    /// Total devices n.
    pub n_devices: usize,
    /// Clusters / edge servers m. Need not divide n: the remainder is
    /// spread over the first clusters ([`ExperimentConfig::cluster_sizes`]).
    pub n_clusters: usize,
    /// Intra-cluster aggregation period: local *epochs* per edge round
    /// (the paper runs epochs, following Reddi et al. [42]).
    pub tau: usize,
    /// Edge rounds per global round.
    pub q: usize,
    /// Gossip steps per global aggregation (π).
    pub pi: u32,
    /// Global rounds p.
    pub rounds: usize,
    pub lr: f32,
    /// Backhaul topology: "ring" | "complete" | "star" | "line" | "er:<p>".
    pub topology: String,
    /// Training samples generated per device (writers) / pool size is
    /// `n_devices * samples_per_device` (pool schemes).
    pub samples_per_device: usize,
    /// Common test-set size (pool schemes; writers derive 10% splits).
    pub test_size: usize,
    pub data: DataScheme,
    pub backend: BackendKind,
    /// Device compute heterogeneity: Some(lo) draws c_k ~ U[lo,1]·capacity.
    pub heterogeneity: Option<f64>,
    /// Heavy-tail straggler population layered on top of `heterogeneity`.
    pub stragglers: Option<StragglerSpec>,
    /// Per-round latency estimator: closed-form Eq. 8 or the event sim.
    pub latency: LatencyMode,
    /// Per-edge-round reporting deadline T_dl in simulated seconds; slow
    /// devices are dropped from Eq. 6 aggregation (weights renormalize
    /// over the survivors). Requires `latency = EventDriven`. Sugar for
    /// `agg_policy = DeadlineDrop { .. }` — cannot be combined with a
    /// non-default `agg_policy` (see [`ExperimentConfig::resolved_policy`]).
    pub deadline_s: Option<f64>,
    /// Edge-round close policy (full barrier / deadline-drop / semi-sync).
    pub agg_policy: AggPolicyKind,
    /// Polynomial staleness exponent `a`: a semi-sync report merged `s`
    /// edge phases after its origin phase is weighted by `n/(1+s)^a`.
    /// `0` weights stale reports like fresh ones.
    pub staleness_exp: f64,
    /// Override the synthetic generator's per-sample noise std (task
    /// difficulty knob; None = the generator default).
    pub data_noise: Option<f32>,
    /// Override the per-writer style-shift std (feature heterogeneity).
    pub writer_style: Option<f32>,
    /// Lossy codec applied to every model upload (device→edge and
    /// backhaul); Eq. 8 scales transmitted bits accordingly.
    pub compression: Compressor,
    /// Secure-aggregation tier for device→edge uploads: off (default),
    /// lossless (mask+unmask identity — bitwise equal to off), or
    /// mask:<bits> (fixed-point pairwise masking with charged compute
    /// and bandwidth costs). Sugar: rewrites every plain `edge(E)`
    /// phase of the resolved plan to `edge(E)@masked`.
    pub secagg: SecaggMode,
    /// Fraction of each cluster's devices sampled per edge round
    /// (classic FedAvg client sampling; 1.0 = full participation).
    pub participation: f64,
    /// Evaluate every k-th global round (1 = every round).
    pub eval_every: usize,
    pub fault: Option<FaultSpec>,
    /// Round-boundary controller: rewrites the next round's plan and
    /// per-cluster close policies from observed telemetry. `Static`
    /// (the default) never adapts and is bit-identical to the plain
    /// interpreter (`rust/tests/control_equivalence.rs`).
    pub controller: ControllerKind,
}

impl ExperimentConfig {
    /// Small fast CE-FedAvg run on the mock backend (README quickstart).
    pub fn quickstart() -> ExperimentConfig {
        ExperimentConfig {
            name: "quickstart".into(),
            seed: 42,
            algorithm: AlgorithmKind::CeFedAvg,
            plan: None,
            scenario: None,
            n_devices: 16,
            n_clusters: 4,
            tau: 2,
            q: 2,
            pi: 10,
            rounds: 15,
            lr: 0.05,
            topology: "ring".into(),
            samples_per_device: 60,
            test_size: 400,
            data: DataScheme::FemnistWriters { label_alpha: 0.3 },
            backend: BackendKind::Mock { hidden: 32 },
            heterogeneity: None,
            stragglers: None,
            latency: LatencyMode::ClosedForm,
            deadline_s: None,
            agg_policy: AggPolicyKind::FullBarrier,
            staleness_exp: 1.0,
            // noise 3.0 puts Bayes accuracy ≈ 0.85 on the 64-d synthetic
            // task, so convergence curves resolve over tens of rounds
            // instead of saturating immediately (tuned empirically).
            data_noise: Some(3.0),
            writer_style: None,
            compression: Compressor::None,
            secagg: SecaggMode::Off,
            participation: 1.0,
            eval_every: 1,
            fault: None,
            controller: ControllerKind::Static,
        }
    }

    /// The paper's §6.1 system shape: 64 devices, 8 edge servers, ring
    /// backhaul, τ=2, q=8, π=10 (scaled sample counts; see DESIGN.md §1).
    pub fn paper_system(algorithm: AlgorithmKind) -> ExperimentConfig {
        ExperimentConfig {
            name: format!("paper-{}", algorithm.name()),
            seed: 1,
            algorithm,
            plan: None,
            scenario: None,
            n_devices: 64,
            n_clusters: 8,
            tau: 2,
            q: 8,
            pi: 10,
            rounds: 40,
            lr: 0.05,
            topology: "ring".into(),
            samples_per_device: 48,
            test_size: 800,
            data: DataScheme::FemnistWriters { label_alpha: 0.3 },
            backend: BackendKind::Mock { hidden: 32 },
            heterogeneity: None,
            stragglers: None,
            latency: LatencyMode::ClosedForm,
            deadline_s: None,
            agg_policy: AggPolicyKind::FullBarrier,
            staleness_exp: 1.0,
            // noise 3.0 puts Bayes accuracy ≈ 0.85 on the 64-d synthetic
            // task, so convergence curves resolve over tens of rounds
            // instead of saturating immediately (tuned empirically).
            data_noise: Some(3.0),
            writer_style: None,
            compression: Compressor::None,
            secagg: SecaggMode::Off,
            participation: 1.0,
            eval_every: 1,
            fault: None,
            controller: ControllerKind::Static,
        }
    }

    /// Floor of the per-cluster device count. With a non-divisible split
    /// the first `n % m` clusters hold one more device — use
    /// [`ExperimentConfig::cluster_sizes`] for the exact layout.
    pub fn devices_per_cluster(&self) -> usize {
        self.n_devices / self.n_clusters
    }

    /// Per-cluster device counts: `n / m` each, with the remainder spread
    /// one-per-cluster over the first `n % m` clusters. Identical to the
    /// historical uniform split whenever `m` divides `n`.
    pub fn cluster_sizes(&self) -> Vec<usize> {
        let base = self.n_devices / self.n_clusters;
        let extra = self.n_devices % self.n_clusters;
        (0..self.n_clusters)
            .map(|ci| base + usize::from(ci < extra))
            .collect()
    }

    /// The world this config runs in: the explicit `scenario` if one is
    /// set, otherwise the static lowering of the flat knobs
    /// ([`Scenario::from_flat`]). The coordinator builds exclusively from
    /// this, so the flat spelling and its lowered scenario are one code
    /// path (pinned bit-identical by `rust/tests/scenario_equivalence.rs`).
    pub fn resolved_scenario(&self) -> Scenario {
        match &self.scenario {
            Some(s) => s.clone(),
            None => Scenario::from_flat(self),
        }
    }

    /// The per-round schedule this config runs: the explicit `plan` if
    /// one is set, otherwise the canned plan `algorithm` names.
    /// (`validate` rejects setting both, mirroring `resolved_policy`.)
    /// With secagg enabled, every plain device→edge phase is rewritten
    /// to the masked channel ([`Plan::mask_edges`]) — the edge-phase
    /// count is preserved, so the phase cursor and RNG streams match the
    /// unmasked plan exactly.
    pub fn resolved_plan(&self) -> Plan {
        let plan = match &self.plan {
            Some(p) => p.clone(),
            None => Plan::for_algorithm(self.algorithm, self),
        };
        if self.secagg == SecaggMode::Off {
            plan
        } else {
            plan.mask_edges()
        }
    }

    /// Series label for logs and CSV rows: the algorithm name for canned
    /// runs (unchanged from the pre-plan CSV schema), the canonical plan
    /// spec for explicit-plan runs. Runs under an explicit scenario append
    /// `@<scenario name>`, and runs under a non-static controller append
    /// `+<controller name>`, so their CSV rows stay distinguishable from
    /// canned-config runs.
    pub fn run_label(&self) -> String {
        let base = match &self.plan {
            Some(p) => format!("plan:{p}"),
            None => self.algorithm.name().to_string(),
        };
        let mut label = match &self.scenario {
            Some(s) => format!("{base}@{}", s.name),
            None => base,
        };
        if self.controller != ControllerKind::Static {
            label.push_str(&format!("+{}", self.controller.name()));
        }
        label
    }

    /// The effective close policy: an explicit `agg_policy` wins; the
    /// legacy `deadline_s` sugar maps to [`AggPolicyKind::DeadlineDrop`];
    /// otherwise the full barrier. (`validate` rejects setting both.)
    pub fn resolved_policy(&self) -> AggPolicyKind {
        if self.agg_policy != AggPolicyKind::FullBarrier {
            return self.agg_policy;
        }
        match self.deadline_s {
            Some(deadline_s) => AggPolicyKind::DeadlineDrop { deadline_s },
            None => AggPolicyKind::FullBarrier,
        }
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.n_devices == 0 || self.n_clusters == 0 {
            return Err(CfelError::Config("need at least 1 device and cluster".into()));
        }
        if self.n_devices < self.n_clusters {
            return Err(CfelError::Config(format!(
                "n_devices {} < n_clusters {}: every edge server needs at \
                 least one device",
                self.n_devices, self.n_clusters
            )));
        }
        if self.tau == 0 || self.q == 0 || self.rounds == 0 || self.eval_every == 0 {
            return Err(CfelError::Config("tau/q/rounds/eval_every must be >= 1".into()));
        }
        if self.pi == 0 && self.plan.is_none() && self.algorithm == AlgorithmKind::CeFedAvg {
            return Err(CfelError::Config("CE-FedAvg needs pi >= 1".into()));
        }
        if let Some(p) = &self.plan {
            p.validate()?;
            // Same contract as `deadline_s` vs `agg_policy` below: the
            // explicit spelling cannot be combined with a non-default
            // value of the knob it replaces. (An explicitly *default*
            // algorithm is indistinguishable here; the CLI layer rejects
            // that case from the flags themselves.)
            if self.algorithm != AlgorithmKind::CeFedAvg {
                return Err(conflicting_options(
                    "plan",
                    "algorithm",
                    "an explicit plan replaces the canned algorithm schedule",
                ));
            }
        }
        if let Some(s) = &self.scenario {
            s.validate()?;
            if s.n_devices != self.n_devices || s.n_clusters() != self.n_clusters {
                return Err(CfelError::Config(format!(
                    "scenario {:?} describes {} devices / {} clusters but the \
                     config says {} / {} (the CLI syncs these when loading \
                     --scenario)",
                    s.name,
                    s.n_devices,
                    s.n_clusters(),
                    self.n_devices,
                    self.n_clusters
                )));
            }
            // The same sugar/primary contract as `deadline_s` vs
            // `agg_policy`: the flat capability knobs lower *into* a
            // scenario, so combining them with an explicit one is
            // contradictory.
            if self.heterogeneity.is_some() {
                return Err(conflicting_options(
                    "scenario",
                    "heterogeneity",
                    "capability profiles live in the scenario",
                ));
            }
            if self.stragglers.is_some() {
                return Err(conflicting_options(
                    "scenario",
                    "stragglers",
                    "capability profiles live in the scenario",
                ));
            }
            if self.fault.is_some() && !s.timeline.is_empty() {
                return Err(conflicting_options(
                    "scenario timeline",
                    "fault",
                    "both mutate the world mid-run",
                ));
            }
            if self.topology != s.topology {
                return Err(CfelError::Config(format!(
                    "config topology {:?} does not match scenario topology \
                     {:?} (the scenario owns the backhaul; the CLI and JSON \
                     loaders sync this field)",
                    self.topology, s.topology
                )));
            }
            // Like `deadline_s`: per-device uplink overrides only exist in
            // the event simulator — the closed form would silently charge
            // the shared channel and report wrong upload times.
            if let CapabilityProfiles::Explicit(profiles) = &s.capabilities {
                if profiles.iter().any(|p| p.uplink_bps.is_some())
                    && self.latency != LatencyMode::EventDriven
                {
                    return Err(CfelError::Config(
                        "per-device uplink overrides require the \
                         event-driven latency mode (set latency = \"event\" \
                         / pass --latency event); the closed-form Eq. 8 \
                         charges the shared channel"
                            .into(),
                    ));
                }
            }
            if s.dormant_count() > 0
                && matches!(
                    self.data,
                    DataScheme::ClusterIid | DataScheme::ClusterNonIid { .. }
                )
            {
                return Err(CfelError::Config(
                    "cluster data schemes partition the pool by roster, so \
                     every device must appear in an initial roster (no \
                     dormant devices)"
                        .into(),
                ));
            }
        }
        if self.lr.is_nan() || self.lr <= 0.0 {
            return Err(CfelError::Config(format!("lr must be positive, got {}", self.lr)));
        }
        if self.samples_per_device == 0 {
            return Err(CfelError::Config("samples_per_device must be >= 1".into()));
        }
        if !(0.0 < self.participation && self.participation <= 1.0) {
            return Err(CfelError::Config(format!(
                "participation {} outside (0,1]",
                self.participation
            )));
        }
        if let Some(lo) = self.heterogeneity {
            if !(0.0 < lo && lo <= 1.0) {
                return Err(CfelError::Config(format!("heterogeneity {lo} outside (0,1]")));
            }
        }
        if let Some(spec) = self.stragglers {
            spec.validate()?;
        }
        if let Some(dl) = self.deadline_s {
            if !(dl > 0.0 && dl.is_finite()) {
                return Err(CfelError::Config(format!(
                    "deadline_s {dl} must be positive and finite"
                )));
            }
            if self.agg_policy != AggPolicyKind::FullBarrier {
                return Err(conflicting_options(
                    "deadline_s",
                    &format!("agg_policy {:?}", self.agg_policy.name()),
                    "deadline_s is sugar for the deadline-drop policy",
                ));
            }
        }
        match self.agg_policy {
            AggPolicyKind::FullBarrier => {}
            AggPolicyKind::DeadlineDrop { deadline_s } => {
                if !(deadline_s > 0.0 && deadline_s.is_finite()) {
                    return Err(CfelError::Config(format!(
                        "deadline-drop deadline {deadline_s} must be positive and finite"
                    )));
                }
            }
            AggPolicyKind::SemiSync { k, timeout_s } => {
                if k == 0 {
                    return Err(CfelError::Config("semi-sync K must be >= 1".into()));
                }
                if timeout_s <= 0.0 || timeout_s.is_nan() {
                    return Err(CfelError::Config(format!(
                        "semi-sync timeout {timeout_s} must be positive (or inf)"
                    )));
                }
            }
        }
        if self.resolved_policy() != AggPolicyKind::FullBarrier
            && self.latency != LatencyMode::EventDriven
        {
            return Err(CfelError::Config(
                "deadline-drop and semi-sync close policies require the \
                 event-driven latency mode (set latency = \"event\" / pass \
                 --latency event)"
                    .into(),
            ));
        }
        if !(self.staleness_exp >= 0.0 && self.staleness_exp.is_finite()) {
            return Err(CfelError::Config(format!(
                "staleness_exp {} must be finite and >= 0",
                self.staleness_exp
            )));
        }
        match self.controller {
            ControllerKind::Static => {}
            ControllerKind::AdaptiveSemiSync { window } => {
                if window == 0 {
                    return Err(CfelError::Config(
                        "adaptive controller window must be >= 1".into(),
                    ));
                }
                if self.latency != LatencyMode::EventDriven {
                    return Err(CfelError::Config(
                        "the adaptive semi-sync controller fits K/timeout to \
                         per-device report times, which only the event-driven \
                         latency mode produces (set latency = \"event\" / pass \
                         --latency event)"
                            .into(),
                    ));
                }
            }
            ControllerKind::FloatingAggregation { threshold } => {
                if !(threshold > 0.0 && threshold <= 1.0) {
                    return Err(CfelError::Config(format!(
                        "floating controller threshold {threshold} outside (0,1]"
                    )));
                }
                if self.pi == 0 {
                    return Err(CfelError::Config(
                        "the floating controller rewrites cloud aggregates \
                         into gossip(pi) consensus; set pi >= 1"
                            .into(),
                    ));
                }
            }
        }
        if self.controller != ControllerKind::Static && self.fault.is_some() {
            return Err(conflicting_options(
                "controller",
                "fault",
                "faults mutate the world outside the telemetry the \
                 controller replays; use a scenario timeline instead",
            ));
        }
        let masked_phases = self.resolved_plan().comms().masked_uploads;
        if self.secagg == SecaggMode::Off && masked_phases > 0 {
            return Err(CfelError::Config(
                "the plan has edge(E)@masked phases but secagg is off; \
                 enable it (--secagg lossless | mask:<bits>) so the \
                 coordinator knows how to mask and cost the uploads"
                    .into(),
            ));
        }
        if self.secagg != SecaggMode::Off && masked_phases == 0 {
            return Err(CfelError::Config(
                "secagg is enabled but the resolved plan has no \
                 device→edge report phases to mask (cloud uploads have \
                 no pairwise-masking tier)"
                    .into(),
            ));
        }
        if let SecaggMode::Mask(bits) = self.secagg {
            if !(1..=crate::secagg::MAX_BITS).contains(&bits) {
                return Err(CfelError::Config(format!(
                    "secagg mask bits {bits} outside 1..={}",
                    crate::secagg::MAX_BITS
                )));
            }
            if matches!(self.resolved_policy(), AggPolicyKind::SemiSync { .. }) {
                return Err(CfelError::Config(
                    "secagg mask mode cannot run under the semi-sync close \
                     policy: a stale report merges after its phase's \
                     pairwise masks were reconciled, so its mask shares \
                     could never cancel; use full or deadline:<seconds>"
                        .into(),
                ));
            }
            if self.controller != ControllerKind::Static {
                return Err(CfelError::Config(
                    "secagg mask mode requires the static controller: \
                     adaptive controllers rewrite per-cluster close \
                     policies (and may introduce semi-sync merges), which \
                     breaks the mask-reconciliation invariant"
                        .into(),
                ));
            }
        }
        if let Some(FaultSpec::KillCluster { cluster, .. }) = self.fault {
            if cluster >= self.n_clusters {
                return Err(CfelError::Config(format!(
                    "fault cluster {cluster} >= n_clusters {}",
                    self.n_clusters
                )));
            }
        }
        if let DataScheme::ClusterNonIid { c_labels } = self.data {
            if c_labels == 0 {
                return Err(CfelError::Config("cluster-noniid C must be >= 1".into()));
            }
        }
        Ok(())
    }

    // ----- JSON persistence --------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::from_str_val(&self.name))
            .set("seed", Json::from_usize(self.seed as usize))
            .set("algorithm", Json::from_str_val(self.algorithm.name()))
            .set("n_devices", Json::from_usize(self.n_devices))
            .set("n_clusters", Json::from_usize(self.n_clusters))
            .set("tau", Json::from_usize(self.tau))
            .set("q", Json::from_usize(self.q))
            .set("pi", Json::from_usize(self.pi as usize))
            .set("rounds", Json::from_usize(self.rounds))
            .set("lr", Json::from_f64(self.lr as f64))
            .set("topology", Json::from_str_val(&self.topology))
            .set("samples_per_device", Json::from_usize(self.samples_per_device))
            .set("test_size", Json::from_usize(self.test_size))
            .set("data", Json::from_str_val(&self.data.name()))
            .set("eval_every", Json::from_usize(self.eval_every));
        match &self.backend {
            BackendKind::Mock { hidden } => {
                o.set("backend", Json::from_str_val("mock"))
                    .set("mock_hidden", Json::from_usize(*hidden));
            }
            BackendKind::Pjrt { model, artifacts_dir } => {
                o.set("backend", Json::from_str_val("pjrt"))
                    .set("model", Json::from_str_val(model));
                if let Some(d) = artifacts_dir {
                    o.set("artifacts_dir", Json::from_str_val(&d.display().to_string()));
                }
            }
        }
        if let Some(p) = &self.plan {
            o.set("plan", Json::from_str_val(&p.to_string()));
        }
        if let Some(s) = &self.scenario {
            o.set("scenario", s.to_json());
        }
        if let Some(h) = self.heterogeneity {
            o.set("heterogeneity", Json::from_f64(h));
        }
        if let Some(s) = self.stragglers {
            o.set("stragglers", Json::from_str_val(&s.name()));
        }
        if self.latency != LatencyMode::ClosedForm {
            o.set("latency", Json::from_str_val(self.latency.name()));
        }
        if let Some(dl) = self.deadline_s {
            o.set("deadline_s", Json::from_f64(dl));
        }
        if self.agg_policy != AggPolicyKind::FullBarrier {
            o.set("agg_policy", Json::from_str_val(&self.agg_policy.name()));
        }
        if self.staleness_exp != 1.0 {
            o.set("staleness_exp", Json::from_f64(self.staleness_exp));
        }
        if let Some(n) = self.data_noise {
            o.set("data_noise", Json::from_f64(n as f64));
        }
        if let Some(s) = self.writer_style {
            o.set("writer_style", Json::from_f64(s as f64));
        }
        if self.compression != Compressor::None {
            o.set("compression", Json::from_str_val(&self.compression.name()));
        }
        if self.secagg != SecaggMode::Off {
            o.set("secagg", Json::from_str_val(&self.secagg.name()));
        }
        if self.participation != 1.0 {
            o.set("participation", Json::from_f64(self.participation));
        }
        if self.controller != ControllerKind::Static {
            o.set("controller", Json::from_str_val(&self.controller.name()));
        }
        match self.fault {
            Some(FaultSpec::KillCluster { at_round, cluster }) => {
                o.set("fault", Json::from_str_val("kill-cluster"))
                    .set("fault_round", Json::from_usize(at_round))
                    .set("fault_cluster", Json::from_usize(cluster));
            }
            Some(FaultSpec::KillAggregator { at_round }) => {
                o.set("fault", Json::from_str_val("kill-aggregator"))
                    .set("fault_round", Json::from_usize(at_round));
            }
            None => {}
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig> {
        let base = ExperimentConfig::quickstart();
        let get_usize = |key: &str, d: usize| -> Result<usize> {
            match j.opt(key) {
                Some(v) => v.as_usize(),
                None => Ok(d),
            }
        };
        let backend = match j.opt("backend").map(|b| b.as_str()).transpose()? {
            Some("pjrt") => BackendKind::Pjrt {
                model: j.get("model")?.as_str()?.to_string(),
                artifacts_dir: j
                    .opt("artifacts_dir")
                    .map(|v| v.as_str().map(PathBuf::from))
                    .transpose()?,
            },
            _ => BackendKind::Mock { hidden: get_usize("mock_hidden", 32)? },
        };
        let fault = match j.opt("fault").map(|f| f.as_str()).transpose()? {
            Some("kill-cluster") => Some(FaultSpec::KillCluster {
                at_round: j.get("fault_round")?.as_usize()?,
                cluster: j.get("fault_cluster")?.as_usize()?,
            }),
            Some("kill-aggregator") => Some(FaultSpec::KillAggregator {
                at_round: j.get("fault_round")?.as_usize()?,
            }),
            Some(other) => {
                return Err(CfelError::Config(format!("unknown fault {other:?}")))
            }
            None => None,
        };
        let scenario = j.opt("scenario").map(Scenario::from_json).transpose()?;
        // An embedded scenario fixes the system shape; explicit
        // n_devices / n_clusters / topology keys still win (validate
        // cross-checks the result).
        let (scen_devices, scen_clusters) = match &scenario {
            Some(s) => (Some(s.n_devices), Some(s.n_clusters())),
            None => (None, None),
        };
        let scen_topology = scenario.as_ref().map(|s| s.topology.clone());
        let cfg = ExperimentConfig {
            name: j
                .opt("name")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| base.name.clone()),
            seed: get_usize("seed", base.seed as usize)? as u64,
            algorithm: match j.opt("algorithm") {
                Some(v) => AlgorithmKind::parse(v.as_str()?)?,
                None => base.algorithm,
            },
            plan: j
                .opt("plan")
                .map(|v| v.as_str().and_then(Plan::parse))
                .transpose()?,
            scenario,
            n_devices: get_usize("n_devices", scen_devices.unwrap_or(base.n_devices))?,
            n_clusters: get_usize("n_clusters", scen_clusters.unwrap_or(base.n_clusters))?,
            tau: get_usize("tau", base.tau)?,
            q: get_usize("q", base.q)?,
            pi: get_usize("pi", base.pi as usize)? as u32,
            rounds: get_usize("rounds", base.rounds)?,
            lr: match j.opt("lr") {
                Some(v) => v.as_f64()? as f32,
                None => base.lr,
            },
            topology: j
                .opt("topology")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| scen_topology.unwrap_or_else(|| base.topology.clone())),
            samples_per_device: get_usize("samples_per_device", base.samples_per_device)?,
            test_size: get_usize("test_size", base.test_size)?,
            data: match j.opt("data") {
                Some(v) => DataScheme::parse(v.as_str()?)?,
                None => base.data.clone(),
            },
            backend,
            heterogeneity: j.opt("heterogeneity").map(|v| v.as_f64()).transpose()?,
            stragglers: j
                .opt("stragglers")
                .map(|v| v.as_str().and_then(StragglerSpec::parse))
                .transpose()?,
            latency: match j.opt("latency") {
                Some(v) => LatencyMode::parse(v.as_str()?)?,
                None => LatencyMode::ClosedForm,
            },
            deadline_s: j.opt("deadline_s").map(|v| v.as_f64()).transpose()?,
            agg_policy: match j.opt("agg_policy") {
                Some(v) => AggPolicyKind::parse(v.as_str()?)?,
                None => AggPolicyKind::FullBarrier,
            },
            staleness_exp: match j.opt("staleness_exp") {
                Some(v) => v.as_f64()?,
                None => 1.0,
            },
            data_noise: j
                .opt("data_noise")
                .map(|v| v.as_f64().map(|x| x as f32))
                .transpose()?,
            writer_style: j
                .opt("writer_style")
                .map(|v| v.as_f64().map(|x| x as f32))
                .transpose()?,
            compression: match j.opt("compression") {
                Some(v) => Compressor::parse(v.as_str()?)?,
                None => Compressor::None,
            },
            secagg: match j.opt("secagg") {
                Some(v) => SecaggMode::parse(v.as_str()?)?,
                None => SecaggMode::Off,
            },
            participation: match j.opt("participation") {
                Some(v) => v.as_f64()?,
                None => 1.0,
            },
            eval_every: get_usize("eval_every", base.eval_every)?,
            fault,
            controller: match j.opt("controller") {
                Some(v) => ControllerKind::parse(v.as_str()?)?,
                None => ControllerKind::Static,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quickstart_and_paper_presets_valid() {
        ExperimentConfig::quickstart().validate().unwrap();
        for a in AlgorithmKind::all() {
            ExperimentConfig::paper_system(a).validate().unwrap();
        }
    }

    #[test]
    fn validation_catches_bad_shapes() {
        // Non-divisible counts are legal now (remainder spreads over the
        // first clusters); fewer devices than clusters is not.
        let mut c = ExperimentConfig::quickstart();
        c.n_devices = 17;
        c.validate().unwrap();
        c.n_devices = 3; // 3 devices cannot cover 4 edge servers
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::quickstart();
        c.tau = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::quickstart();
        c.lr = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::quickstart();
        c.heterogeneity = Some(1.5);
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::quickstart();
        c.fault = Some(FaultSpec::KillCluster { at_round: 1, cluster: 99 });
        assert!(c.validate().is_err());
        // A deadline without the event-driven latency mode is rejected...
        let mut c = ExperimentConfig::quickstart();
        c.deadline_s = Some(0.5);
        assert!(c.validate().is_err());
        // ...and accepted with it.
        c.latency = LatencyMode::EventDriven;
        c.validate().unwrap();
        c.deadline_s = Some(-1.0);
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::quickstart();
        c.stragglers = Some(StragglerSpec { fraction: 2.0, slowdown: 4.0 });
        assert!(c.validate().is_err());
        // Semi-sync / deadline-drop policies need the event-driven mode...
        let mut c = ExperimentConfig::quickstart();
        c.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: f64::INFINITY };
        assert!(c.validate().is_err());
        // ...and are accepted with it.
        c.latency = LatencyMode::EventDriven;
        c.validate().unwrap();
        c.agg_policy = AggPolicyKind::SemiSync { k: 0, timeout_s: 1.0 };
        assert!(c.validate().is_err(), "K = 0 rejected");
        c.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: -1.0 };
        assert!(c.validate().is_err(), "negative timeout rejected");
        c.agg_policy = AggPolicyKind::DeadlineDrop { deadline_s: f64::INFINITY };
        assert!(c.validate().is_err(), "deadline-drop needs a finite deadline");
        // The deadline_s sugar conflicts with an explicit policy.
        c.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 1.0 };
        c.deadline_s = Some(0.5);
        assert!(c.validate().is_err());
        c.deadline_s = None;
        c.staleness_exp = -0.5;
        assert!(c.validate().is_err(), "negative staleness exponent rejected");
    }

    #[test]
    fn agg_policy_parse_roundtrip() {
        for p in [
            AggPolicyKind::FullBarrier,
            AggPolicyKind::DeadlineDrop { deadline_s: 0.02 },
            AggPolicyKind::SemiSync { k: 5, timeout_s: 1.5 },
            AggPolicyKind::SemiSync { k: 12, timeout_s: f64::INFINITY },
        ] {
            assert_eq!(AggPolicyKind::parse(&p.name()).unwrap(), p);
        }
        assert_eq!(
            AggPolicyKind::parse("kofn:4:inf").unwrap(),
            AggPolicyKind::SemiSync { k: 4, timeout_s: f64::INFINITY }
        );
        assert!(AggPolicyKind::parse("kofn:4").is_err());
        assert!(AggPolicyKind::parse("kofn:x:1").is_err());
        assert!(AggPolicyKind::parse("async").is_err());
    }

    #[test]
    fn resolved_policy_maps_deadline_sugar() {
        let mut c = ExperimentConfig::quickstart();
        assert_eq!(c.resolved_policy(), AggPolicyKind::FullBarrier);
        c.latency = LatencyMode::EventDriven;
        c.deadline_s = Some(0.25);
        c.validate().unwrap();
        assert_eq!(
            c.resolved_policy(),
            AggPolicyKind::DeadlineDrop { deadline_s: 0.25 }
        );
        c.deadline_s = None;
        c.agg_policy = AggPolicyKind::SemiSync { k: 2, timeout_s: 0.5 };
        c.validate().unwrap();
        assert_eq!(c.resolved_policy(), c.agg_policy);
    }

    #[test]
    fn latency_mode_parse_roundtrip() {
        for m in [LatencyMode::ClosedForm, LatencyMode::EventDriven] {
            assert_eq!(LatencyMode::parse(m.name()).unwrap(), m);
        }
        assert!(LatencyMode::parse("psychic").is_err());
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in AlgorithmKind::all() {
            assert_eq!(AlgorithmKind::parse(a.name()).unwrap(), a);
        }
        assert!(AlgorithmKind::parse("sgd").is_err());
    }

    #[test]
    fn data_scheme_parse_roundtrip() {
        for s in [
            DataScheme::FemnistWriters { label_alpha: 0.3 },
            DataScheme::PoolDirichlet { alpha: 0.5 },
            DataScheme::PoolIid,
            DataScheme::ClusterIid,
            DataScheme::ClusterNonIid { c_labels: 5 },
        ] {
            assert_eq!(DataScheme::parse(&s.name()).unwrap(), s);
        }
        assert!(DataScheme::parse("magic").is_err());
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut c = ExperimentConfig::paper_system(AlgorithmKind::HierFAvg);
        c.heterogeneity = Some(0.5);
        c.fault = Some(FaultSpec::KillCluster { at_round: 3, cluster: 2 });
        c.data = DataScheme::ClusterNonIid { c_labels: 2 };
        c.backend = BackendKind::Pjrt { model: "femnist_cnn".into(), artifacts_dir: None };
        c.stragglers = Some(StragglerSpec { fraction: 0.125, slowdown: 50.0 });
        c.latency = LatencyMode::EventDriven;
        c.deadline_s = Some(21.5);
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.algorithm, c.algorithm);
        assert_eq!(c2.n_devices, c.n_devices);
        assert_eq!(c2.data, c.data);
        assert_eq!(c2.backend, c.backend);
        assert_eq!(c2.fault, c.fault);
        assert_eq!(c2.heterogeneity, c.heterogeneity);
        assert_eq!(c2.stragglers, c.stragglers);
        assert_eq!(c2.latency, c.latency);
        assert_eq!(c2.deadline_s, c.deadline_s);
        assert_eq!(c2.tau, c.tau);
    }

    #[test]
    fn json_roundtrip_preserves_agg_policy() {
        let mut c = ExperimentConfig::quickstart();
        c.latency = LatencyMode::EventDriven;
        c.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.25 };
        c.staleness_exp = 2.0;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.agg_policy, c.agg_policy);
        assert_eq!(c2.staleness_exp, c.staleness_exp);
        // The infinite-timeout spelling survives the round trip too.
        c.agg_policy = AggPolicyKind::SemiSync { k: 16, timeout_s: f64::INFINITY };
        let c3 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c3.agg_policy, c.agg_policy);
    }

    #[test]
    fn plan_resolves_overrides_and_roundtrips() {
        let mut c = ExperimentConfig::quickstart();
        // No explicit plan: the algorithm's canned plan, algorithm label.
        assert_eq!(c.resolved_plan(), Plan::ce_fedavg(&c));
        assert_eq!(c.run_label(), "ce-fedavg");
        c.algorithm = AlgorithmKind::FedAvg;
        assert_eq!(c.resolved_plan(), Plan::fedavg(&c));
        c.validate().unwrap();
        // Explicit plan wins and labels the series with its spec.
        c.algorithm = AlgorithmKind::CeFedAvg;
        c.plan = Some(Plan::parse("(edge(2); gossip(3))*2").unwrap());
        c.validate().unwrap();
        assert_eq!(c.resolved_plan().to_string(), "(edge(2); gossip(3))*2");
        assert_eq!(c.run_label(), "plan:(edge(2); gossip(3))*2");
        // JSON carries the spec through.
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.plan, c.plan);
        assert_eq!(c2.resolved_plan(), c.resolved_plan());
    }

    #[test]
    fn plan_conflicts_with_algorithm_like_deadline_with_policy() {
        let mut c = ExperimentConfig::quickstart();
        c.plan = Some(Plan::parse("edge(2)*2").unwrap());
        c.algorithm = AlgorithmKind::LocalEdge;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("conflicts"), "{err}");
        // The same uniform helper phrases the deadline conflict.
        let mut d = ExperimentConfig::quickstart();
        d.latency = LatencyMode::EventDriven;
        d.deadline_s = Some(0.5);
        d.agg_policy = AggPolicyKind::SemiSync { k: 2, timeout_s: 1.0 };
        let err = d.validate().unwrap_err().to_string();
        assert!(err.contains("conflicts"), "{err}");
        // An invalid explicit plan is rejected by the same validate pass.
        let mut p = ExperimentConfig::quickstart();
        p.plan = Some(Plan::from_steps(vec![crate::plan::Step::Gossip { pi: 3 }]));
        assert!(p.validate().is_err(), "train-less plan accepted");
    }

    #[test]
    fn cluster_sizes_distribute_the_remainder() {
        let mut c = ExperimentConfig::quickstart();
        assert_eq!(c.cluster_sizes(), vec![4, 4, 4, 4]); // divisible: uniform
        c.n_devices = 18;
        assert_eq!(c.cluster_sizes(), vec![5, 5, 4, 4]);
        c.n_devices = 5;
        assert_eq!(c.cluster_sizes(), vec![2, 1, 1, 1]);
        assert_eq!(c.cluster_sizes().iter().sum::<usize>(), c.n_devices);
    }

    #[test]
    fn scenario_resolves_labels_and_roundtrips() {
        let mut c = ExperimentConfig::quickstart();
        // No explicit scenario: the lowering, plain label.
        assert_eq!(c.resolved_scenario(), Scenario::from_flat(&c));
        assert_eq!(c.run_label(), "ce-fedavg");
        // Explicit scenario: validated, and the label carries its name.
        let mut s = Scenario::from_flat(&c);
        s.name = "churny".into();
        c.scenario = Some(s);
        c.validate().unwrap();
        assert_eq!(c.run_label(), "ce-fedavg@churny");
        // JSON carries the whole scenario through.
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.scenario, c.scenario);
        assert_eq!(c2.n_devices, c.n_devices);
        // A config JSON whose only shape source is the scenario syncs
        // n_devices / n_clusters from it.
        let mut small = Scenario::from_flat(&ExperimentConfig::quickstart());
        small.rosters = vec![vec![0, 1], vec![2, 3, 4]];
        small.n_devices = 5;
        let mut j = Json::obj();
        j.set("scenario", small.to_json());
        let c3 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c3.n_devices, 5);
        assert_eq!(c3.n_clusters, 2);
    }

    #[test]
    fn scenario_conflicts_with_flat_capability_knobs() {
        let mut c = ExperimentConfig::quickstart();
        c.scenario = Some(Scenario::from_flat(&c));
        c.heterogeneity = Some(0.5);
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("conflicts"), "{err}");
        let mut c = ExperimentConfig::quickstart();
        c.scenario = Some(Scenario::from_flat(&c));
        c.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 10.0 });
        assert!(c.validate().is_err());
        // Shape mismatch between config and scenario is rejected.
        let mut c = ExperimentConfig::quickstart();
        c.scenario = Some(Scenario::from_flat(&c));
        c.n_devices = 32;
        assert!(c.validate().is_err());
        // So is a topology mismatch (the loaders sync the field).
        let mut c = ExperimentConfig::quickstart();
        c.scenario = Some(Scenario::from_flat(&c));
        c.topology = "complete".into();
        assert!(c.validate().is_err());
        // Per-device uplink overrides need the event-driven latency mode.
        let mut c = ExperimentConfig::quickstart();
        let mut s = Scenario::from_flat(&c);
        s.capabilities = CapabilityProfiles::Explicit(
            (0..16)
                .map(|k| crate::scenario::DeviceProfile {
                    flops: 1e9,
                    uplink_bps: if k == 0 { Some(5e6) } else { None },
                })
                .collect(),
        );
        c.scenario = Some(s);
        assert!(c.validate().is_err(), "uplink override accepted in closed form");
        c.latency = LatencyMode::EventDriven;
        c.validate().unwrap();
        // A fault plus a non-empty timeline is contradictory; a fault
        // plus a *static* scenario is fine.
        let mut c = ExperimentConfig::quickstart();
        c.fault = Some(FaultSpec::KillCluster { at_round: 2, cluster: 1 });
        c.scenario = Some(Scenario::from_flat(&c));
        c.validate().unwrap();
        let mut s = Scenario::from_flat(&c);
        s.timeline = crate::scenario::Timeline {
            events: vec![crate::scenario::TimelineEvent {
                round: 1,
                event: crate::scenario::WorldEvent::Leave { device: 0 },
            }],
        };
        c.scenario = Some(s);
        assert!(c.validate().is_err());
    }

    #[test]
    fn controller_parse_roundtrip() {
        for c in [
            ControllerKind::Static,
            ControllerKind::AdaptiveSemiSync { window: 3 },
            ControllerKind::FloatingAggregation { threshold: 0.5 },
        ] {
            assert_eq!(ControllerKind::parse(&c.name()).unwrap(), c);
        }
        // Bare spellings take the documented defaults.
        assert_eq!(
            ControllerKind::parse("adaptive").unwrap(),
            ControllerKind::AdaptiveSemiSync { window: 5 }
        );
        assert_eq!(
            ControllerKind::parse("floating").unwrap(),
            ControllerKind::FloatingAggregation { threshold: 0.5 }
        );
        assert!(ControllerKind::parse("adaptive:x").is_err());
        assert!(ControllerKind::parse("floatingly").is_err());
        assert!(ControllerKind::parse("pid").is_err());
    }

    #[test]
    fn controller_validation_and_label() {
        // Adaptive needs the event-driven latency mode and window >= 1.
        let mut c = ExperimentConfig::quickstart();
        c.controller = ControllerKind::AdaptiveSemiSync { window: 3 };
        assert!(c.validate().is_err(), "adaptive accepted in closed form");
        c.latency = LatencyMode::EventDriven;
        c.validate().unwrap();
        assert_eq!(c.run_label(), "ce-fedavg+adaptive:3");
        c.controller = ControllerKind::AdaptiveSemiSync { window: 0 };
        assert!(c.validate().is_err(), "window 0 accepted");
        // Floating needs a threshold in (0,1] and pi >= 1, and works in
        // either latency mode.
        let mut c = ExperimentConfig::quickstart();
        c.controller = ControllerKind::FloatingAggregation { threshold: 0.5 };
        c.validate().unwrap();
        assert_eq!(c.run_label(), "ce-fedavg+floating:0.5");
        c.controller = ControllerKind::FloatingAggregation { threshold: 1.5 };
        assert!(c.validate().is_err(), "threshold > 1 accepted");
        c.controller = ControllerKind::FloatingAggregation { threshold: 0.5 };
        c.pi = 0;
        c.algorithm = AlgorithmKind::FedAvg;
        assert!(c.validate().is_err(), "pi 0 accepted with floating");
        // Controllers and faults both mutate the world mid-run.
        let mut c = ExperimentConfig::quickstart();
        c.controller = ControllerKind::FloatingAggregation { threshold: 0.5 };
        c.fault = Some(FaultSpec::KillAggregator { at_round: 2 });
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("conflicts"), "{err}");
        // The scenario suffix composes with the controller suffix.
        let mut c = ExperimentConfig::quickstart();
        let mut s = Scenario::from_flat(&c);
        s.name = "churny".into();
        c.scenario = Some(s);
        c.controller = ControllerKind::FloatingAggregation { threshold: 0.25 };
        c.validate().unwrap();
        assert_eq!(c.run_label(), "ce-fedavg@churny+floating:0.25");
    }

    #[test]
    fn json_roundtrip_preserves_controller() {
        let mut c = ExperimentConfig::quickstart();
        // Static stays implicit: no "controller" key in the JSON.
        assert!(c.to_json().opt("controller").is_none());
        c.latency = LatencyMode::EventDriven;
        c.controller = ControllerKind::AdaptiveSemiSync { window: 4 };
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.controller, c.controller);
        c.controller = ControllerKind::FloatingAggregation { threshold: 0.25 };
        let c3 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c3.controller, c.controller);
    }

    #[test]
    fn secagg_parse_roundtrip() {
        for m in [SecaggMode::Off, SecaggMode::Lossless, SecaggMode::Mask(16)] {
            assert_eq!(SecaggMode::parse(&m.name()).unwrap(), m);
        }
        assert_eq!(SecaggMode::parse("none").unwrap(), SecaggMode::Off);
        assert!(SecaggMode::parse("mask:0").is_err(), "0 bits accepted");
        assert!(SecaggMode::parse("mask:47").is_err(), "bits > MAX_BITS accepted");
        assert!(SecaggMode::parse("mask:x").is_err());
        assert!(SecaggMode::parse("mask:").is_err());
        let err = SecaggMode::parse("homomorphic").unwrap_err().to_string();
        assert!(err.contains("off | lossless | mask:<bits"), "{err}");
    }

    #[test]
    fn secagg_sugar_masks_the_resolved_plan() {
        let mut c = ExperimentConfig::quickstart();
        c.secagg = SecaggMode::Mask(16);
        c.validate().unwrap();
        assert_eq!(c.resolved_plan().to_string(), "edge(2)@masked*2; gossip(10)");
        // The edge-phase count (= phase-cursor stride) is unchanged.
        assert_eq!(
            c.resolved_plan().edge_phases(),
            ExperimentConfig::quickstart().resolved_plan().edge_phases()
        );
        // run_label is untouched: the CSV series stays comparable.
        assert_eq!(c.run_label(), "ce-fedavg");
        // Lossless applies the same rewrite.
        c.secagg = SecaggMode::Lossless;
        assert!(c.resolved_plan().comms().masked_uploads > 0);
        // Explicit plans are rewritten too (idempotent on @masked).
        c.plan = Some(Plan::parse("edge(2)@masked; gossip(4)").unwrap());
        c.validate().unwrap();
        assert_eq!(c.resolved_plan().to_string(), "edge(2)@masked; gossip(4)");
    }

    #[test]
    fn secagg_validation_rules() {
        // A masked plan without secagg enabled is rejected...
        let mut c = ExperimentConfig::quickstart();
        c.plan = Some(Plan::parse("edge(2)@masked; gossip(4)").unwrap());
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("secagg"), "{err}");
        // ...and accepted once it is.
        c.secagg = SecaggMode::Mask(16);
        c.validate().unwrap();
        // Secagg with nothing to mask is contradictory (cloud uploads
        // have no masking tier).
        let mut c = ExperimentConfig::quickstart();
        c.algorithm = AlgorithmKind::FedAvg;
        c.secagg = SecaggMode::Mask(16);
        assert!(c.validate().is_err(), "secagg with a pure-cloud plan accepted");
        // Mask mode rejects semi-sync (stale merges arrive after the
        // phase's masks were reconciled) but lossless composes with it.
        let mut c = ExperimentConfig::quickstart();
        c.latency = LatencyMode::EventDriven;
        c.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 1.0 };
        c.secagg = SecaggMode::Mask(16);
        assert!(c.validate().is_err(), "mask mode accepted under semi-sync");
        c.secagg = SecaggMode::Lossless;
        c.validate().unwrap();
        // Mask mode requires the static controller.
        let mut c = ExperimentConfig::quickstart();
        c.secagg = SecaggMode::Mask(16);
        c.controller = ControllerKind::FloatingAggregation { threshold: 0.5 };
        assert!(c.validate().is_err(), "mask mode accepted with a controller");
        // Deadline-drop composes with mask mode (dropouts are recovered
        // by deterministic seed reconstruction).
        let mut c = ExperimentConfig::quickstart();
        c.latency = LatencyMode::EventDriven;
        c.deadline_s = Some(0.5);
        c.secagg = SecaggMode::Mask(16);
        c.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_preserves_secagg() {
        let mut c = ExperimentConfig::quickstart();
        // Off stays implicit: no "secagg" key in the JSON.
        assert!(c.to_json().opt("secagg").is_none());
        c.secagg = SecaggMode::Mask(20);
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.secagg, c.secagg);
        c.secagg = SecaggMode::Lossless;
        let c3 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c3.secagg, c.secagg);
    }

    #[test]
    fn from_json_applies_defaults() {
        let j = Json::parse(r#"{"algorithm": "fedavg", "rounds": 3}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.algorithm, AlgorithmKind::FedAvg);
        assert_eq!(c.rounds, 3);
        assert_eq!(c.n_devices, ExperimentConfig::quickstart().n_devices);
    }
}
