//! Table 1 — multi-server FL algorithm properties, with the
//! fault-tolerance column turned into a *measured* experiment.
//!
//! The paper's Table 1 asserts CE-FedAvg tolerates aggregator faults while
//! hierarchical schemes do not. We measure it: kill an edge server (CE)
//! or the central aggregator (FedAvg / Hier-FAvg) halfway through the run
//! and compare accuracy trajectories before/after the fault. CE-FedAvg
//! keeps improving over the surviving ring; the centralised baselines stop
//! cooperating (consensus drifts, accuracy stalls).

use crate::config::{AlgorithmKind, DataScheme, ExperimentConfig, FaultSpec};
use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::experiments::{write_summary, FigureOpts};
use crate::metrics::{markdown_table, CsvWriter, History, ROUND_HEADER};

struct FaultRun {
    series: String,
    acc_at_fault: f64,
    best_after: f64,
    consensus_after: f64,
    survived: bool,
}

fn run_one(
    cfg: &ExperimentConfig,
    fault_round: usize,
    csv: &mut CsvWriter,
) -> Result<(History, FaultRun)> {
    let mut coord = Coordinator::from_config(cfg)?;
    let h = coord.run()?;
    for rec in &h {
        csv.round_row(&cfg.name, rec)?;
    }
    let acc_at_fault = h[..fault_round]
        .iter()
        .map(|r| r.test_accuracy)
        .filter(|a| !a.is_nan())
        .fold(0.0f64, f64::max);
    let best_after = h[fault_round..]
        .iter()
        .map(|r| r.test_accuracy)
        .filter(|a| !a.is_nan())
        .fold(0.0f64, f64::max);
    let consensus_after = h.last().unwrap().consensus;
    Ok((
        h,
        FaultRun {
            series: cfg.name.clone(),
            acc_at_fault,
            best_after,
            consensus_after,
            // Fault tolerance = the system keeps (at least) its accuracy
            // after losing the aggregator; the centralised baselines drop
            // because their cluster models drift apart once cooperation
            // stops.
            survived: best_after >= acc_at_fault - 0.01,
        },
    ))
}

pub fn run(opts: &FigureOpts) -> Result<String> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut csv = CsvWriter::create(&opts.out_dir.join("table1.csv"), ROUND_HEADER)?;
    let rounds = opts.rounds.max(8);
    let fault_round = rounds / 4;

    let mut base = ExperimentConfig::paper_system(AlgorithmKind::CeFedAvg);
    base.rounds = rounds;
    base.seed = opts.seed;
    base.backend = opts.backend.clone();
    // A skewed cluster split so continued cooperation matters.
    base.data = DataScheme::ClusterNonIid { c_labels: 3 };

    let mut runs = Vec::new();

    // CE-FedAvg: lose edge server 2 (ring stays connected as a line).
    let mut ce = base.clone();
    ce.name = "ce-fedavg+kill-edge".into();
    ce.fault = Some(FaultSpec::KillCluster { at_round: fault_round, cluster: 2 });
    runs.push(run_one(&ce, fault_round, &mut csv)?.1);

    // FedAvg / Hier-FAvg: lose the cloud aggregator.
    for alg in [AlgorithmKind::FedAvg, AlgorithmKind::HierFAvg] {
        let mut c = base.clone();
        c.algorithm = alg;
        c.name = format!("{}+kill-cloud", alg.name());
        c.fault = Some(FaultSpec::KillAggregator { at_round: fault_round });
        runs.push(run_one(&c, fault_round, &mut csv)?.1);
    }

    // Local-Edge: no aggregator to kill; include for the property table.
    let mut le = base.clone();
    le.algorithm = AlgorithmKind::LocalEdge;
    le.name = "local-edge".into();
    runs.push(run_one(&le, fault_round, &mut csv)?.1);

    let measured = markdown_table(
        &["run", "best_acc_pre_fault", "best_acc_post_fault", "final_consensus", "retains accuracy?"],
        &runs
            .iter()
            .map(|r| {
                vec![
                    r.series.clone(),
                    format!("{:.4}", r.acc_at_fault),
                    format!("{:.4}", r.best_after),
                    format!("{:.2e}", r.consensus_after),
                    if r.survived { "yes".into() } else { "no".into() },
                ]
            })
            .collect::<Vec<_>>(),
    );

    let properties = markdown_table(
        &["algorithm", "non-IID", "non-convex", "fault tolerance", "local aggregation benefit"],
        &[
            vec!["Hier-FAvg [19,20]".into(), "yes".into(), "yes".into(), "no (cloud SPOF)".into(), "no".into()],
            vec!["P-FedAvg [21]".into(), "yes".into(), "no (convex)".into(), "yes".into(), "no".into()],
            vec!["MLL-SGD [22]".into(), "no (IID)".into(), "yes".into(), "yes".into(), "no".into()],
            vec!["SE-FEEL [23]".into(), "yes".into(), "yes".into(), "yes".into(), "no".into()],
            vec!["CE-FedAvg (ours)".into(), "yes".into(), "yes".into(), "yes (measured below)".into(), "yes (Remark 1 / Fig. 3)".into()],
        ],
    );

    let summary = format!(
        "Table 1 — algorithm properties in the multi-server FL setting, \
         with fault tolerance measured by killing an aggregator at round \
         {fault_round} of {rounds} (cluster-non-IID(3) split).\n\n\
         ## Property comparison (paper Table 1)\n\n{properties}\n\
         ## Measured fault injection\n\n{measured}\n\
         CE-FedAvg reroutes gossip over the surviving subgraph and keeps \
         improving; FedAvg/Hier-FAvg lose all cooperation when the cloud \
         dies (consensus drifts, accuracy stalls at the fault-time level).\n"
    );
    write_summary(opts, "table1", &summary)?;
    Ok(summary)
}
