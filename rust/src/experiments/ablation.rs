//! Ablation: the two extension knobs the paper's related work motivates —
//! upload compression (§2's quantization/sparsification line) and partial
//! device participation (classic FedAvg sampling). Measures the
//! accuracy / simulated-runtime trade-off each buys on the paper system.

use crate::compression::Compressor;
use crate::config::{AlgorithmKind, ExperimentConfig};
use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::experiments::{write_summary, FigureOpts};
use crate::metrics::{best_accuracy, markdown_table, CsvWriter, ROUND_HEADER};

pub fn run(opts: &FigureOpts) -> Result<String> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut csv = CsvWriter::create(&opts.out_dir.join("ablation.csv"), ROUND_HEADER)?;
    let mut rows = Vec::new();

    let mut base = ExperimentConfig::paper_system(AlgorithmKind::CeFedAvg);
    base.rounds = opts.rounds;
    base.seed = opts.seed;
    base.backend = opts.backend.clone();

    let variants: Vec<(String, Compressor, f64)> = vec![
        ("baseline".into(), Compressor::None, 1.0),
        ("quantize:8".into(), Compressor::Quantize { bits: 8 }, 1.0),
        ("quantize:4".into(), Compressor::Quantize { bits: 4 }, 1.0),
        ("topk:0.25".into(), Compressor::TopK { fraction: 0.25 }, 1.0),
        ("topk:0.05".into(), Compressor::TopK { fraction: 0.05 }, 1.0),
        ("participation:0.5".into(), Compressor::None, 0.5),
        ("participation:0.25".into(), Compressor::None, 0.25),
        ("q8 + part 0.5".into(), Compressor::Quantize { bits: 8 }, 0.5),
    ];
    for (name, comp, part) in variants {
        let mut cfg = base.clone();
        cfg.compression = comp.clone();
        cfg.participation = part;
        cfg.name = format!("ablation-{name}");
        let mut coord = Coordinator::from_config(&cfg)?;
        coord.verbose = opts.verbose;
        let h = coord.run()?;
        for rec in &h {
            csv.round_row(&name, rec)?;
        }
        let last = h.last().unwrap();
        rows.push(vec![
            name,
            format!("{:.2}", comp.ratio() * 32.0),
            format!("{part:.2}"),
            format!("{:.4}", best_accuracy(&h)),
            format!("{:.2}", last.sim_time_s),
            format!("{}", h.iter().map(|r| r.steps).sum::<usize>()),
        ]);
    }

    let summary = format!(
        "Ablation — upload compression + partial participation on CE-FedAvg \
         (paper system, {} rounds).\n\nCompression scales every transmitted \
         model in Eq. 8; participation scales compute and upload count. Both \
         trade a little accuracy for large simulated-runtime savings — and \
         compose (last row).\n\n{}",
        opts.rounds,
        markdown_table(
            &["variant", "bits/value", "participation", "best_acc", "total_sim_s", "total_steps"],
            &rows
        )
    );
    write_summary(opts, "ablation", &summary)?;
    Ok(summary)
}
