//! Runners for Figs. 2–6 (paper §6.2).

use crate::config::{AlgorithmKind, DataScheme, ExperimentConfig};
use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::experiments::{write_summary, FigureOpts};
use crate::metrics::{
    best_accuracy, markdown_table, time_to_accuracy, CsvWriter, History, ROUND_HEADER,
};
use crate::topology::{Graph, MixingMatrix};
use crate::util::rng::Rng;

fn base_config(opts: &FigureOpts) -> ExperimentConfig {
    let mut c = ExperimentConfig::paper_system(AlgorithmKind::CeFedAvg);
    c.rounds = opts.rounds;
    c.seed = opts.seed;
    c.backend = opts.backend.clone();
    c
}

fn run_series(
    cfg: &ExperimentConfig,
    opts: &FigureOpts,
    csv: &mut CsvWriter,
    series: &str,
) -> Result<History> {
    let mut coord = Coordinator::from_config(cfg)?;
    coord.verbose = opts.verbose;
    let history = coord.run()?;
    for rec in &history {
        csv.round_row(series, rec)?;
    }
    Ok(history)
}

/// Accuracy target for the time-to-accuracy tables: 90% of the best
/// accuracy any series in the figure reached (the paper uses a fixed 80%
/// on real datasets; the scaled testbed needs a relative target).
fn relative_target(histories: &[(&str, &History)]) -> f64 {
    let best = histories
        .iter()
        .map(|(_, h)| best_accuracy(h))
        .fold(0.0f64, f64::max);
    best * 0.9
}

fn tta_rows(histories: &[(&str, &History)]) -> (f64, Vec<Vec<String>>) {
    let target = relative_target(histories);
    let rows = histories
        .iter()
        .map(|(name, h)| {
            let best = best_accuracy(h);
            let (round, time) = time_to_accuracy(h, target)
                .map(|(r, t)| (r.to_string(), format!("{t:.1}")))
                .unwrap_or(("-".into(), "-".into()));
            vec![
                name.to_string(),
                format!("{best:.4}"),
                round,
                time,
                format!("{:.1}", h.last().unwrap().sim_time_s),
            ]
        })
        .collect();
    (target, rows)
}

const TTA_HEADERS: [&str; 5] = [
    "series",
    "best_acc",
    "rounds_to_target",
    "sim_time_to_target_s",
    "total_sim_time_s",
];

/// Fig. 2: the four algorithms on the FEMNIST-like (writers) and
/// CIFAR-like (Dirichlet-0.5) workloads, τ=2, q=8, ring backhaul.
pub fn fig2(opts: &FigureOpts) -> Result<String> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut csv = CsvWriter::create(&opts.out_dir.join("fig2.csv"), ROUND_HEADER)?;
    let mut summary = String::from(
        "Fig. 2 — convergence & runtime of CE-FedAvg vs FedAvg / Hier-FAvg / \
         Local-Edge (τ=2, q=8, π=10, ring, 64 devices / 8 clusters).\n\n",
    );
    for (ds_name, scheme) in [
        ("femnist", DataScheme::FemnistWriters { label_alpha: 0.3 }),
        ("cifar", DataScheme::PoolDirichlet { alpha: 0.5 }),
    ] {
        let mut hs: Vec<(String, History)> = Vec::new();
        for alg in AlgorithmKind::all() {
            let mut cfg = base_config(opts);
            cfg.algorithm = alg;
            cfg.data = scheme.clone();
            cfg.name = format!("fig2-{ds_name}-{}", alg.name());
            let series = format!("{ds_name}/{}", alg.name());
            let h = run_series(&cfg, opts, &mut csv, &series)?;
            hs.push((series, h));
        }
        let refs: Vec<(&str, &History)> =
            hs.iter().map(|(n, h)| (n.as_str(), h)).collect();
        let (target, rows) = tta_rows(&refs);
        summary.push_str(&format!("## {ds_name} (target accuracy {target:.3})\n\n"));
        summary.push_str(&markdown_table(&TTA_HEADERS, &rows));
        summary.push('\n');
    }
    write_summary(opts, "fig2", &summary)?;
    Ok(summary)
}

/// Fig. 3: CE-FedAvg under τ ∈ {2,4,8} with fixed qτ = 16.
pub fn fig3(opts: &FigureOpts) -> Result<String> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut csv = CsvWriter::create(&opts.out_dir.join("fig3.csv"), ROUND_HEADER)?;
    let mut hs: Vec<(String, History)> = Vec::new();
    for tau in [2usize, 4, 8] {
        let mut cfg = base_config(opts);
        cfg.tau = tau;
        cfg.q = 16 / tau;
        cfg.name = format!("fig3-tau{tau}");
        let series = format!("tau={tau},q={}", cfg.q);
        let h = run_series(&cfg, opts, &mut csv, &series)?;
        hs.push((series, h));
    }
    let refs: Vec<(&str, &History)> = hs.iter().map(|(n, h)| (n.as_str(), h)).collect();
    let (target, rows) = tta_rows(&refs);
    let mut summary = format!(
        "Fig. 3 — CE-FedAvg: intra-cluster period τ vs fixed inter-cluster \
         period qτ=16 (target accuracy {target:.3}).\n\nSmaller τ ⇒ faster \
         per-round convergence (Remark 1) but more device-edge uploads per \
         global round ⇒ runtime trade-off.\n\n"
    );
    summary.push_str(&markdown_table(&TTA_HEADERS, &rows));
    write_summary(opts, "fig3", &summary)?;
    Ok(summary)
}

/// Fig. 4: cluster count m ∈ {4,6,8,16} at fixed n = 64 (Remark 2).
/// m = 6 does not divide 64 — the sweep covers the uneven-coverage regime
/// (clusters of 11/11/11/11/10/10 devices) the scenario API unlocked.
pub fn fig4(opts: &FigureOpts) -> Result<String> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut csv = CsvWriter::create(&opts.out_dir.join("fig4.csv"), ROUND_HEADER)?;
    let mut hs: Vec<(String, History)> = Vec::new();
    for m in [4usize, 6, 8, 16] {
        let mut cfg = base_config(opts);
        cfg.n_clusters = m;
        cfg.name = format!("fig4-m{m}");
        let series = format!("m={m}");
        let h = run_series(&cfg, opts, &mut csv, &series)?;
        hs.push((series, h));
    }
    let refs: Vec<(&str, &History)> = hs.iter().map(|(n, h)| (n.as_str(), h)).collect();
    let (target, rows) = tta_rows(&refs);
    let mut summary = format!(
        "Fig. 4 — CE-FedAvg under m ∈ {{4,6,8,16}} clusters, n=64 devices \
         (target accuracy {target:.3}; m=6 splits unevenly, 11/11/11/11/10/10). \
         Smaller m ⇒ lower inter-cluster divergence ⇒ faster convergence \
         (Remark 2).\n\n"
    );
    summary.push_str(&markdown_table(&TTA_HEADERS, &rows));
    write_summary(opts, "fig4", &summary)?;
    Ok(summary)
}

/// Fig. 5: cluster-level data distribution (Remark 3): cluster-IID vs
/// cluster-non-IID with C ∈ {2,5,8} labels per cluster.
pub fn fig5(opts: &FigureOpts) -> Result<String> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut csv = CsvWriter::create(&opts.out_dir.join("fig5.csv"), ROUND_HEADER)?;
    let mut hs: Vec<(String, History)> = Vec::new();
    let schemes: Vec<(String, DataScheme)> = std::iter::once(
        ("cluster-iid".to_string(), DataScheme::ClusterIid),
    )
    .chain([2usize, 5, 8].into_iter().map(|c| {
        (
            format!("cluster-noniid-C{c}"),
            DataScheme::ClusterNonIid { c_labels: c },
        )
    }))
    .collect();
    for (name, scheme) in schemes {
        let mut cfg = base_config(opts);
        cfg.data = scheme;
        cfg.name = format!("fig5-{name}");
        let h = run_series(&cfg, opts, &mut csv, &name)?;
        hs.push((name, h));
    }
    let refs: Vec<(&str, &History)> = hs.iter().map(|(n, h)| (n.as_str(), h)).collect();
    let (target, rows) = tta_rows(&refs);
    let mut summary = format!(
        "Fig. 5 — CE-FedAvg under cluster-level distributions (target \
         accuracy {target:.3}). Cluster-IID converges fastest; smaller C \
         (fewer labels per cluster ⇒ larger inter-cluster divergence ε²) \
         slows convergence (Remark 3).\n\n"
    );
    summary.push_str(&markdown_table(&TTA_HEADERS, &rows));
    write_summary(opts, "fig5", &summary)?;
    Ok(summary)
}

/// Fig. 6: backhaul topologies — ring vs Erdős–Rényi p ∈ {0.2,0.4,0.6}
/// at τ=1, q=1, π=1 (pure decentralised regime), with ζ reported.
pub fn fig6(opts: &FigureOpts) -> Result<String> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut csv = CsvWriter::create(&opts.out_dir.join("fig6.csv"), ROUND_HEADER)?;
    let mut rows = Vec::new();
    let mut hs: Vec<(String, History)> = Vec::new();
    for topo in ["ring", "er:0.2", "er:0.4", "er:0.6"] {
        let mut cfg = base_config(opts);
        cfg.topology = topo.to_string();
        cfg.tau = 1;
        cfg.q = 1;
        cfg.pi = 1;
        cfg.name = format!("fig6-{topo}");
        // Report the theory-side spectral quantities next to the curve.
        let g = Graph::by_name(topo, cfg.n_clusters, &Rng::new(cfg.seed ^ 0x706F))?;
        let h_mat = MixingMatrix::metropolis(&g);
        let zeta = h_mat.zeta();
        let series = format!("{topo}(zeta={zeta:.3})");
        let h = run_series(&cfg, opts, &mut csv, &series)?;
        rows.push(vec![
            topo.to_string(),
            format!("{zeta:.4}"),
            format!("{:.2}", h_mat.omega1(1)),
            format!("{:.2}", h_mat.omega2(1)),
            format!("{:.4}", best_accuracy(&h)),
            format!("{:.2e}", h.last().unwrap().consensus),
        ]);
        hs.push((series, h));
    }
    let mut summary = String::from(
        "Fig. 6 — CE-FedAvg under backhaul topologies (τ=q=π=1). Better \
         connectivity ⇒ smaller ζ ⇒ faster convergence (Theorem 1).\n\n",
    );
    summary.push_str(&markdown_table(
        &["topology", "zeta", "omega1", "omega2", "best_acc", "final_consensus"],
        &rows,
    ));
    write_summary(opts, "fig6", &summary)?;
    Ok(summary)
}
