//! Eq. 8 runtime decomposition table (§4.2 / §6.1 constants).
//!
//! For each model in the artifact manifest (falling back to the mock
//! model's analytic numbers when artifacts are absent), print one global
//! round's latency decomposition — compute vs device-edge upload vs
//! backhaul/cloud — for all four algorithms under the paper's default
//! system (64 devices, 8 clusters, τ=2, q=8, π=10). The last column
//! replays the same round through the discrete-event simulator
//! (`netsim::event`), which must agree with the closed form in this
//! homogeneous no-deadline regime — the table doubles as an oracle check.

use crate::aggregation::policy::FullBarrier;
use crate::config::{AggPolicyKind, AlgorithmKind, ExperimentConfig, LatencyMode};
use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::experiments::{write_summary, FigureOpts};
use crate::metrics::{best_accuracy, markdown_table, time_to_accuracy, History};
use crate::netsim::{
    ClosedFormEstimator, EventDrivenEstimator, LatencyEstimator, NetworkModel, RoundTiming,
    StragglerSpec,
};
use crate::plan::{Plan, Step};
use crate::runtime::Manifest;

struct ModelRow {
    name: String,
    flops_per_sample: f64,
    param_count: usize,
    batch: usize,
}

pub fn run(opts: &FigureOpts) -> Result<String> {
    let mut models = Vec::new();
    if let Ok(man) = Manifest::load(&Manifest::default_dir()) {
        for (name, e) in &man.models {
            models.push(ModelRow {
                name: name.clone(),
                flops_per_sample: e.flops_per_sample,
                param_count: e.schema.param_count,
                batch: e.batch_size,
            });
        }
    }
    if models.is_empty() {
        models.push(ModelRow {
            name: "mock-mlp".into(),
            flops_per_sample: 2.0 * (64.0 * 32.0 + 32.0 * 10.0),
            param_count: 64 * 32 + 32 + 32 * 10 + 10,
            batch: 16,
        });
    }
    // Paper-scale reference points for context.
    models.push(ModelRow {
        name: "paper femnist-cnn (6.6M)".into(),
        flops_per_sample: 13.30e6,
        param_count: 6_603_710,
        batch: 50,
    });
    models.push(ModelRow {
        name: "paper vgg-11 (9.75M)".into(),
        flops_per_sample: 920.67e6,
        param_count: 9_750_922,
        batch: 50,
    });

    let (n, m_clusters, q, tau, pi) = (64usize, 8usize, 8usize, 2usize, 10usize);
    // The paper's default system shape, stated once; the canned plan
    // constructors derive every algorithm's schedule from it, and both
    // latency columns are computed from that plan structure (no
    // per-algorithm dispatch left in this table).
    let mut shape = ExperimentConfig::quickstart();
    shape.n_devices = n;
    shape.n_clusters = m_clusters;
    shape.q = q;
    shape.tau = tau;
    shape.pi = pi as u32;
    let mut rows = Vec::new();
    for m in &models {
        let net = NetworkModel::paper_defaults(n, m.flops_per_sample, m.batch, m.param_count);
        // One epoch ≈ 1 batch for the scaled sets; the paper's τ counts
        // steps, so use steps = qτ directly for the reference rows.
        let steps: Vec<(usize, usize)> = (0..n).map(|d| (d, q * tau)).collect();
        for alg in AlgorithmKind::all() {
            let plan = Plan::for_algorithm(alg, &shape);
            let lat = ClosedFormEstimator.round_latency(
                &net,
                &plan,
                &steps,
                &RoundTiming::default(),
            );
            rows.push(vec![
                m.name.clone(),
                alg.name().to_string(),
                format!("{:.3}", lat.compute_s),
                format!("{:.3}", lat.upload_s),
                format!("{:.3}", lat.backhaul_s),
                format!("{:.3}", lat.total()),
                format!("{:.3}", event_total(&net, &plan, n / m_clusters)),
            ]);
        }
    }
    let summary = format!(
        "Eq. 8 — per-global-round latency decomposition (64 devices, 8 \
         clusters, τ=2, q=8, π=10; b_d2e=10 Mbps, b_e2e=50 Mbps, \
         b_d2c=1 Mbps, devices at iPhone-X 691.2 GFLOPS). event_total_s \
         replays the round through the discrete-event simulator.\n\n{}\n\n{}",
        markdown_table(
            &[
                "model",
                "algorithm",
                "compute_s",
                "upload_s",
                "backhaul_s",
                "total_s",
                "event_total_s",
            ],
            &rows
        ),
        policy_comparison(opts)?
    );
    write_summary(opts, "runtime", &summary)?;
    Ok(summary)
}

/// Time-to-target-accuracy of the three edge-round close policies on the
/// *same seed and straggler population*: a CE-FedAvg fleet with U[0.5,1]
/// heterogeneity plus a 10⁴× heavy tail, run under the full barrier (the
/// oracle), the 20 ms deadline-drop, and semi-sync K-of-N with the same
/// 20 ms timeout. The target is 90% of the full barrier's best accuracy,
/// so the table answers the FedBuff question directly: how much virtual
/// time does each policy need to reach the same model quality?
fn policy_comparison(opts: &FigureOpts) -> Result<String> {
    let mut base = ExperimentConfig::quickstart();
    base.name = "policy-comparison".into();
    base.seed = opts.seed;
    base.rounds = opts.rounds.clamp(4, 12);
    base.backend = opts.backend.clone();
    base.latency = LatencyMode::EventDriven;
    base.heterogeneity = Some(0.5);
    base.stragglers = Some(StragglerSpec { fraction: 0.125, slowdown: 1e4 });

    // quickstart: 4 devices per cluster; K=3 lets each cluster close
    // without its slowest device, the 20 ms timeout bounds the wait.
    let policies = [
        AggPolicyKind::FullBarrier,
        AggPolicyKind::DeadlineDrop { deadline_s: 0.02 },
        AggPolicyKind::SemiSync { k: 3, timeout_s: 0.02 },
    ];
    let mut histories: Vec<(String, History)> = Vec::new();
    for p in policies {
        let mut cfg = base.clone();
        cfg.agg_policy = p;
        let mut coord = Coordinator::from_config(&cfg)?;
        coord.verbose = opts.verbose;
        histories.push((p.name(), coord.run()?));
    }

    let target = 0.9 * best_accuracy(&histories[0].1);
    let rows: Vec<Vec<String>> = histories
        .iter()
        .map(|(name, h)| {
            let (round, t) = match time_to_accuracy(h, target) {
                Some((r, t)) => (r.to_string(), format!("{t:.3}")),
                None => ("-".into(), "-".into()),
            };
            let last = h.last().expect("at least one round");
            vec![
                name.clone(),
                format!("{:.4}", best_accuracy(h)),
                round,
                t,
                format!("{:.3}", last.sim_time_s),
                h.iter().map(|r| r.dropped_devices).sum::<usize>().to_string(),
                h.iter().map(|r| r.late_devices).sum::<usize>().to_string(),
                h.iter().map(|r| r.stale_merged).sum::<usize>().to_string(),
            ]
        })
        .collect();
    Ok(format!(
        "Close policies — time to {target:.4} accuracy (90% of the full \
         barrier's best) on one straggler-heavy CE-FedAvg fleet (seed {}, \
         {} rounds, 1/8 of devices 10⁴× slow):\n\n{}",
        base.seed,
        base.rounds,
        markdown_table(
            &[
                "policy",
                "best_acc",
                "round@target",
                "time_to_target_s",
                "total_sim_s",
                "dropped",
                "late",
                "stale_merged",
            ],
            &rows
        )
    ))
}

/// The same global round replayed as discrete events, driven by the plan
/// itself: every edge phase (with repetition) is simulated for one
/// representative cluster — the fleet is homogeneous, so every cluster's
/// trajectory is identical — and every gossip step contributes its π
/// backhaul hops. One epoch ≈ 1 SGD step for these reference rows.
fn event_total(net: &NetworkModel, plan: &Plan, dpc: usize) -> f64 {
    fn walk(net: &NetworkModel, steps: &[Step], dpc: usize, total: &mut f64) {
        for s in steps {
            match s {
                Step::EdgePhase { epochs, channel } => {
                    let work: Vec<(usize, usize)> = (0..dpc).map(|d| (d, *epochs)).collect();
                    *total += EventDrivenEstimator::simulate_phase(
                        net,
                        &work,
                        *channel,
                        &FullBarrier,
                    )
                    .duration_s;
                }
                Step::Gossip { pi } => {
                    *total += EventDrivenEstimator::simulate_gossip(net, *pi as usize).0;
                }
                Step::CloudAggregate => {}
                Step::Repeat { n, body } => {
                    for _ in 0..*n {
                        walk(net, body, dpc, total);
                    }
                }
            }
        }
    }
    let mut total = 0.0;
    walk(net, &plan.steps, dpc, &mut total);
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_rows_for_paper_models() {
        let opts = FigureOpts {
            out_dir: std::env::temp_dir().join(format!("cfel_rt_{}", std::process::id())),
            rounds: 4, // keep the three policy-comparison runs cheap
            ..Default::default()
        };
        let s = run(&opts).unwrap();
        assert!(s.contains("vgg-11"));
        assert!(s.contains("ce-fedavg"));
        assert!(s.contains("event_total_s"));
        // The close-policy comparison rides along on the same summary.
        assert!(s.contains("time_to_target_s"));
        assert!(s.contains("full"));
        assert!(s.contains("deadline:0.02"));
        assert!(s.contains("kofn:3:0.02"));
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }

    #[test]
    fn event_replay_agrees_with_closed_form() {
        // Homogeneous fleet, no deadline: the event column must be the
        // Eq. 8 total (the table's oracle property) — now for the *plan*
        // rather than a per-algorithm dispatch string.
        let net = NetworkModel::paper_defaults(64, 13.30e6, 50, 6_603_710);
        let steps: Vec<(usize, usize)> = (0..64).map(|d| (d, 16)).collect();
        let mut shape = ExperimentConfig::quickstart();
        shape.n_devices = 64;
        shape.n_clusters = 8;
        shape.q = 8;
        shape.tau = 2;
        shape.pi = 10;
        for (alg, want) in [
            (AlgorithmKind::CeFedAvg, net.ce_fedavg_round(&steps, 8, 10).total()),
            (AlgorithmKind::FedAvg, net.fedavg_round(&steps).total()),
            (AlgorithmKind::HierFAvg, net.hier_favg_round(&steps, 8).total()),
            (AlgorithmKind::LocalEdge, net.local_edge_round(&steps, 8).total()),
        ] {
            let plan = Plan::for_algorithm(alg, &shape);
            let got = event_total(&net, &plan, 8);
            assert!(
                (got - want).abs() / want <= 1e-9,
                "{alg:?}: event {got} vs closed {want}"
            );
        }
    }
}
