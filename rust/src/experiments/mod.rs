//! Experiment harnesses — one runner per paper figure/table.
//!
//! Every runner regenerates its figure's data: it builds the exact system
//! configurations of the paper's §6, runs them through the coordinator,
//! and writes `results/<fig>.csv` (per-round series, [`metrics::ROUND_HEADER`]
//! schema) plus `results/<fig>.md` (the headline comparison the paper's
//! text quotes). `cfel figures --fig all` runs everything;
//! `cargo bench` wraps the same runners with timing.
//!
//! The default backend is the mock MLP so a full figure regenerates in
//! seconds; pass `--backend pjrt --model femnist_cnn` to run the real
//! AOT artifacts through PJRT (slower, same orderings — see
//! EXPERIMENTS.md for both sets of numbers).

pub mod ablation;
pub mod figures;
pub mod runtime_table;
pub mod table1;

use std::path::PathBuf;

use crate::config::BackendKind;
use crate::error::{CfelError, Result};

/// Shared options for all figure runners.
#[derive(Debug, Clone)]
pub struct FigureOpts {
    pub out_dir: PathBuf,
    /// Global rounds per run (paper: up to 1500; scaled default).
    pub rounds: usize,
    pub seed: u64,
    pub backend: BackendKind,
    pub verbose: bool,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts {
            out_dir: PathBuf::from("results"),
            rounds: 30,
            seed: 1,
            backend: BackendKind::Mock { hidden: 32 },
            verbose: false,
        }
    }
}

/// All known figure ids.
pub const ALL_FIGURES: &[&str] =
    &["fig2", "fig3", "fig4", "fig5", "fig6", "table1", "runtime", "ablation"];

/// Run one figure (or "all"); returns the markdown summary.
pub fn run_figure(name: &str, opts: &FigureOpts) -> Result<String> {
    match name {
        "fig2" => figures::fig2(opts),
        "fig3" => figures::fig3(opts),
        "fig4" => figures::fig4(opts),
        "fig5" => figures::fig5(opts),
        "fig6" => figures::fig6(opts),
        "table1" => table1::run(opts),
        "runtime" => runtime_table::run(opts),
        "ablation" => ablation::run(opts),
        "all" => {
            let mut out = String::new();
            for f in ALL_FIGURES {
                out.push_str(&format!("\n\n# {f}\n\n"));
                out.push_str(&run_figure(f, opts)?);
            }
            Ok(out)
        }
        _ => Err(CfelError::Config(format!(
            "unknown figure {name:?}; have {ALL_FIGURES:?} or \"all\""
        ))),
    }
}

/// Write a markdown summary next to the CSV.
pub(crate) fn write_summary(opts: &FigureOpts, fig: &str, text: &str) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    std::fs::write(opts.out_dir.join(format!("{fig}.md")), text)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_figure_errors() {
        assert!(run_figure("fig99", &FigureOpts::default()).is_err());
    }

    #[test]
    fn all_figures_listed_are_dispatchable() {
        // Smoke-run the cheapest figure end to end in a tempdir.
        let mut opts = FigureOpts {
            out_dir: std::env::temp_dir().join(format!("cfel_fig_{}", std::process::id())),
            rounds: 2,
            ..Default::default()
        };
        opts.verbose = false;
        let summary = run_figure("fig6", &opts).unwrap();
        assert!(summary.contains("zeta") || summary.contains("ζ"));
        assert!(opts.out_dir.join("fig6.csv").exists());
        assert!(opts.out_dir.join("fig6.md").exists());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
