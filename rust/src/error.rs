//! Crate-wide error type.

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, CfelError>;

/// Errors produced by the CFEL coordinator and its substrates.
#[derive(Debug, thiserror::Error)]
pub enum CfelError {
    /// Invalid experiment / system configuration.
    #[error("config error: {0}")]
    Config(String),

    /// Malformed JSON (manifest, config file, results).
    #[error("json error: {0}")]
    Json(String),

    /// Artifact manifest inconsistent with HLO or with the config.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// Topology construction or validation failure (e.g. disconnected graph).
    #[error("topology error: {0}")]
    Topology(String),

    /// Data generation / partitioning failure.
    #[error("data error: {0}")]
    Data(String),

    /// PJRT runtime failure (compile, execute, literal conversion).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Underlying XLA error.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for CfelError {
    fn from(e: xla::Error) -> Self {
        CfelError::Xla(e.to_string())
    }
}
