//! Crate-wide error type (hand-rolled: the offline build carries no
//! `thiserror`).

use std::fmt;

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, CfelError>;

/// Errors produced by the CFEL coordinator and its substrates.
#[derive(Debug)]
pub enum CfelError {
    /// Invalid experiment / system configuration.
    Config(String),

    /// Malformed JSON (manifest, config file, results).
    Json(String),

    /// Artifact manifest inconsistent with HLO or with the config.
    Manifest(String),

    /// Topology construction or validation failure (e.g. disconnected graph).
    Topology(String),

    /// Data generation / partitioning failure.
    Data(String),

    /// Aggregation over an invalid participant set (e.g. every device of a
    /// cluster was dropped by a fault or a reporting deadline).
    Aggregation(String),

    /// PJRT runtime failure (compile, execute, literal conversion).
    Runtime(String),

    /// Underlying XLA error.
    Xla(String),

    /// Wire-codec failure (bad magic/version, truncated or oversized
    /// frame, payload that does not decode).
    Codec(String),

    /// Distributed-transport failure (connection lost, read timeout,
    /// edge process death). `cluster` names one of the clusters owned
    /// by the failed peer when known.
    Transport {
        cluster: Option<usize>,
        message: String,
    },

    /// I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for CfelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CfelError::Config(m) => write!(f, "config error: {m}"),
            CfelError::Json(m) => write!(f, "json error: {m}"),
            CfelError::Manifest(m) => write!(f, "manifest error: {m}"),
            CfelError::Topology(m) => write!(f, "topology error: {m}"),
            CfelError::Data(m) => write!(f, "data error: {m}"),
            CfelError::Aggregation(m) => write!(f, "aggregation error: {m}"),
            CfelError::Runtime(m) => write!(f, "runtime error: {m}"),
            CfelError::Xla(m) => write!(f, "xla error: {m}"),
            CfelError::Codec(m) => write!(f, "codec error: {m}"),
            CfelError::Transport { cluster, message } => match cluster {
                Some(ci) => write!(f, "transport error (cluster {ci}): {message}"),
                None => write!(f, "transport error: {message}"),
            },
            CfelError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for CfelError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CfelError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CfelError {
    fn from(e: std::io::Error) -> Self {
        CfelError::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for CfelError {
    fn from(e: xla::Error) -> Self {
        CfelError::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_kind() {
        assert!(CfelError::Config("x".into()).to_string().starts_with("config error"));
        assert!(CfelError::Manifest("x".into()).to_string().contains("x"));
    }

    #[test]
    fn io_error_converts_and_chains() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: CfelError = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
