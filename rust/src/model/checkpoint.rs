//! Model checkpointing: save/load a [`ModelState`] (+ metadata) to a
//! compact self-describing binary format (own codec — the vendor set has
//! no serde). Used by `cfel train --save/--load` so long runs can resume
//! and trained models can be handed to downstream evaluation.
//!
//! Layout (all little-endian):
//! ```text
//! magic "CFEL" | u32 version | u32 json_len | json header bytes
//! | params f32×n | momentum f32×n
//! ```
//! The JSON header records `param_count`, the model name and the
//! originating round, and is validated on load.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{CfelError, Result};
use crate::model::ModelState;
use crate::util::json::Json;

const MAGIC: &[u8; 4] = b"CFEL";
const VERSION: u32 = 1;

/// Metadata stored alongside the tensors.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub model: String,
    pub round: usize,
    pub param_count: usize,
}

/// Write `state` + metadata to `path` (atomically via a temp file).
pub fn save(path: &Path, state: &ModelState, model: &str, round: usize) -> Result<()> {
    if state.params.len() != state.momentum.len() {
        return Err(CfelError::Config("params/momentum length mismatch".into()));
    }
    let mut header = Json::obj();
    header
        .set("model", Json::from_str_val(model))
        .set("round", Json::from_usize(round))
        .set("param_count", Json::from_usize(state.params.len()));
    let header_bytes = header.to_string().into_bytes();

    let tmp = path.with_extension("tmp");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(header_bytes.len() as u32).to_le_bytes())?;
        f.write_all(&header_bytes)?;
        write_f32s(&mut f, &state.params)?;
        write_f32s(&mut f, &state.momentum)?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a checkpoint; `expect_params` guards against loading a model of
/// the wrong architecture.
pub fn load(path: &Path, expect_params: Option<usize>) -> Result<(ModelState, CheckpointMeta)> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(CfelError::Config(format!(
            "{}: not a CFEL checkpoint",
            path.display()
        )));
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        return Err(CfelError::Config(format!(
            "unsupported checkpoint version {version}"
        )));
    }
    let header_len = read_u32(&mut f)? as usize;
    if header_len > 1 << 20 {
        return Err(CfelError::Config("implausible checkpoint header".into()));
    }
    let mut header_bytes = vec![0u8; header_len];
    f.read_exact(&mut header_bytes)?;
    let header = Json::parse(
        std::str::from_utf8(&header_bytes)
            .map_err(|_| CfelError::Config("checkpoint header not utf-8".into()))?,
    )?;
    let meta = CheckpointMeta {
        model: header.get("model")?.as_str()?.to_string(),
        round: header.get("round")?.as_usize()?,
        param_count: header.get("param_count")?.as_usize()?,
    };
    if let Some(n) = expect_params {
        if n != meta.param_count {
            return Err(CfelError::Config(format!(
                "checkpoint has {} params, expected {n}",
                meta.param_count
            )));
        }
    }
    let params = read_f32s(&mut f, meta.param_count)?;
    let momentum = read_f32s(&mut f, meta.param_count)?;
    Ok((ModelState { params, momentum }, meta))
}

fn write_f32s(f: &mut impl Write, xs: &[f32]) -> Result<()> {
    // Chunked to avoid a full byte-copy of large models.
    let mut buf = Vec::with_capacity(4 * 4096.min(xs.len()));
    for chunk in xs.chunks(4096) {
        buf.clear();
        for v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    Ok(())
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s(f: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; 4 * n];
    f.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cfel_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let path = tmpfile("rt.ckpt");
        let state = ModelState {
            params: vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE],
            momentum: vec![0.5, 0.0, -1.0, 3.0],
        };
        save(&path, &state, "mlp_synth", 7).unwrap();
        let (loaded, meta) = load(&path, Some(4)).unwrap();
        assert_eq!(loaded.params, state.params);
        assert_eq!(loaded.momentum, state.momentum);
        assert_eq!(meta, CheckpointMeta { model: "mlp_synth".into(), round: 7, param_count: 4 });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_arch_and_garbage() {
        let path = tmpfile("bad.ckpt");
        let state = ModelState::zeros(3);
        save(&path, &state, "m", 0).unwrap();
        assert!(load(&path, Some(5)).is_err());
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path, None).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_errors_cleanly() {
        let path = tmpfile("trunc.ckpt");
        let state = ModelState::zeros(1000);
        save(&path, &state, "m", 1).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(load(&path, None).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn large_model_roundtrip() {
        let path = tmpfile("large.ckpt");
        let n = 150_000;
        let state = ModelState {
            params: (0..n).map(|i| (i as f32).sin()).collect(),
            momentum: (0..n).map(|i| (i as f32).cos()).collect(),
        };
        save(&path, &state, "cifar_cnn", 42).unwrap();
        let (loaded, meta) = load(&path, Some(n)).unwrap();
        assert_eq!(loaded.params, state.params);
        assert_eq!(meta.round, 42);
        std::fs::remove_file(&path).ok();
    }
}
