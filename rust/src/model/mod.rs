//! Model parameter schema + initialisation.
//!
//! Parameters travel through the coordinator as a single flat `Vec<f32>`
//! (concatenation of every tensor in manifest order) — aggregation,
//! gossip and netsim all operate on flat vectors; only the PJRT backend
//! re-slices them into per-tensor literals. Initialisation mirrors the
//! Python reference (`model.init_params`): Glorot-uniform weights, zero
//! biases — the *family* must match, bit-identity is not required because
//! all training flows through the same HLO artifacts afterwards.

pub mod checkpoint;

use crate::error::{CfelError, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Initialisation recipe for one tensor (manifest `init` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKind {
    GlorotUniform,
    Zeros,
}

/// One parameter tensor's schema entry.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    pub init: InitKind,
    pub fan_in: usize,
    pub fan_out: usize,
}

impl ParamSpec {
    pub fn from_json(j: &Json) -> Result<ParamSpec> {
        let shape: Vec<usize> = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?;
        let size = j.get("size")?.as_usize()?;
        let computed: usize = shape.iter().product();
        if computed != size {
            return Err(CfelError::Manifest(format!(
                "param size {size} != product of shape {shape:?}"
            )));
        }
        let init = match j.get("init")?.as_str()? {
            "glorot_uniform" => InitKind::GlorotUniform,
            "zeros" => InitKind::Zeros,
            other => {
                return Err(CfelError::Manifest(format!("unknown init {other:?}")))
            }
        };
        Ok(ParamSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape,
            size,
            init,
            fan_in: j.get("fan_in")?.as_usize()?,
            fan_out: j.get("fan_out")?.as_usize()?,
        })
    }
}

/// Full parameter schema of one model (ordered tensor list).
#[derive(Debug, Clone)]
pub struct ModelSchema {
    pub specs: Vec<ParamSpec>,
    pub param_count: usize,
}

impl ModelSchema {
    pub fn new(specs: Vec<ParamSpec>) -> ModelSchema {
        let param_count = specs.iter().map(|s| s.size).sum();
        ModelSchema { specs, param_count }
    }

    /// (start, end) offsets of each tensor inside the flat vector.
    pub fn offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.specs.len());
        let mut off = 0;
        for s in &self.specs {
            out.push((off, off + s.size));
            off += s.size;
        }
        out
    }

    /// Initialise a flat parameter vector (Glorot weights, zero biases).
    pub fn init_flat(&self, rng: &Rng) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count);
        for (i, spec) in self.specs.iter().enumerate() {
            let mut r = rng.split(i as u64);
            match spec.init {
                InitKind::Zeros => out.resize(out.len() + spec.size, 0.0),
                InitKind::GlorotUniform => {
                    let limit =
                        (6.0 / (spec.fan_in + spec.fan_out) as f32).sqrt();
                    out.extend((0..spec.size).map(|_| r.uniform(-limit, limit)));
                }
            }
        }
        out
    }
}

/// A device/cluster model: flat parameters + flat momentum buffer.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
}

impl ModelState {
    pub fn zeros(n: usize) -> ModelState {
        ModelState { params: vec![0.0; n], momentum: vec![0.0; n] }
    }

    pub fn from_params(params: Vec<f32>) -> ModelState {
        let momentum = vec![0.0; params.len()];
        ModelState { params, momentum }
    }

    /// Reset the momentum buffer (devices start each local round fresh).
    pub fn reset_momentum(&mut self) {
        self.momentum.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema() -> ModelSchema {
        ModelSchema::new(vec![
            ParamSpec {
                name: "w".into(),
                shape: vec![4, 3],
                size: 12,
                init: InitKind::GlorotUniform,
                fan_in: 4,
                fan_out: 3,
            },
            ParamSpec {
                name: "b".into(),
                shape: vec![3],
                size: 3,
                init: InitKind::Zeros,
                fan_in: 0,
                fan_out: 0,
            },
        ])
    }

    #[test]
    fn offsets_and_count() {
        let s = schema();
        assert_eq!(s.param_count, 15);
        assert_eq!(s.offsets(), vec![(0, 12), (12, 15)]);
    }

    #[test]
    fn init_respects_kinds_and_limits() {
        let s = schema();
        let flat = s.init_flat(&Rng::new(1));
        assert_eq!(flat.len(), 15);
        let limit = (6.0f32 / 7.0).sqrt();
        assert!(flat[..12].iter().all(|&v| v.abs() <= limit));
        assert!(flat[..12].iter().any(|&v| v != 0.0));
        assert!(flat[12..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn init_deterministic_per_seed() {
        let s = schema();
        assert_eq!(s.init_flat(&Rng::new(9)), s.init_flat(&Rng::new(9)));
        assert_ne!(s.init_flat(&Rng::new(9)), s.init_flat(&Rng::new(10)));
    }

    #[test]
    fn spec_from_json_roundtrip_and_validation() {
        let j = Json::parse(
            r#"{"name":"w","shape":[2,3],"size":6,"init":"glorot_uniform","fan_in":2,"fan_out":3}"#,
        )
        .unwrap();
        let s = ParamSpec::from_json(&j).unwrap();
        assert_eq!(s.size, 6);
        assert_eq!(s.init, InitKind::GlorotUniform);

        let bad = Json::parse(
            r#"{"name":"w","shape":[2,3],"size":7,"init":"zeros","fan_in":0,"fan_out":0}"#,
        )
        .unwrap();
        assert!(ParamSpec::from_json(&bad).is_err());

        let bad2 = Json::parse(
            r#"{"name":"w","shape":[1],"size":1,"init":"magic","fan_in":0,"fan_out":0}"#,
        )
        .unwrap();
        assert!(ParamSpec::from_json(&bad2).is_err());
    }

    #[test]
    fn model_state_reset() {
        let mut st = ModelState::from_params(vec![1.0, 2.0]);
        st.momentum[0] = 5.0;
        st.reset_momentum();
        assert_eq!(st.momentum, vec![0.0, 0.0]);
        assert_eq!(st.params, vec![1.0, 2.0]);
    }
}
