//! The `Scenario` API — the *world* as a first-class, time-varying object.
//!
//! The flat `ExperimentConfig` knobs describe one static uniform world: a
//! forced-even `n_devices / n_clusters` split, a frozen capability table
//! drawn once from `heterogeneity` / `stragglers`, a topology named by a
//! string. The mobile-edge setting of the paper (§3) is the opposite —
//! coverage is uneven and devices move, appear and disappear. A
//! [`Scenario`] owns that description:
//!
//! * **rosters** — per-cluster device lists, arbitrary and non-uniform;
//!   devices absent from every roster are *dormant* until a
//!   [`WorldEvent::Join`] activates them;
//! * **capability profiles** ([`CapabilityProfiles`]) — per-device compute
//!   capacity and optional per-device uplink bandwidth, either drawn from
//!   the experiment seed exactly like the flat `heterogeneity` /
//!   `stragglers` knobs ([`CapabilityProfiles::Derived`]) or spelled out
//!   per device ([`CapabilityProfiles::Explicit`]);
//! * **links** ([`LinkSpec`]) — overrides for the shared d2e/e2e/d2c
//!   bandwidths;
//! * a round-indexed [`Timeline`] of world events (churn, handover,
//!   capacity and link changes) that the coordinator applies at round
//!   boundaries, re-deriving the Eq. 6 weights and the gossip mixing
//!   matrices when membership changes.
//!
//! Every flat config *lowers* into a static scenario
//! ([`Scenario::from_flat`]) that reproduces it bit for bit — the flat
//! knobs are sugar, pinned by `rust/tests/scenario_equivalence.rs`.
//! Scenarios round-trip through JSON (`--scenario <file.json>`, like
//! `--plan`); see `examples/scenarios/` for shipped files and the README
//! for the schema.
//!
//! Determinism: capability application mutates the network model in
//! place (`NetworkModel::apply_heterogeneity` /
//! `NetworkModel::apply_stragglers`) on the same derived RNG streams the
//! pre-scenario coordinator consumed, and timeline generation is a pure
//! function of `(rosters, ChurnSpec)` — independent of the experiment
//! seed. The full stream-derivation table lives in
//! `docs/DETERMINISM.md`.

pub mod timeline;

pub use timeline::{ChurnSpec, LinkKind, Timeline, TimelineEvent, WorldEvent};

use std::path::Path;

use crate::config::ExperimentConfig;
use crate::error::{CfelError, Result};
use crate::netsim::{NetworkModel, StragglerSpec};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One device's explicit capability profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    /// Compute capacity c_k in FLOP/s (Eq. 8's denominator).
    pub flops: f64,
    /// Optional device→edge uplink override in bits/s (None = the shared
    /// `b_d2e`). Only the event-driven latency mode simulates uploads per
    /// device, so `ExperimentConfig::validate` rejects overrides under the
    /// closed-form Eq. 8 (which could only charge the shared channel).
    pub uplink_bps: Option<f64>,
}

/// Where the per-device capability table comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum CapabilityProfiles {
    /// Draw from the experiment seed exactly like the flat knobs: every
    /// device at the paper's iPhone-X capacity, optionally rescaled by
    /// `c_k ~ U[lo,1]` heterogeneity and a heavy-tail straggler subset.
    /// This is what flat configs lower to — the same RNG streams, so the
    /// lowering is bit-identical.
    Derived {
        heterogeneity: Option<f64>,
        stragglers: Option<StragglerSpec>,
    },
    /// One explicit [`DeviceProfile`] per device (length = `n_devices`).
    Explicit(Vec<DeviceProfile>),
}

impl CapabilityProfiles {
    /// The paper's homogeneous fleet.
    pub fn uniform() -> CapabilityProfiles {
        CapabilityProfiles::Derived { heterogeneity: None, stragglers: None }
    }

    /// Write this profile set into the network model. `rng` is the
    /// coordinator's root stream; the derived path splits it exactly as
    /// the pre-scenario coordinator did (0x4E37 / 0x5746).
    pub fn apply(&self, net: &mut NetworkModel, rng: &Rng) -> Result<()> {
        match self {
            CapabilityProfiles::Derived { heterogeneity, stragglers } => {
                if let Some(lo) = heterogeneity {
                    net.apply_heterogeneity(*lo, &rng.split(0x4E37));
                }
                if let Some(spec) = stragglers {
                    net.apply_stragglers(*spec, &rng.split(0x5746));
                }
                Ok(())
            }
            CapabilityProfiles::Explicit(profiles) => {
                if profiles.len() != net.device_flops.len() {
                    return Err(CfelError::Config(format!(
                        "{} capability profiles for {} devices",
                        profiles.len(),
                        net.device_flops.len()
                    )));
                }
                for (k, p) in profiles.iter().enumerate() {
                    net.device_flops[k] = p.flops;
                    net.device_uplink[k] = p.uplink_bps;
                }
                Ok(())
            }
        }
    }

    pub fn validate(&self, n_devices: usize) -> Result<()> {
        match self {
            CapabilityProfiles::Derived { heterogeneity, stragglers } => {
                if let Some(lo) = heterogeneity {
                    if !(0.0 < *lo && *lo <= 1.0) {
                        return Err(CfelError::Config(format!(
                            "scenario heterogeneity {lo} outside (0,1]"
                        )));
                    }
                }
                if let Some(spec) = stragglers {
                    spec.validate()?;
                }
                Ok(())
            }
            CapabilityProfiles::Explicit(profiles) => {
                if profiles.len() != n_devices {
                    return Err(CfelError::Config(format!(
                        "scenario lists {} capability profiles for {n_devices} devices",
                        profiles.len()
                    )));
                }
                for (k, p) in profiles.iter().enumerate() {
                    if !(p.flops > 0.0 && p.flops.is_finite()) {
                        return Err(CfelError::Config(format!(
                            "device {k} capability {} FLOP/s must be positive and finite",
                            p.flops
                        )));
                    }
                    if let Some(u) = p.uplink_bps {
                        if !(u > 0.0 && u.is_finite()) {
                            return Err(CfelError::Config(format!(
                                "device {k} uplink {u} bit/s must be positive and finite"
                            )));
                        }
                    }
                }
                Ok(())
            }
        }
    }
}

/// Optional shared-link bandwidth overrides (paper §6.1 defaults apply
/// where `None`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LinkSpec {
    /// Device → edge uplink, bits/s (default 10 Mbps).
    pub b_d2e: Option<f64>,
    /// Edge ↔ edge backhaul, bits/s (default 50 Mbps).
    pub b_e2e: Option<f64>,
    /// Device → cloud uplink, bits/s (default 1 Mbps).
    pub b_d2c: Option<f64>,
}

impl LinkSpec {
    pub fn apply(&self, net: &mut NetworkModel) {
        if let Some(b) = self.b_d2e {
            net.b_d2e = b;
        }
        if let Some(b) = self.b_e2e {
            net.b_e2e = b;
        }
        if let Some(b) = self.b_d2c {
            net.b_d2c = b;
        }
    }

    pub fn validate(&self) -> Result<()> {
        for (name, b) in [
            ("b_d2e", self.b_d2e),
            ("b_e2e", self.b_e2e),
            ("b_d2c", self.b_d2c),
        ] {
            if let Some(b) = b {
                if !(b > 0.0 && b.is_finite()) {
                    return Err(CfelError::Config(format!(
                        "scenario link {name} = {b} bit/s must be positive and finite"
                    )));
                }
            }
        }
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.b_d2e.is_none() && self.b_e2e.is_none() && self.b_d2c.is_none()
    }
}

/// The full world description one experiment runs in.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Name, included in [`ExperimentConfig::run_label`] so CSV rows from
    /// scenario runs stay distinguishable from canned-config runs.
    pub name: String,
    /// Total device universe (data and capability tables are sized to
    /// it). Devices `0..n_devices` outside every roster start dormant.
    pub n_devices: usize,
    /// Per-cluster device id lists, each sorted strictly ascending (the
    /// canonical order — Eq. 6 merges follow roster order).
    pub rosters: Vec<Vec<usize>>,
    pub capabilities: CapabilityProfiles,
    /// Backhaul topology spec: "ring" | "complete" | "star" | "line" |
    /// "er:<p>" (built against the experiment seed, like the flat knob).
    pub topology: String,
    pub links: Option<LinkSpec>,
    pub timeline: Timeline,
}

impl Scenario {
    /// Contiguous rosters of the given sizes: cluster i owns the next
    /// `sizes[i]` device ids (the paper's §5.2 layout, generalized to
    /// uneven sizes).
    pub fn contiguous_rosters(sizes: &[usize]) -> Vec<Vec<usize>> {
        let mut rosters = Vec::with_capacity(sizes.len());
        let mut next = 0usize;
        for &s in sizes {
            rosters.push((next..next + s).collect());
            next += s;
        }
        rosters
    }

    /// Lower a flat config into the static scenario it has always meant:
    /// contiguous rosters from [`ExperimentConfig::cluster_sizes`], the
    /// derived capability profile its `heterogeneity` / `stragglers`
    /// knobs name, its topology, paper-default links, an empty timeline.
    /// `rust/tests/scenario_equivalence.rs` pins this lowering
    /// bit-identical to the flat run.
    pub fn from_flat(cfg: &ExperimentConfig) -> Scenario {
        Scenario {
            name: format!("static-{}", cfg.name),
            n_devices: cfg.n_devices,
            rosters: Self::contiguous_rosters(&cfg.cluster_sizes()),
            capabilities: CapabilityProfiles::Derived {
                heterogeneity: cfg.heterogeneity,
                stragglers: cfg.stragglers,
            },
            topology: cfg.topology.clone(),
            links: None,
            timeline: Timeline::default(),
        }
    }

    /// Devices outside every initial roster (activatable by `Join`).
    pub fn dormant_count(&self) -> usize {
        let rostered: usize = self.rosters.iter().map(|r| r.len()).sum();
        self.n_devices - rostered
    }

    pub fn n_clusters(&self) -> usize {
        self.rosters.len()
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_devices == 0 || self.rosters.is_empty() {
            return Err(CfelError::Config(
                "scenario needs at least 1 device and 1 cluster".into(),
            ));
        }
        let mut seen = vec![false; self.n_devices];
        let mut rostered = 0usize;
        for (ci, roster) in self.rosters.iter().enumerate() {
            let mut prev: Option<usize> = None;
            for &d in roster {
                if d >= self.n_devices {
                    return Err(CfelError::Config(format!(
                        "cluster {ci} roster names device {d} >= n_devices {}",
                        self.n_devices
                    )));
                }
                if seen[d] {
                    return Err(CfelError::Config(format!(
                        "device {d} appears in two rosters"
                    )));
                }
                seen[d] = true;
                rostered += 1;
                if let Some(p) = prev {
                    if d <= p {
                        return Err(CfelError::Config(format!(
                            "cluster {ci} roster is not sorted strictly ascending \
                             (the canonical Eq. 6 merge order)"
                        )));
                    }
                }
                prev = Some(d);
            }
        }
        if rostered == 0 {
            return Err(CfelError::Config(
                "scenario rosters no devices at round 0 (nothing would train)".into(),
            ));
        }
        self.capabilities.validate(self.n_devices)?;
        if let Some(l) = &self.links {
            l.validate()?;
        }
        self.timeline.validate(self.n_devices, &self.rosters)?;
        Ok(())
    }

    // ----- JSON persistence --------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::from_str_val(&self.name))
            .set("n_devices", Json::from_usize(self.n_devices))
            .set("topology", Json::from_str_val(&self.topology))
            .set(
                "rosters",
                Json::Arr(
                    self.rosters
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|&d| Json::from_usize(d)).collect()))
                        .collect(),
                ),
            );
        match &self.capabilities {
            CapabilityProfiles::Derived { heterogeneity, stragglers } => {
                let mut c = Json::obj();
                c.set("kind", Json::from_str_val("derived"));
                if let Some(h) = heterogeneity {
                    c.set("heterogeneity", Json::from_f64(*h));
                }
                if let Some(s) = stragglers {
                    c.set("stragglers", Json::from_str_val(&s.name()));
                }
                o.set("capabilities", c);
            }
            CapabilityProfiles::Explicit(profiles) => {
                let mut c = Json::obj();
                c.set("kind", Json::from_str_val("explicit")).set(
                    "profiles",
                    Json::Arr(
                        profiles
                            .iter()
                            .map(|p| {
                                let mut e = Json::obj();
                                e.set("flops", Json::from_f64(p.flops));
                                if let Some(u) = p.uplink_bps {
                                    e.set("uplink_bps", Json::from_f64(u));
                                }
                                e
                            })
                            .collect(),
                    ),
                );
                o.set("capabilities", c);
            }
        }
        if let Some(l) = &self.links {
            if !l.is_empty() {
                let mut lj = Json::obj();
                if let Some(b) = l.b_d2e {
                    lj.set("b_d2e", Json::from_f64(b));
                }
                if let Some(b) = l.b_e2e {
                    lj.set("b_e2e", Json::from_f64(b));
                }
                if let Some(b) = l.b_d2c {
                    lj.set("b_d2c", Json::from_f64(b));
                }
                o.set("links", lj);
            }
        }
        if !self.timeline.is_empty() {
            o.set("timeline", self.timeline.to_json());
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<Scenario> {
        let mut rosters = Vec::new();
        for r in j.get("rosters")?.as_arr()? {
            let mut ids = Vec::new();
            for d in r.as_arr()? {
                ids.push(d.as_usize()?);
            }
            rosters.push(ids);
        }
        let n_devices = match j.opt("n_devices") {
            Some(v) => v.as_usize()?,
            // Default: the smallest universe covering every rostered id.
            None => rosters.iter().flatten().max().map_or(0, |&m| m + 1),
        };
        let capabilities = match j.opt("capabilities") {
            None => CapabilityProfiles::uniform(),
            Some(c) => match c.get("kind")?.as_str()? {
                "derived" => CapabilityProfiles::Derived {
                    heterogeneity: c.opt("heterogeneity").map(|v| v.as_f64()).transpose()?,
                    stragglers: c
                        .opt("stragglers")
                        .map(|v| v.as_str().and_then(StragglerSpec::parse))
                        .transpose()?,
                },
                "explicit" => {
                    let mut profiles = Vec::new();
                    for p in c.get("profiles")?.as_arr()? {
                        profiles.push(DeviceProfile {
                            flops: p.get("flops")?.as_f64()?,
                            uplink_bps: p.opt("uplink_bps").map(|v| v.as_f64()).transpose()?,
                        });
                    }
                    CapabilityProfiles::Explicit(profiles)
                }
                other => {
                    return Err(CfelError::Config(format!(
                        "unknown capabilities kind {other:?} (derived | explicit)"
                    )))
                }
            },
        };
        let links = match j.opt("links") {
            None => None,
            Some(l) => Some(LinkSpec {
                b_d2e: l.opt("b_d2e").map(|v| v.as_f64()).transpose()?,
                b_e2e: l.opt("b_e2e").map(|v| v.as_f64()).transpose()?,
                b_d2c: l.opt("b_d2c").map(|v| v.as_f64()).transpose()?,
            }),
        };
        let timeline = match j.opt("timeline") {
            Some(t) => Timeline::from_json(t, &rosters)?,
            None => Timeline::default(),
        };
        let scenario = Scenario {
            name: j
                .opt("name")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "scenario".into()),
            n_devices,
            rosters,
            capabilities,
            topology: j
                .opt("topology")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "ring".into()),
            links,
            timeline,
        };
        scenario.validate()?;
        Ok(scenario)
    }

    /// Load and validate a scenario JSON file (the `--scenario` path).
    pub fn load(path: &Path) -> Result<Scenario> {
        Scenario::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_flat_matches_the_legacy_contiguous_layout() {
        let cfg = ExperimentConfig::quickstart(); // 16 devices / 4 clusters
        let s = Scenario::from_flat(&cfg);
        assert_eq!(s.n_devices, 16);
        assert_eq!(s.rosters.len(), 4);
        for (ci, roster) in s.rosters.iter().enumerate() {
            let want: Vec<usize> = (ci * 4..(ci + 1) * 4).collect();
            assert_eq!(roster, &want);
        }
        assert_eq!(s.topology, "ring");
        assert_eq!(s.dormant_count(), 0);
        assert!(s.timeline.is_empty());
        s.validate().unwrap();
    }

    #[test]
    fn from_flat_distributes_the_remainder_to_the_first_clusters() {
        let mut cfg = ExperimentConfig::quickstart();
        cfg.n_devices = 18; // 18 / 4 = 4 rem 2
        let s = Scenario::from_flat(&cfg);
        let sizes: Vec<usize> = s.rosters.iter().map(|r| r.len()).collect();
        assert_eq!(sizes, vec![5, 5, 4, 4]);
        assert_eq!(s.rosters[0], vec![0, 1, 2, 3, 4]);
        assert_eq!(s.rosters[3], vec![14, 15, 16, 17]);
        s.validate().unwrap();
    }

    #[test]
    fn derived_apply_matches_the_flat_knob_draws() {
        // The lowering contract: Derived::apply must reproduce the exact
        // RNG streams the pre-scenario coordinator used.
        let rng = Rng::new(11);
        let spec = StragglerSpec { fraction: 0.25, slowdown: 50.0 };
        let direct = NetworkModel::paper_defaults(8, 1e6, 16, 1000)
            .with_heterogeneity(0.5, &rng.split(0x4E37))
            .with_stragglers(spec, &rng.split(0x5746));
        let mut via = NetworkModel::paper_defaults(8, 1e6, 16, 1000);
        CapabilityProfiles::Derived { heterogeneity: Some(0.5), stragglers: Some(spec) }
            .apply(&mut via, &rng)
            .unwrap();
        assert_eq!(direct.device_flops, via.device_flops);
    }

    #[test]
    fn explicit_profiles_write_flops_and_uplinks() {
        let mut net = NetworkModel::paper_defaults(2, 1e6, 16, 1000);
        let profiles = vec![
            DeviceProfile { flops: 1e9, uplink_bps: Some(5e6) },
            DeviceProfile { flops: 2e9, uplink_bps: None },
        ];
        CapabilityProfiles::Explicit(profiles.clone())
            .apply(&mut net, &Rng::new(0))
            .unwrap();
        assert_eq!(net.device_flops, vec![1e9, 2e9]);
        assert_eq!(net.device_uplink, vec![Some(5e6), None]);
        // Wrong length rejected by both apply and validate.
        let short = CapabilityProfiles::Explicit(profiles[..1].to_vec());
        assert!(short.apply(&mut net, &Rng::new(0)).is_err());
        assert!(short.validate(2).is_err());
    }

    #[test]
    fn link_spec_applies_only_what_it_names() {
        let mut net = NetworkModel::paper_defaults(2, 1e6, 16, 1000);
        let d2e = net.b_d2e;
        LinkSpec { b_d2e: None, b_e2e: Some(2.5e7), b_d2c: None }.apply(&mut net);
        assert_eq!(net.b_d2e, d2e);
        assert_eq!(net.b_e2e, 2.5e7);
        assert!(LinkSpec { b_e2e: Some(-1.0), ..LinkSpec::default() }.validate().is_err());
        assert!(LinkSpec::default().is_empty());
    }

    #[test]
    fn validate_rejects_malformed_rosters() {
        let mut s = Scenario::from_flat(&ExperimentConfig::quickstart());
        s.rosters[0] = vec![0, 0, 1, 2]; // duplicate within a roster
        assert!(s.validate().is_err());
        let mut s = Scenario::from_flat(&ExperimentConfig::quickstart());
        s.rosters[0] = vec![1, 0, 2, 3]; // unsorted
        assert!(s.validate().is_err());
        let mut s = Scenario::from_flat(&ExperimentConfig::quickstart());
        s.rosters[1][0] = 0; // device 0 in two rosters
        assert!(s.validate().is_err());
        let mut s = Scenario::from_flat(&ExperimentConfig::quickstart());
        s.rosters[0] = vec![0, 1, 2, 99]; // out of range
        assert!(s.validate().is_err());
        let mut s = Scenario::from_flat(&ExperimentConfig::quickstart());
        for r in &mut s.rosters {
            r.clear(); // nobody rostered
        }
        assert!(s.validate().is_err());
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut s = Scenario::from_flat(&ExperimentConfig::quickstart());
        s.name = "roundtrip".into();
        s.rosters = vec![vec![0, 1, 2, 3, 4, 5], vec![6, 7, 8], vec![9, 10], vec![11]];
        s.n_devices = 14; // devices 12, 13 dormant
        s.capabilities = CapabilityProfiles::Derived {
            heterogeneity: Some(0.5),
            stragglers: Some(StragglerSpec { fraction: 0.25, slowdown: 100.0 }),
        };
        s.links = Some(LinkSpec { b_d2e: None, b_e2e: Some(2.5e7), b_d2c: None });
        s.timeline = Timeline {
            events: vec![
                TimelineEvent { round: 2, event: WorldEvent::Join { device: 12, cluster: 3 } },
                TimelineEvent {
                    round: 3,
                    event: WorldEvent::Handover { device: 0, from: 0, to: 1 },
                },
            ],
        };
        s.validate().unwrap();
        let back = Scenario::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Explicit profiles survive the round trip too.
        s.capabilities = CapabilityProfiles::Explicit(
            (0..14)
                .map(|k| DeviceProfile {
                    flops: 1e9 + k as f64,
                    uplink_bps: if k % 2 == 0 { Some(5e6) } else { None },
                })
                .collect(),
        );
        let back = Scenario::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn from_json_defaults_and_churn_expansion() {
        let j = Json::parse(
            r#"{
                "rosters": [[0, 1, 2], [3, 4, 5]],
                "timeline": {"churn": {"p_leave": 0.5, "p_join": 0.5, "rounds": 6, "seed": 3}}
            }"#,
        )
        .unwrap();
        let s = Scenario::from_json(&j).unwrap();
        assert_eq!(s.n_devices, 6, "n_devices inferred from rosters");
        assert_eq!(s.topology, "ring");
        assert_eq!(s.capabilities, CapabilityProfiles::uniform());
        let want = Timeline::markov_churn(
            &s.rosters,
            &ChurnSpec { p_leave: 0.5, p_join: 0.5, rounds: 6, seed: 3 },
        )
        .unwrap();
        assert_eq!(s.timeline, want);
    }
}
