//! Round-indexed world-event timeline — the time-varying half of a
//! [`Scenario`](crate::scenario::Scenario).
//!
//! A [`Timeline`] is a list of [`TimelineEvent`]s, each naming the global
//! round at whose *start* it fires. The coordinator applies the events of
//! round `r` at the round boundary (single-threaded, before any training),
//! so world changes are deterministic for any `CFEL_THREADS`:
//!
//! * [`WorldEvent::Join`] / [`WorldEvent::Leave`] — a device appears in /
//!   disappears from a cluster's roster (coverage churn);
//! * [`WorldEvent::Handover`] — a moving device switches edge servers;
//! * [`WorldEvent::CapacityChange`] — a device's compute capacity c_k is
//!   rescaled (thermal throttling, background load, recovery);
//! * [`WorldEvent::LinkChange`] — one of the shared link bandwidths is
//!   retuned mid-run (congestion, a backhaul upgrade).
//!
//! [`Timeline::markov_churn`] is the canned timeline source: each rostered
//! device flips between on and off with per-round probabilities
//! `p_leave` / `p_join` (a two-state Markov chain, the availability model
//! of the floating-aggregation-point setting, arXiv:2203.13950), never
//! emptying a cluster. Timelines serialize to JSON either as an explicit
//! event array or as a `{"churn": {..}}` generator spec.

use crate::error::{CfelError, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Which shared link a [`WorldEvent::LinkChange`] retunes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// Device → edge uplink (`b_d2e`, paper default 10 Mbps).
    DeviceEdge,
    /// Edge ↔ edge backhaul (`b_e2e`, paper default 50 Mbps).
    EdgeEdge,
    /// Device → cloud uplink (`b_d2c`, paper default 1 Mbps).
    DeviceCloud,
}

impl LinkKind {
    pub fn parse(s: &str) -> Result<LinkKind> {
        match s {
            "d2e" => Ok(LinkKind::DeviceEdge),
            "e2e" => Ok(LinkKind::EdgeEdge),
            "d2c" => Ok(LinkKind::DeviceCloud),
            _ => Err(CfelError::Config(format!(
                "unknown link kind {s:?} (d2e | e2e | d2c)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LinkKind::DeviceEdge => "d2e",
            LinkKind::EdgeEdge => "e2e",
            LinkKind::DeviceCloud => "d2c",
        }
    }
}

/// One world change, applied at a round boundary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorldEvent {
    /// A dormant device becomes active in `cluster`'s roster.
    Join { device: usize, cluster: usize },
    /// An active device drops out of its cluster's roster.
    Leave { device: usize },
    /// An active device moves from edge server `from` to `to`.
    Handover { device: usize, from: usize, to: usize },
    /// Device compute capacity c_k is multiplied by `factor` (< 1 slows
    /// the device down, > 1 speeds it up; composes across events).
    CapacityChange { device: usize, factor: f64 },
    /// The named shared link's bandwidth becomes `bps` bits/s.
    LinkChange { link: LinkKind, bps: f64 },
}

impl WorldEvent {
    pub fn kind_name(&self) -> &'static str {
        match self {
            WorldEvent::Join { .. } => "join",
            WorldEvent::Leave { .. } => "leave",
            WorldEvent::Handover { .. } => "handover",
            WorldEvent::CapacityChange { .. } => "capacity-change",
            WorldEvent::LinkChange { .. } => "link-change",
        }
    }

    /// Human-readable one-liner for verbose logs and dry runs.
    pub fn describe(&self) -> String {
        match *self {
            WorldEvent::Join { device, cluster } => {
                format!("device {device} joins cluster {cluster}")
            }
            WorldEvent::Leave { device } => format!("device {device} leaves"),
            WorldEvent::Handover { device, from, to } => {
                format!("device {device} hands over from cluster {from} to {to}")
            }
            WorldEvent::CapacityChange { device, factor } => {
                format!("device {device} capacity x{factor}")
            }
            WorldEvent::LinkChange { link, bps } => {
                format!("link {} -> {bps} bit/s", link.name())
            }
        }
    }
}

/// A [`WorldEvent`] pinned to the global round at whose start it fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelineEvent {
    pub round: usize,
    pub event: WorldEvent,
}

/// The ordered world-event schedule of a scenario. Events of the same
/// round apply in list order; rounds past the run's horizon simply never
/// fire.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Timeline {
    pub events: Vec<TimelineEvent>,
}

/// Two-state Markov on/off availability model: per round, an active
/// device leaves with probability `p_leave` and an offline device
/// returns (to its home cluster) with probability `p_join`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnSpec {
    /// Per-round P(active → offline), in [0, 1].
    pub p_leave: f64,
    /// Per-round P(offline → active), in [0, 1].
    pub p_join: f64,
    /// Rounds to generate events for (events fire in rounds 1..rounds;
    /// round 0 is the initial roster state).
    pub rounds: usize,
    /// Generator seed — the timeline is a pure function of (rosters,
    /// spec), independent of the experiment seed.
    pub seed: u64,
}

impl ChurnSpec {
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [("p_leave", self.p_leave), ("p_join", self.p_join)] {
            if !(0.0..=1.0).contains(&p) || p.is_nan() {
                return Err(CfelError::Config(format!(
                    "churn {name} {p} outside [0,1]"
                )));
            }
        }
        if self.rounds == 0 {
            return Err(CfelError::Config("churn rounds must be >= 1".into()));
        }
        Ok(())
    }
}

impl Timeline {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events firing at the start of `round`, in timeline order.
    pub fn at(&self, round: usize) -> Vec<TimelineEvent> {
        self.events
            .iter()
            .filter(|e| e.round == round)
            .copied()
            .collect()
    }

    /// Generate a Markov on/off churn timeline over `rosters` (each
    /// device's home cluster is where it starts). A leave that would
    /// empty its cluster is skipped, so every cluster always keeps at
    /// least one active device. Deterministic: each (round, device) pair
    /// draws from its own split of `spec.seed`.
    pub fn markov_churn(rosters: &[Vec<usize>], spec: &ChurnSpec) -> Result<Timeline> {
        spec.validate()?;
        let rng = Rng::new(spec.seed);
        let mut active: Vec<Vec<bool>> = rosters.iter().map(|r| vec![true; r.len()]).collect();
        let mut counts: Vec<usize> = rosters.iter().map(|r| r.len()).collect();
        let mut events = Vec::new();
        for round in 1..spec.rounds {
            for (ci, roster) in rosters.iter().enumerate() {
                for (slot, &dev) in roster.iter().enumerate() {
                    let mut r = rng.split(round as u64).split(dev as u64);
                    if active[ci][slot] {
                        if counts[ci] > 1 && r.f64() < spec.p_leave {
                            active[ci][slot] = false;
                            counts[ci] -= 1;
                            events.push(TimelineEvent {
                                round,
                                event: WorldEvent::Leave { device: dev },
                            });
                        }
                    } else if r.f64() < spec.p_join {
                        active[ci][slot] = true;
                        counts[ci] += 1;
                        events.push(TimelineEvent {
                            round,
                            event: WorldEvent::Join { device: dev, cluster: ci },
                        });
                    }
                }
            }
        }
        Ok(Timeline { events })
    }

    /// Structural + semantic validation against the scenario's shape:
    /// every id in range, factors/bandwidths positive, and — replaying
    /// membership events in firing order from the initial rosters — no
    /// join of an active device, no leave/handover of an inactive one,
    /// and no handover from the wrong cluster. This is what `--dry-run`
    /// checks, so a broken timeline fails before anything trains.
    pub fn validate(&self, n_devices: usize, rosters: &[Vec<usize>]) -> Result<()> {
        let n_clusters = rosters.len();
        let mut cluster_of: Vec<Option<usize>> = vec![None; n_devices];
        for (ci, roster) in rosters.iter().enumerate() {
            for &d in roster {
                if d < n_devices {
                    cluster_of[d] = Some(ci);
                }
            }
        }
        // Stable sort by round reproduces the coordinator's firing order
        // (per-round batches in list order).
        let mut order: Vec<&TimelineEvent> = self.events.iter().collect();
        order.sort_by_key(|e| e.round);
        for ev in order {
            let bad = |msg: String| {
                CfelError::Config(format!("timeline round {}: {msg}", ev.round))
            };
            let check_device = |d: usize| {
                if d >= n_devices {
                    Err(bad(format!("device {d} out of range (n_devices {n_devices})")))
                } else {
                    Ok(())
                }
            };
            let check_cluster = |c: usize| {
                if c >= n_clusters {
                    Err(bad(format!("cluster {c} out of range (m {n_clusters})")))
                } else {
                    Ok(())
                }
            };
            match ev.event {
                WorldEvent::Join { device, cluster } => {
                    check_device(device)?;
                    check_cluster(cluster)?;
                    if cluster_of[device].is_some() {
                        return Err(bad(format!("join of already-active device {device}")));
                    }
                    cluster_of[device] = Some(cluster);
                }
                WorldEvent::Leave { device } => {
                    check_device(device)?;
                    if cluster_of[device].is_none() {
                        return Err(bad(format!("leave of inactive device {device}")));
                    }
                    cluster_of[device] = None;
                }
                WorldEvent::Handover { device, from, to } => {
                    check_device(device)?;
                    check_cluster(from)?;
                    check_cluster(to)?;
                    if from == to {
                        return Err(bad(format!("handover of device {device} to itself")));
                    }
                    if cluster_of[device] != Some(from) {
                        return Err(bad(format!(
                            "handover of device {device} from cluster {from}, but it is {}",
                            match cluster_of[device] {
                                Some(c) => format!("in cluster {c}"),
                                None => "inactive".into(),
                            }
                        )));
                    }
                    cluster_of[device] = Some(to);
                }
                WorldEvent::CapacityChange { device, factor } => {
                    check_device(device)?;
                    if !(factor > 0.0 && factor.is_finite()) {
                        return Err(bad(format!(
                            "capacity factor {factor} must be positive and finite"
                        )));
                    }
                }
                WorldEvent::LinkChange { bps, .. } => {
                    if !(bps > 0.0 && bps.is_finite()) {
                        return Err(bad(format!(
                            "link bandwidth {bps} must be positive and finite"
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Compact description for `--dry-run` and verbose logs.
    pub fn summary(&self) -> String {
        if self.events.is_empty() {
            return "static world (no events)".into();
        }
        let mut counts = [0usize; 5];
        let mut lo = usize::MAX;
        let mut hi = 0usize;
        for e in &self.events {
            let slot = match e.event {
                WorldEvent::Join { .. } => 0,
                WorldEvent::Leave { .. } => 1,
                WorldEvent::Handover { .. } => 2,
                WorldEvent::CapacityChange { .. } => 3,
                WorldEvent::LinkChange { .. } => 4,
            };
            counts[slot] += 1;
            lo = lo.min(e.round);
            hi = hi.max(e.round);
        }
        format!(
            "{} events over rounds {lo}..={hi}: {} join, {} leave, {} handover, \
             {} capacity-change, {} link-change",
            self.events.len(),
            counts[0],
            counts[1],
            counts[2],
            counts[3],
            counts[4]
        )
    }

    // ----- JSON persistence --------------------------------------------------

    /// Serialize as an explicit event array (a generator-spec input is
    /// expanded at parse time, so round trips preserve the events).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.events.iter().map(event_to_json).collect())
    }

    /// Parse either an explicit event array or a `{"churn": {...}}`
    /// generator spec (expanded against `rosters`).
    pub fn from_json(j: &Json, rosters: &[Vec<usize>]) -> Result<Timeline> {
        if let Some(churn) = j.opt("churn") {
            let spec = ChurnSpec {
                p_leave: churn.get("p_leave")?.as_f64()?,
                p_join: churn.get("p_join")?.as_f64()?,
                rounds: churn.get("rounds")?.as_usize()?,
                seed: match churn.opt("seed") {
                    Some(v) => v.as_usize()? as u64,
                    None => 0,
                },
            };
            return Timeline::markov_churn(rosters, &spec);
        }
        let mut events = Vec::new();
        for item in j.as_arr()? {
            events.push(event_from_json(item)?);
        }
        Ok(Timeline { events })
    }
}

fn event_to_json(e: &TimelineEvent) -> Json {
    let mut o = Json::obj();
    o.set("round", Json::from_usize(e.round))
        .set("kind", Json::from_str_val(e.event.kind_name()));
    match e.event {
        WorldEvent::Join { device, cluster } => {
            o.set("device", Json::from_usize(device))
                .set("cluster", Json::from_usize(cluster));
        }
        WorldEvent::Leave { device } => {
            o.set("device", Json::from_usize(device));
        }
        WorldEvent::Handover { device, from, to } => {
            o.set("device", Json::from_usize(device))
                .set("from", Json::from_usize(from))
                .set("to", Json::from_usize(to));
        }
        WorldEvent::CapacityChange { device, factor } => {
            o.set("device", Json::from_usize(device))
                .set("factor", Json::from_f64(factor));
        }
        WorldEvent::LinkChange { link, bps } => {
            o.set("link", Json::from_str_val(link.name()))
                .set("bps", Json::from_f64(bps));
        }
    }
    o
}

fn event_from_json(j: &Json) -> Result<TimelineEvent> {
    let round = j.get("round")?.as_usize()?;
    let kind = j.get("kind")?.as_str()?;
    let event = match kind {
        "join" => WorldEvent::Join {
            device: j.get("device")?.as_usize()?,
            cluster: j.get("cluster")?.as_usize()?,
        },
        "leave" => WorldEvent::Leave { device: j.get("device")?.as_usize()? },
        "handover" => WorldEvent::Handover {
            device: j.get("device")?.as_usize()?,
            from: j.get("from")?.as_usize()?,
            to: j.get("to")?.as_usize()?,
        },
        "capacity-change" => WorldEvent::CapacityChange {
            device: j.get("device")?.as_usize()?,
            factor: j.get("factor")?.as_f64()?,
        },
        "link-change" => WorldEvent::LinkChange {
            link: LinkKind::parse(j.get("link")?.as_str()?)?,
            bps: j.get("bps")?.as_f64()?,
        },
        other => {
            return Err(CfelError::Config(format!(
                "unknown timeline event kind {other:?} \
                 (join | leave | handover | capacity-change | link-change)"
            )))
        }
    };
    Ok(TimelineEvent { round, event })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rosters() -> Vec<Vec<usize>> {
        vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]
    }

    #[test]
    fn link_kind_parse_roundtrip() {
        for k in [LinkKind::DeviceEdge, LinkKind::EdgeEdge, LinkKind::DeviceCloud] {
            assert_eq!(LinkKind::parse(k.name()).unwrap(), k);
        }
        assert!(LinkKind::parse("wifi").is_err());
    }

    #[test]
    fn markov_churn_is_deterministic_and_never_empties_a_cluster() {
        let spec = ChurnSpec { p_leave: 0.5, p_join: 0.3, rounds: 20, seed: 9 };
        let a = Timeline::markov_churn(&rosters(), &spec).unwrap();
        let b = Timeline::markov_churn(&rosters(), &spec).unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty(), "p=0.5 over 20 rounds must churn something");
        // Replay: per-cluster active counts never hit zero.
        let r = rosters();
        let mut cluster_of: Vec<Option<usize>> = vec![None; 8];
        for (ci, ros) in r.iter().enumerate() {
            for &d in ros {
                cluster_of[d] = Some(ci);
            }
        }
        let mut counts = [4usize, 4];
        for e in &a.events {
            match e.event {
                WorldEvent::Leave { device } => {
                    let ci = cluster_of[device].expect("leave of inactive device");
                    counts[ci] -= 1;
                    cluster_of[device] = None;
                    assert!(counts[ci] >= 1, "cluster {ci} emptied at round {}", e.round);
                }
                WorldEvent::Join { device, cluster } => {
                    assert!(cluster_of[device].is_none(), "join of active device");
                    cluster_of[device] = Some(cluster);
                    counts[cluster] += 1;
                }
                _ => unreachable!("churn only emits join/leave"),
            }
        }
        // The generated timeline passes its own validator.
        a.validate(8, &rosters()).unwrap();
    }

    #[test]
    fn churn_extremes() {
        let never = ChurnSpec { p_leave: 0.0, p_join: 1.0, rounds: 10, seed: 1 };
        assert!(Timeline::markov_churn(&rosters(), &never).unwrap().is_empty());
        assert!(ChurnSpec { p_leave: 1.5, p_join: 0.0, rounds: 5, seed: 0 }
            .validate()
            .is_err());
        assert!(ChurnSpec { p_leave: 0.1, p_join: 0.1, rounds: 0, seed: 0 }
            .validate()
            .is_err());
    }

    #[test]
    fn validate_replays_membership() {
        let r = rosters();
        // Leave then re-join elsewhere is fine.
        let ok = Timeline {
            events: vec![
                TimelineEvent { round: 1, event: WorldEvent::Leave { device: 0 } },
                TimelineEvent { round: 3, event: WorldEvent::Join { device: 0, cluster: 1 } },
                TimelineEvent {
                    round: 4,
                    event: WorldEvent::Handover { device: 0, from: 1, to: 0 },
                },
            ],
        };
        ok.validate(8, &r).unwrap();
        // Join of an active device is rejected.
        let dup = Timeline {
            events: vec![TimelineEvent {
                round: 1,
                event: WorldEvent::Join { device: 0, cluster: 1 },
            }],
        };
        assert!(dup.validate(8, &r).is_err());
        // Handover from the wrong cluster is rejected.
        let wrong = Timeline {
            events: vec![TimelineEvent {
                round: 2,
                event: WorldEvent::Handover { device: 0, from: 1, to: 0 },
            }],
        };
        assert!(wrong.validate(8, &r).is_err());
        // Out-of-range ids, bad factors, bad bandwidths.
        let oob = Timeline {
            events: vec![TimelineEvent { round: 1, event: WorldEvent::Leave { device: 99 } }],
        };
        assert!(oob.validate(8, &r).is_err());
        let badf = Timeline {
            events: vec![TimelineEvent {
                round: 1,
                event: WorldEvent::CapacityChange { device: 1, factor: 0.0 },
            }],
        };
        assert!(badf.validate(8, &r).is_err());
        let badb = Timeline {
            events: vec![TimelineEvent {
                round: 1,
                event: WorldEvent::LinkChange { link: LinkKind::EdgeEdge, bps: -1.0 },
            }],
        };
        assert!(badb.validate(8, &r).is_err());
    }

    #[test]
    fn at_preserves_list_order_within_a_round() {
        let t = Timeline {
            events: vec![
                TimelineEvent { round: 2, event: WorldEvent::Leave { device: 1 } },
                TimelineEvent { round: 1, event: WorldEvent::Leave { device: 0 } },
                TimelineEvent { round: 2, event: WorldEvent::Join { device: 1, cluster: 0 } },
            ],
        };
        let r2 = t.at(2);
        assert_eq!(r2.len(), 2);
        assert_eq!(r2[0].event, WorldEvent::Leave { device: 1 });
        assert_eq!(r2[1].event, WorldEvent::Join { device: 1, cluster: 0 });
        assert!(t.at(7).is_empty());
    }

    #[test]
    fn json_roundtrip_events_and_churn_spec() {
        let t = Timeline {
            events: vec![
                TimelineEvent { round: 1, event: WorldEvent::Leave { device: 3 } },
                TimelineEvent { round: 2, event: WorldEvent::Join { device: 3, cluster: 1 } },
                TimelineEvent {
                    round: 3,
                    event: WorldEvent::Handover { device: 4, from: 1, to: 0 },
                },
                TimelineEvent {
                    round: 4,
                    event: WorldEvent::CapacityChange { device: 0, factor: 0.25 },
                },
                TimelineEvent {
                    round: 5,
                    event: WorldEvent::LinkChange { link: LinkKind::EdgeEdge, bps: 1e7 },
                },
            ],
        };
        let back = Timeline::from_json(&t.to_json(), &rosters()).unwrap();
        assert_eq!(back, t);
        // Generator-spec form expands to the same events as the API call.
        let spec = ChurnSpec { p_leave: 0.4, p_join: 0.4, rounds: 8, seed: 5 };
        let api = Timeline::markov_churn(&rosters(), &spec).unwrap();
        let j = Json::parse(
            r#"{"churn": {"p_leave": 0.4, "p_join": 0.4, "rounds": 8, "seed": 5}}"#,
        )
        .unwrap();
        let parsed = Timeline::from_json(&j, &rosters()).unwrap();
        assert_eq!(parsed, api);
        // And its serialization round-trips as explicit events.
        assert_eq!(Timeline::from_json(&parsed.to_json(), &rosters()).unwrap(), parsed);
    }

    #[test]
    fn summary_counts_kinds() {
        assert_eq!(Timeline::default().summary(), "static world (no events)");
        let t = Timeline {
            events: vec![
                TimelineEvent { round: 2, event: WorldEvent::Leave { device: 0 } },
                TimelineEvent { round: 5, event: WorldEvent::Join { device: 0, cluster: 0 } },
            ],
        };
        let s = t.summary();
        assert!(s.contains("2 events over rounds 2..=5"), "{s}");
        assert!(s.contains("1 join, 1 leave"), "{s}");
    }

    #[test]
    fn unknown_event_kind_rejected() {
        let j = Json::parse(r#"[{"round": 1, "kind": "teleport", "device": 0}]"#).unwrap();
        assert!(Timeline::from_json(&j, &rosters()).is_err());
    }
}
