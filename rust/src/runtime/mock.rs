//! Pure-Rust reference backend: one-hidden-layer MLP with hand-written
//! backprop and the exact step semantics of the exported HLO train step
//! (mean softmax cross-entropy, SGD with momentum 0.9, padded batches).
//!
//! Used by unit/property/integration tests and the figure benches so they
//! run in milliseconds without artifacts; also serves as the independent
//! oracle the PJRT round-trip test compares against. Gradients are pinned
//! against central finite differences in the tests below.

use crate::data::Batch;
use crate::error::{CfelError, Result};
use crate::model::{InitKind, ModelSchema, ModelState, ParamSpec};
use crate::runtime::{accumulate_eval, EvalResult, TrainBackend};
use crate::util::rng::Rng;

/// MLP: x[B,D] → relu(x·W1+b1)[B,H] → (h·W2+b2)[B,C].
/// Flat layout: [W1 (D·H) | b1 (H) | W2 (H·C) | b2 (C)].
#[derive(Debug, Clone)]
pub struct MockBackend {
    pub dim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub momentum: f32,
    schema: ModelSchema,
}

impl MockBackend {
    pub fn new(dim: usize, hidden: usize, classes: usize, batch: usize) -> MockBackend {
        let schema = ModelSchema::new(vec![
            ParamSpec {
                name: "fc1/w".into(),
                shape: vec![dim, hidden],
                size: dim * hidden,
                init: InitKind::GlorotUniform,
                fan_in: dim,
                fan_out: hidden,
            },
            ParamSpec {
                name: "fc1/b".into(),
                shape: vec![hidden],
                size: hidden,
                init: InitKind::Zeros,
                fan_in: 0,
                fan_out: 0,
            },
            ParamSpec {
                name: "fc2/w".into(),
                shape: vec![hidden, classes],
                size: hidden * classes,
                init: InitKind::GlorotUniform,
                fan_in: hidden,
                fan_out: classes,
            },
            ParamSpec {
                name: "fc2/b".into(),
                shape: vec![classes],
                size: classes,
                init: InitKind::Zeros,
                fan_in: 0,
                fan_out: 0,
            },
        ]);
        MockBackend { dim, hidden, classes, batch, momentum: 0.9, schema }
    }

    /// The default test fixture matching `SyntheticSpec::mlp_synth`.
    pub fn mlp_synth() -> MockBackend {
        MockBackend::new(64, 32, 10, 16)
    }

    fn split_offsets(&self) -> (usize, usize, usize, usize) {
        let w1 = self.dim * self.hidden;
        let b1 = w1 + self.hidden;
        let w2 = b1 + self.hidden * self.classes;
        let b2 = w2 + self.classes;
        (w1, b1, w2, b2)
    }

    /// Forward pass; fills `hid` [B,H] and `logits` [B,C].
    fn forward(&self, p: &[f32], x: &[f32], bsz: usize, hid: &mut [f32], logits: &mut [f32]) {
        let (w1e, b1e, w2e, _) = self.split_offsets();
        let (w1, rest) = p.split_at(w1e);
        let (b1, rest2) = rest.split_at(b1e - w1e);
        let (w2, b2) = rest2.split_at(w2e - b1e);
        let (d, h, c) = (self.dim, self.hidden, self.classes);
        for bi in 0..bsz {
            let xrow = &x[bi * d..(bi + 1) * d];
            let hrow = &mut hid[bi * h..(bi + 1) * h];
            hrow.copy_from_slice(b1);
            for (k, &xv) in xrow.iter().enumerate() {
                if xv != 0.0 {
                    let wrow = &w1[k * h..(k + 1) * h];
                    for (hv, &wv) in hrow.iter_mut().zip(wrow) {
                        *hv += xv * wv;
                    }
                }
            }
            for hv in hrow.iter_mut() {
                *hv = hv.max(0.0);
            }
            let lrow = &mut logits[bi * c..(bi + 1) * c];
            lrow.copy_from_slice(b2);
            for (k, &hv) in hid[bi * h..(bi + 1) * h].iter().enumerate() {
                if hv != 0.0 {
                    let wrow = &w2[k * c..(k + 1) * c];
                    for (lv, &wv) in lrow.iter_mut().zip(wrow) {
                        *lv += hv * wv;
                    }
                }
            }
        }
    }

    /// Softmax in place per row; returns per-row cross-entropy losses.
    fn softmax_xent(&self, logits: &mut [f32], y: &[i32], bsz: usize) -> Vec<f32> {
        let c = self.classes;
        let mut losses = Vec::with_capacity(bsz);
        for bi in 0..bsz {
            let row = &mut logits[bi * c..(bi + 1) * c];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - maxv).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
            let target = y[bi] as usize;
            losses.push(-(row[target].max(1e-30)).ln());
        }
        losses
    }

    /// Full loss + gradient over the (padded) batch; mirrors the HLO step:
    /// loss = mean over the full batch (padding included — identical to
    /// the exported artifact, which also sees the padded rows).
    fn loss_and_grad(&self, p: &[f32], batch: &Batch, grad: &mut [f32]) -> f32 {
        let bsz = self.batch;
        let (d, h, c) = (self.dim, self.hidden, self.classes);
        let (w1e, b1e, w2e, _) = self.split_offsets();
        let mut hid = vec![0.0f32; bsz * h];
        let mut probs = vec![0.0f32; bsz * c];
        self.forward(p, &batch.x, bsz, &mut hid, &mut probs);
        let losses = self.softmax_xent(&mut probs, &batch.y, bsz);
        let loss = losses.iter().sum::<f32>() / bsz as f32;

        grad.fill(0.0);
        let (gw1, grest) = grad.split_at_mut(w1e);
        let (gb1, grest2) = grest.split_at_mut(b1e - w1e);
        let (gw2, gb2) = grest2.split_at_mut(w2e - b1e);
        let w2 = &p[b1e..w2e];

        let scale = 1.0 / bsz as f32;
        let mut dh = vec![0.0f32; h];
        for bi in 0..bsz {
            // dlogits = (probs - onehot) / B
            let prow = &mut probs[bi * c..(bi + 1) * c];
            prow[batch.y[bi] as usize] -= 1.0;
            for v in prow.iter_mut() {
                *v *= scale;
            }
            let hrow = &hid[bi * h..(bi + 1) * h];
            // gw2 += h ⊗ dlogits; gb2 += dlogits; dh = W2 · dlogits
            for (k, &hv) in hrow.iter().enumerate() {
                let grow = &mut gw2[k * c..(k + 1) * c];
                let wrow = &w2[k * c..(k + 1) * c];
                let mut acc = 0.0f32;
                for j in 0..c {
                    grow[j] += hv * prow[j];
                    acc += wrow[j] * prow[j];
                }
                dh[k] = if hv > 0.0 { acc } else { 0.0 }; // relu mask
            }
            for (gb, &pv) in gb2.iter_mut().zip(prow.iter()) {
                *gb += pv;
            }
            // gw1 += x ⊗ dh; gb1 += dh
            let xrow = &batch.x[bi * d..(bi + 1) * d];
            for (k, &xv) in xrow.iter().enumerate() {
                if xv != 0.0 {
                    let grow = &mut gw1[k * h..(k + 1) * h];
                    for (g, &dv) in grow.iter_mut().zip(dh.iter()) {
                        *g += xv * dv;
                    }
                }
            }
            for (g, &dv) in gb1.iter_mut().zip(dh.iter()) {
                *g += dv;
            }
        }
        loss
    }
}

impl TrainBackend for MockBackend {
    fn param_count(&self) -> usize {
        self.schema.param_count
    }

    fn flat_dim(&self) -> usize {
        self.dim
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn flops_per_sample(&self) -> f64 {
        (2 * self.dim * self.hidden + 2 * self.hidden * self.classes) as f64
    }

    fn init_state(&self, rng: &Rng) -> ModelState {
        ModelState::from_params(self.schema.init_flat(rng))
    }

    fn train_step(&self, state: &mut ModelState, batch: &Batch, lr: f32) -> Result<f32> {
        if batch.y.len() != self.batch {
            return Err(CfelError::Runtime(format!(
                "batch size {} != backend batch {}",
                batch.y.len(),
                self.batch
            )));
        }
        let mut grad = vec![0.0f32; self.schema.param_count];
        let loss = self.loss_and_grad(&state.params, batch, &mut grad);
        // v ← μ·v + g; p ← p − lr·v  (matches the exported HLO step).
        for ((p, v), &g) in state
            .params
            .iter_mut()
            .zip(state.momentum.iter_mut())
            .zip(grad.iter())
        {
            *v = self.momentum * *v + g;
            *p -= lr * *v;
        }
        Ok(loss)
    }

    fn eval(&self, params: &[f32], batches: &[Batch]) -> Result<EvalResult> {
        let bsz = self.batch;
        let (h, c) = (self.hidden, self.classes);
        let mut results = Vec::with_capacity(batches.len());
        let mut hid = vec![0.0f32; bsz * h];
        let mut logits = vec![0.0f32; bsz * c];
        for b in batches {
            self.forward(params, &b.x, bsz, &mut hid, &mut logits);
            let mut correct = vec![0.0f32; bsz];
            for bi in 0..bsz {
                let row = &logits[bi * c..(bi + 1) * c];
                let am = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct[bi] = (am as i32 == b.y[bi]) as i32 as f32;
            }
            let losses = self.softmax_xent(&mut logits, &b.y, bsz);
            results.push((correct, losses, b.valid));
        }
        Ok(accumulate_eval(results))
    }

    fn parallel_devices(&self) -> bool {
        true
    }

    fn name(&self) -> &str {
        "mock-mlp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::data::synthetic::{Prototypes, SyntheticSpec};

    fn toy_batch(backend: &MockBackend, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let mut ds = Dataset::new(backend.dim, backend.classes);
        let mut buf = vec![0.0f32; backend.dim];
        for i in 0..backend.batch {
            for v in &mut buf {
                *v = rng.normal();
            }
            ds.push(&buf, (i % backend.classes) as u32);
        }
        Batch::gather(&ds, &(0..backend.batch).collect::<Vec<_>>(), backend.batch)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let be = MockBackend::new(6, 5, 4, 3);
        let state = be.init_state(&Rng::new(1));
        let batch = toy_batch(&be, 2);
        let mut grad = vec![0.0f32; be.param_count()];
        let _ = be.loss_and_grad(&state.params, &batch, &mut grad);

        let eps = 1e-3f32;
        let mut p = state.params.clone();
        let mut scratch = vec![0.0f32; be.param_count()];
        // probe a spread of parameter indices
        for &idx in &[0usize, 7, 29, 30, 34, 54, 55, 58] {
            let orig = p[idx];
            p[idx] = orig + eps;
            let lp = be.loss_and_grad(&p, &batch, &mut scratch);
            p[idx] = orig - eps;
            let lm = be.loss_and_grad(&p, &batch, &mut scratch);
            p[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {idx}: fd {fd} vs analytic {}",
                grad[idx]
            );
        }
    }

    #[test]
    fn training_reduces_loss_and_improves_accuracy() {
        let be = MockBackend::mlp_synth();
        let spec = SyntheticSpec::mlp_synth();
        let protos = Prototypes::new(spec, &Rng::new(3));
        let ds = protos.global_pool(64, &Rng::new(4));
        let idx: Vec<usize> = (0..16).collect();
        let batch = Batch::gather(&ds, &idx, be.batch_size());

        let mut state = be.init_state(&Rng::new(5));
        let eval_batches = crate::data::sampler::eval_batches(&ds, be.batch_size());
        let before = be.eval(&state.params, &eval_batches).unwrap();
        let l0 = be.train_step(&mut state, &batch, 0.1).unwrap();
        let mut last = l0;
        for _ in 0..40 {
            last = be.train_step(&mut state, &batch, 0.1).unwrap();
        }
        let after = be.eval(&state.params, &eval_batches).unwrap();
        assert!(last < l0 * 0.7, "loss {l0} -> {last}");
        assert!(after.accuracy > before.accuracy, "{before:?} -> {after:?}");
    }

    #[test]
    fn momentum_semantics_match_pytorch_sgd() {
        // One step with lr=0 leaves params but accumulates momentum = g.
        let be = MockBackend::new(4, 3, 2, 2);
        let mut state = be.init_state(&Rng::new(7));
        let p0 = state.params.clone();
        let batch = toy_batch(&be, 8);
        be.train_step(&mut state, &batch, 0.0).unwrap();
        assert_eq!(state.params, p0);
        let m1 = state.momentum.clone();
        assert!(m1.iter().any(|&v| v != 0.0));
        // Second identical step: v2 = 0.9*v1 + g = 1.9*v1 (same grads).
        be.train_step(&mut state, &batch, 0.0).unwrap();
        for (a, b) in state.momentum.iter().zip(m1.iter()) {
            assert!((a - 1.9 * b).abs() < 1e-5, "{a} vs 1.9*{b}");
        }
    }

    #[test]
    fn eval_masks_padded_examples() {
        let be = MockBackend::new(4, 3, 2, 4);
        let state = be.init_state(&Rng::new(2));
        let mut ds = Dataset::new(4, 2);
        ds.push(&[1.0, 0.0, 0.0, 0.0], 0);
        ds.push(&[0.0, 1.0, 0.0, 0.0], 1);
        // One batch of 4 slots but only 2 valid.
        let b = Batch::gather(&ds, &[0, 1], 4);
        let r = be.eval(&state.params, &[b]).unwrap();
        assert_eq!(r.examples, 2);
    }

    #[test]
    fn rejects_wrong_batch_size() {
        let be = MockBackend::new(4, 3, 2, 4);
        let mut state = be.init_state(&Rng::new(2));
        let bad = Batch { x: vec![0.0; 8], y: vec![0, 1], valid: 2 };
        assert!(be.train_step(&mut state, &bad, 0.1).is_err());
    }

    #[test]
    fn deterministic_step() {
        let be = MockBackend::mlp_synth();
        let batch = toy_batch(&be, 9);
        let mut s1 = be.init_state(&Rng::new(11));
        let mut s2 = be.init_state(&Rng::new(11));
        be.train_step(&mut s1, &batch, 0.05).unwrap();
        be.train_step(&mut s2, &batch, 0.05).unwrap();
        assert_eq!(s1.params, s2.params);
    }
}
