//! Execution backends for the on-device train/eval steps.
//!
//! [`TrainBackend`] is the seam between the coordinator (L3) and the
//! compiled compute graph (L2/L1):
//!
//! * [`PjrtBackend`](pjrt::PjrtBackend) — the production path: loads the
//!   AOT HLO-text artifacts through the PJRT C API and executes them on
//!   the CPU client. Python is never involved at runtime.
//! * [`MockBackend`](mock::MockBackend) — a pure-Rust one-hidden-layer MLP
//!   with hand-written backprop and identical step semantics (SGD with
//!   momentum over a padded batch). It exists so the coordinator, the
//!   property suite and the figure benches run fast and without
//!   artifacts; its gradients are pinned against finite differences.

pub mod manifest;
pub mod mock;
pub mod pjrt;

pub use manifest::Manifest;
pub use mock::MockBackend;
pub use pjrt::PjrtBackend;

use crate::data::Batch;
use crate::error::Result;
use crate::model::ModelState;
use crate::util::rng::Rng;

/// Aggregate evaluation result over a test set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub accuracy: f64,
    pub loss: f64,
    pub examples: usize,
}

/// One on-device training/eval engine. Implementations must be
/// deterministic given the same state + batch.
pub trait TrainBackend: Send + Sync {
    /// Flat parameter vector length.
    fn param_count(&self) -> usize;
    /// Flattened input dimension D (x is `[batch, D]`).
    fn flat_dim(&self) -> usize;
    fn num_classes(&self) -> usize;
    fn batch_size(&self) -> usize;
    /// Forward FLOPs per sample (Eq. 8 workload constant).
    fn flops_per_sample(&self) -> f64;

    /// Initialise a fresh model (Glorot weights / zero biases family).
    fn init_state(&self, rng: &Rng) -> ModelState;

    /// One SGD-with-momentum step on `batch`; returns the mean batch loss.
    fn train_step(&self, state: &mut ModelState, batch: &Batch, lr: f32) -> Result<f32>;

    /// Evaluate `params` over `batches` (per-example masking of padding).
    fn eval(&self, params: &[f32], batches: &[Batch]) -> Result<EvalResult>;

    /// Whether the coordinator may call `train_step` from multiple threads
    /// concurrently (on distinct states).
    fn parallel_devices(&self) -> bool {
        false
    }

    /// Human-readable backend name for logs.
    fn name(&self) -> &str;
}

/// Accumulate per-example (correct, loss) vectors into an [`EvalResult`],
/// honouring each batch's `valid` prefix. Shared by both backends.
pub fn accumulate_eval(
    per_batch: impl IntoIterator<Item = (Vec<f32>, Vec<f32>, usize)>,
) -> EvalResult {
    let mut correct = 0.0f64;
    let mut loss = 0.0f64;
    let mut n = 0usize;
    for (c, l, valid) in per_batch {
        for i in 0..valid {
            correct += c[i] as f64;
            loss += l[i] as f64;
        }
        n += valid;
    }
    if n == 0 {
        return EvalResult { accuracy: 0.0, loss: 0.0, examples: 0 };
    }
    EvalResult {
        accuracy: correct / n as f64,
        loss: loss / n as f64,
        examples: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_masks_padding() {
        let r = accumulate_eval(vec![
            (vec![1.0, 1.0, 0.0], vec![0.1, 0.2, 9.0], 2), // 3rd entry padded
            (vec![0.0], vec![0.4], 1),
        ]);
        assert_eq!(r.examples, 3);
        assert!((r.accuracy - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.loss - (0.1 + 0.2 + 0.4) / 3.0).abs() < 1e-7);
    }

    #[test]
    fn accumulate_empty() {
        let r = accumulate_eval(Vec::<(Vec<f32>, Vec<f32>, usize)>::new());
        assert_eq!(r.examples, 0);
        assert_eq!(r.accuracy, 0.0);
    }
}
