//! Artifact manifest loader — the L2→L3 contract (DESIGN.md §6).
//!
//! `artifacts/manifest.json` is written once by `python/compile/aot.py`;
//! this module parses it into typed entries and validates the pieces the
//! runtime depends on (parameter order/shapes, batch size, file presence).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{CfelError, Result};
use crate::model::{ModelSchema, ParamSpec};
use crate::util::json::Json;

/// One model's artifact entry.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub batch_size: usize,
    pub input_dim: Vec<usize>,
    pub flat_dim: usize,
    pub num_classes: usize,
    pub momentum: f64,
    pub flops_per_sample: f64,
    pub schema: ModelSchema,
}

/// The shared Pallas aggregation executables.
#[derive(Debug, Clone)]
pub struct AggregateEntry {
    pub mix_hlo: PathBuf,
    pub wavg_hlo: PathBuf,
    pub rows: usize,
    pub dim: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
    pub aggregate: AggregateEntry,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Err(CfelError::Manifest(format!(
                "{} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let j = Json::parse_file(&path)?;
        let version = j.get("version")?.as_usize()?;
        if version != 1 {
            return Err(CfelError::Manifest(format!(
                "unsupported manifest version {version}"
            )));
        }
        let mut models = BTreeMap::new();
        for (name, entry) in j.get("models")?.as_obj()? {
            models.insert(name.clone(), Self::parse_model(dir, name, entry)?);
        }
        let agg = j.get("aggregate")?;
        let aggregate = AggregateEntry {
            mix_hlo: dir.join(agg.get("mix_hlo")?.as_str()?),
            wavg_hlo: dir.join(agg.get("wavg_hlo")?.as_str()?),
            rows: agg.get("rows")?.as_usize()?,
            dim: agg.get("dim")?.as_usize()?,
        };
        let m = Manifest { dir: dir.to_path_buf(), models, aggregate };
        m.validate()?;
        Ok(m)
    }

    fn parse_model(dir: &Path, name: &str, j: &Json) -> Result<ModelEntry> {
        let specs: Vec<ParamSpec> = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(ParamSpec::from_json)
            .collect::<Result<_>>()?;
        let schema = ModelSchema::new(specs);
        let declared = j.get("param_count")?.as_usize()?;
        if declared != schema.param_count {
            return Err(CfelError::Manifest(format!(
                "{name}: param_count {declared} != schema total {}",
                schema.param_count
            )));
        }
        let input_dim: Vec<usize> = j
            .get("input_dim")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?;
        let flat_dim = j.get("flat_dim")?.as_usize()?;
        if input_dim.iter().product::<usize>() != flat_dim {
            return Err(CfelError::Manifest(format!(
                "{name}: flat_dim {flat_dim} != product of input_dim {input_dim:?}"
            )));
        }
        Ok(ModelEntry {
            name: name.to_string(),
            train_hlo: dir.join(j.get("train_hlo")?.as_str()?),
            eval_hlo: dir.join(j.get("eval_hlo")?.as_str()?),
            batch_size: j.get("batch_size")?.as_usize()?,
            input_dim,
            flat_dim,
            num_classes: j.get("num_classes")?.as_usize()?,
            momentum: j.get("momentum")?.as_f64()?,
            flops_per_sample: j.get("flops_per_sample")?.as_f64()?,
            schema,
        })
    }

    fn validate(&self) -> Result<()> {
        for (name, m) in &self.models {
            for f in [&m.train_hlo, &m.eval_hlo] {
                if !f.exists() {
                    return Err(CfelError::Manifest(format!(
                        "{name}: missing artifact {}",
                        f.display()
                    )));
                }
            }
            if m.batch_size == 0 || m.num_classes == 0 {
                return Err(CfelError::Manifest(format!("{name}: zero batch/classes")));
            }
        }
        for f in [&self.aggregate.mix_hlo, &self.aggregate.wavg_hlo] {
            if !f.exists() {
                return Err(CfelError::Manifest(format!(
                    "missing aggregate artifact {}",
                    f.display()
                )));
            }
        }
        Ok(())
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            CfelError::Manifest(format!(
                "model {name:?} not in manifest (have {:?})",
                self.models.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// Default artifacts directory: `$CFEL_ARTIFACTS` or `<repo>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("CFEL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests exercise the real artifacts when present (CI runs
    /// `make artifacts` first) and are skipped otherwise.
    fn real() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(m) = real() else { return };
        assert!(m.models.contains_key("mlp_synth"));
        let mlp = m.model("mlp_synth").unwrap();
        assert_eq!(mlp.flat_dim, 64);
        assert_eq!(mlp.num_classes, 10);
        assert!((mlp.momentum - 0.9).abs() < 1e-9);
        assert!(mlp.schema.param_count > 0);
        assert!(mlp.train_hlo.exists());
    }

    #[test]
    fn unknown_model_errors() {
        let Some(m) = real() else { return };
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_dir_is_clean_error() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn corrupt_manifest_rejected() {
        let tmp = std::env::temp_dir().join(format!("cfel_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), "{\"version\": 2}").unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::write(tmp.join("manifest.json"), "not json").unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
