//! PJRT execution backend — the production request path.
//!
//! Loads the AOT HLO-text artifacts (`make artifacts`) through
//! `HloModuleProto::from_text_file`, compiles them once on the PJRT CPU
//! client, and executes them with flat-parameter slices converted to
//! literals per the manifest schema. HLO *text* is the interchange format
//! (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects —
//! see DESIGN.md §6 and /opt/xla-example/README.md).
//!
//! The real backend needs the vendored `xla` crate and is gated behind the
//! `xla` cargo feature. The default (offline) build compiles a stub whose
//! loaders return a clean runtime error, so the coordinator, CLI, benches
//! and tests all build and run on the mock backend without artifacts.

#[cfg(feature = "xla")]
mod real {
    use std::path::Path;

    use crate::data::Batch;
    use crate::error::{CfelError, Result};
    use crate::model::ModelState;
    use crate::runtime::manifest::{Manifest, ModelEntry};
    use crate::runtime::{accumulate_eval, EvalResult, TrainBackend};
    use crate::util::rng::Rng;

    /// The PJRT-backed [`TrainBackend`].
    pub struct PjrtBackend {
        entry: ModelEntry,
        _client: xla::PjRtClient,
        train: xla::PjRtLoadedExecutable,
        eval: xla::PjRtLoadedExecutable,
    }

    // SAFETY: the PJRT C API guarantees thread-safe clients/executables
    // (PJRT_Client/PJRT_LoadedExecutable may be used from multiple threads);
    // the Rust wrapper types only miss the auto-traits because they hold raw
    // pointers. The coordinator still serialises access per executable call
    // (each device call is independent; XLA's CPU backend does its own
    // intra-op threading).
    unsafe impl Send for PjrtBackend {}
    unsafe impl Sync for PjrtBackend {}

    impl PjrtBackend {
        /// Load `model_name` from the artifacts directory.
        pub fn load(artifacts_dir: &Path, model_name: &str) -> Result<PjrtBackend> {
            let manifest = Manifest::load(artifacts_dir)?;
            Self::from_manifest(&manifest, model_name)
        }

        /// Load from an already-parsed manifest.
        pub fn from_manifest(manifest: &Manifest, model_name: &str) -> Result<PjrtBackend> {
            let entry = manifest.model(model_name)?.clone();
            let client = xla::PjRtClient::cpu()?;
            let train = Self::compile(&client, &entry.train_hlo)?;
            let eval = Self::compile(&client, &entry.eval_hlo)?;
            Ok(PjrtBackend { entry, _client: client, train, eval })
        }

        fn compile(
            client: &xla::PjRtClient,
            path: &Path,
        ) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
                CfelError::Runtime(format!("parse {}: {e}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(|e| {
                CfelError::Runtime(format!("compile {}: {e}", path.display()))
            })
        }

        pub fn entry(&self) -> &ModelEntry {
            &self.entry
        }

        /// Slice a flat vector into per-tensor literals (manifest order).
        fn tensor_literals(&self, flat: &[f32], out: &mut Vec<xla::Literal>) -> Result<()> {
            debug_assert_eq!(flat.len(), self.entry.schema.param_count);
            for (spec, (start, end)) in self
                .entry
                .schema
                .specs
                .iter()
                .zip(self.entry.schema.offsets())
            {
                let lit = xla::Literal::vec1(&flat[start..end]);
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                out.push(lit.reshape(&dims)?);
            }
            Ok(())
        }

        fn batch_literals(&self, batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
            let b = self.entry.batch_size as i64;
            let d = self.entry.flat_dim as i64;
            if batch.y.len() != self.entry.batch_size {
                return Err(CfelError::Runtime(format!(
                    "batch size {} != artifact batch {}",
                    batch.y.len(),
                    self.entry.batch_size
                )));
            }
            let x = xla::Literal::vec1(&batch.x).reshape(&[b, d])?;
            let y = xla::Literal::vec1(&batch.y);
            Ok((x, y))
        }
    }

    impl TrainBackend for PjrtBackend {
        fn param_count(&self) -> usize {
            self.entry.schema.param_count
        }

        fn flat_dim(&self) -> usize {
            self.entry.flat_dim
        }

        fn num_classes(&self) -> usize {
            self.entry.num_classes
        }

        fn batch_size(&self) -> usize {
            self.entry.batch_size
        }

        fn flops_per_sample(&self) -> f64 {
            self.entry.flops_per_sample
        }

        fn init_state(&self, rng: &Rng) -> ModelState {
            ModelState::from_params(self.entry.schema.init_flat(rng))
        }

        fn train_step(&self, state: &mut ModelState, batch: &Batch, lr: f32) -> Result<f32> {
            let k = self.entry.schema.specs.len();
            let mut args: Vec<xla::Literal> = Vec::with_capacity(2 * k + 3);
            self.tensor_literals(&state.params, &mut args)?;
            self.tensor_literals(&state.momentum, &mut args)?;
            let (x, y) = self.batch_literals(batch)?;
            args.push(x);
            args.push(y);
            args.push(xla::Literal::scalar(lr));

            let result = self.train.execute::<xla::Literal>(&args)?;
            let tuple = result[0][0].to_literal_sync()?;
            let mut parts = tuple.to_tuple()?;
            if parts.len() != 2 * k + 1 {
                return Err(CfelError::Runtime(format!(
                    "train step returned {} outputs, expected {}",
                    parts.len(),
                    2 * k + 1
                )));
            }
            let loss = parts
                .pop()
                .unwrap()
                .get_first_element::<f32>()
                .map_err(|e| CfelError::Runtime(format!("loss read: {e}")))?;
            let offsets = self.entry.schema.offsets();
            for (i, part) in parts.iter().enumerate() {
                let (start, end) = offsets[i % k];
                let dst = if i < k {
                    &mut state.params[start..end]
                } else {
                    &mut state.momentum[start..end]
                };
                part.copy_raw_to::<f32>(dst)
                    .map_err(|e| CfelError::Runtime(format!("param read-back: {e}")))?;
            }
            Ok(loss)
        }

        fn eval(&self, params: &[f32], batches: &[Batch]) -> Result<EvalResult> {
            let k = self.entry.schema.specs.len();
            let mut param_lits: Vec<xla::Literal> = Vec::with_capacity(k);
            self.tensor_literals(params, &mut param_lits)?;
            let mut results = Vec::with_capacity(batches.len());
            for b in batches {
                let (x, y) = self.batch_literals(b)?;
                let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
                args.push(&x);
                args.push(&y);
                let out = self.eval.execute::<&xla::Literal>(&args)?;
                let tuple = out[0][0].to_literal_sync()?;
                let (correct, loss) = tuple.to_tuple2()?;
                results.push((
                    correct.to_vec::<f32>()?,
                    loss.to_vec::<f32>()?,
                    b.valid,
                ));
            }
            Ok(accumulate_eval(results))
        }

        fn parallel_devices(&self) -> bool {
            // PJRT CPU executables are thread-safe, but the CPU client already
            // parallelises intra-op; device-level threading buys little and
            // oversubscribes. Keep the device loop sequential.
            false
        }

        fn name(&self) -> &str {
            &self.entry.name
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use crate::data::Batch;
    use crate::error::{CfelError, Result};
    use crate::model::ModelState;
    use crate::runtime::manifest::Manifest;
    use crate::runtime::{EvalResult, TrainBackend};
    use crate::util::rng::Rng;

    /// Uninhabited placeholder for the PJRT backend: the `xla` feature is
    /// off, so no value of this type can ever exist. Both loaders return a
    /// clean error pointing at the feature flag; the [`TrainBackend`] impl
    /// exists only so call sites type-check.
    pub enum PjrtBackend {}

    fn unavailable() -> CfelError {
        CfelError::Runtime(
            "PJRT backend unavailable: this binary was built without the \
             `xla` cargo feature (use the mock backend, or rebuild with \
             --features xla and the vendored xla crate)"
                .into(),
        )
    }

    impl PjrtBackend {
        /// Load `model_name` from the artifacts directory.
        pub fn load(_artifacts_dir: &Path, _model_name: &str) -> Result<PjrtBackend> {
            Err(unavailable())
        }

        /// Load from an already-parsed manifest.
        pub fn from_manifest(_manifest: &Manifest, _model_name: &str) -> Result<PjrtBackend> {
            Err(unavailable())
        }
    }

    impl TrainBackend for PjrtBackend {
        fn param_count(&self) -> usize {
            match *self {}
        }

        fn flat_dim(&self) -> usize {
            match *self {}
        }

        fn num_classes(&self) -> usize {
            match *self {}
        }

        fn batch_size(&self) -> usize {
            match *self {}
        }

        fn flops_per_sample(&self) -> f64 {
            match *self {}
        }

        fn init_state(&self, _rng: &Rng) -> ModelState {
            match *self {}
        }

        fn train_step(&self, _state: &mut ModelState, _batch: &Batch, _lr: f32) -> Result<f32> {
            match *self {}
        }

        fn eval(&self, _params: &[f32], _batches: &[Batch]) -> Result<EvalResult> {
            match *self {}
        }

        fn name(&self) -> &str {
            match *self {}
        }
    }
}

#[cfg(feature = "xla")]
pub use real::PjrtBackend;
#[cfg(not(feature = "xla"))]
pub use stub::PjrtBackend;

// Integration coverage for this backend lives in rust/tests/pjrt_roundtrip.rs
// (artifact-gated): numerics vs the mock oracle, loss decrease, eval masking.
