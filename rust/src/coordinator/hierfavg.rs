//! Hier-FAvg baseline (Liu et al. [19]) — hierarchical FL.
//!
//! Per global round: q−1 rounds of (τ local epochs + edge aggregation),
//! then one more τ-epoch round whose models go to the *cloud* for a global
//! aggregation. The cloud is a star bottleneck: it gives the fastest
//! per-round convergence (full averaging) at the price of the slow
//! device→cloud upload in Eq. 8 and a single point of failure. The
//! configured close policy governs every one of the q phases — edge and
//! cloud alike — through the shared `edge_phase` machinery.

use crate::coordinator::cefedavg::merge_steps;
use crate::coordinator::{Coordinator, RoundStats};
use crate::error::Result;
use crate::netsim::UploadChannel;

impl Coordinator {
    pub(crate) fn hier_favg_round(&mut self, round: usize) -> Result<RoundStats> {
        let mut stats = RoundStats::default();
        for r in 0..self.cfg.q {
            let phase = (round * self.cfg.q + r) as u64;
            // Clusters are independent between cloud syncs — run them
            // concurrently through the parallel round engine. The first
            // q−1 rounds report to the edge server; the q-th feeds the
            // cloud aggregation over the slow device→cloud links (§6.1).
            let channel = if r + 1 == self.cfg.q {
                UploadChannel::DeviceCloud
            } else {
                UploadChannel::DeviceEdge
            };
            self.edge_phase(self.cfg.tau, phase, channel, &mut stats)?;
        }
        if self.aggregator_alive {
            self.cloud_aggregate()?;
        }
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{AlgorithmKind, ExperimentConfig};
    use crate::coordinator::Coordinator;
    use crate::metrics::best_accuracy;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::quickstart();
        c.algorithm = AlgorithmKind::HierFAvg;
        c.rounds = 6;
        c
    }

    #[test]
    fn learns_and_synchronises() {
        let mut coord = Coordinator::from_config(&cfg()).unwrap();
        let h = coord.run().unwrap();
        assert!(best_accuracy(&h) > 0.3);
        assert!(h.last().unwrap().consensus < 1e-12);
    }

    #[test]
    fn equals_ce_fedavg_under_complete_strong_gossip() {
        // §4.3: fully-connected backhaul + full averaging ⇒ CE-FedAvg's
        // update rule coincides with Hier-FAvg. Uniform H (π irrelevant)
        // averages exactly, so losses must match round for round —
        // *almost*: Hier weights the cloud average by cluster sample
        // counts while gossip with doubly-stochastic H is uniform. Use
        // equal cluster sizes so both weightings coincide.
        let mut hier_cfg = cfg();
        hier_cfg.rounds = 3;
        let mut ce_cfg = hier_cfg.clone();
        ce_cfg.algorithm = AlgorithmKind::CeFedAvg;
        ce_cfg.topology = "complete".into();
        ce_cfg.pi = 60; // H^60 of a complete-graph Metropolis ≈ uniform
        let mut hier = Coordinator::from_config(&hier_cfg).unwrap();
        let hh = hier.run().unwrap();
        let mut ce = Coordinator::from_config(&ce_cfg).unwrap();
        let hc = ce.run().unwrap();
        for (a, b) in hh.iter().zip(&hc) {
            assert!(
                (a.train_loss - b.train_loss).abs() < 1e-3,
                "round {}: hier {} vs ce {}",
                a.round,
                a.train_loss,
                b.train_loss
            );
        }
    }

    #[test]
    fn semi_sync_timeout_splits_edge_and_cloud_phase_closes() {
        use crate::config::{AggPolicyKind, LatencyMode};
        // Hier-FAvg is the one algorithm whose phases ride two different
        // uplinks per global round: q−1 edge phases (~8 ms healthy
        // reports on 10 Mbps) and one cloud phase (~77 ms on 1 Mbps). A
        // 20 ms semi-sync timeout therefore lands *between* the two —
        // edge phases close with every report in, cloud phases time out
        // with everyone late-but-kept — so the round's close reasons are
        // genuinely mixed and nothing is ever dropped.
        let mut c = cfg();
        c.rounds = 4;
        c.latency = LatencyMode::EventDriven;
        c.agg_policy = AggPolicyKind::SemiSync {
            k: c.devices_per_cluster(),
            timeout_s: 0.02,
        };
        let h = Coordinator::from_config(&c).unwrap().run().unwrap();
        for rec in &h {
            assert_eq!(rec.close_reason, "mixed", "round {}", rec.round);
            assert_eq!(rec.dropped_devices, 0, "semi-sync never drops");
            // Every cloud report misses the timeout; every edge report
            // makes it.
            assert_eq!(rec.late_devices, c.n_devices);
            assert_eq!(rec.on_time_devices, (c.q - 1) * c.n_devices);
        }
    }

    #[test]
    fn hier_per_round_slower_than_local_edge() {
        let mut le_cfg = cfg();
        le_cfg.algorithm = AlgorithmKind::LocalEdge;
        let mut hier = Coordinator::from_config(&cfg()).unwrap();
        let mut le = Coordinator::from_config(&le_cfg).unwrap();
        let hh = hier.run().unwrap();
        let hl = le.run().unwrap();
        assert!(hh.last().unwrap().sim_time_s > hl.last().unwrap().sim_time_s);
    }
}
