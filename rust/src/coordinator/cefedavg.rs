//! CE-FedAvg — the paper's Algorithm 1.
//!
//! One global round l:
//!   1. q edge rounds: every cluster independently runs τ local epochs on
//!      each of its devices from the edge model, then aggregates
//!      intra-cluster (Eq. 6, size-weighted). When an edge round *closes*
//!      is the configured `AggregationPolicy`'s call — the paper's full
//!      barrier, a reporting deadline, or a semi-sync K-of-N close that
//!      defers stragglers to a later edge round with a staleness discount.
//!   2. One inter-cluster aggregation: π gossip steps with the
//!      doubly-stochastic H over the edge backhaul (Eq. 7), implemented as
//!      a single application of the precomputed H^π.

use crate::coordinator::{Coordinator, RoundStats};
use crate::error::Result;
use crate::netsim::UploadChannel;

impl Coordinator {
    pub(crate) fn ce_fedavg_round(&mut self, round: usize) -> Result<RoundStats> {
        let mut stats = RoundStats::default();
        for r in 0..self.cfg.q {
            let phase = (round * self.cfg.q + r) as u64;
            // Every alive cluster trains + aggregates concurrently —
            // Algorithm 1's edge rounds are cluster-independent until
            // the gossip step below.
            self.edge_phase(self.cfg.tau, phase, UploadChannel::DeviceEdge, &mut stats)?;
        }
        self.gossip();
        // Eq. 8 wants per-device steps of the *whole* global round.
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }
}

/// Sum steps per device across the q edge rounds.
pub(crate) fn merge_steps(raw: Vec<(usize, usize)>) -> Vec<(usize, usize)> {
    let mut map = std::collections::BTreeMap::new();
    for (dev, s) in raw {
        *map.entry(dev).or_insert(0usize) += s;
    }
    map.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmKind, ExperimentConfig};
    use crate::metrics::best_accuracy;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::quickstart();
        c.rounds = 8;
        c
    }

    #[test]
    fn merge_steps_sums_per_device() {
        let merged = merge_steps(vec![(1, 3), (0, 2), (1, 4)]);
        assert_eq!(merged, vec![(0, 2), (1, 7)]);
    }

    #[test]
    fn learns_on_quickstart() {
        let mut coord = Coordinator::from_config(&cfg()).unwrap();
        let history = coord.run().unwrap();
        assert_eq!(history.len(), 8);
        let first = history[0].test_accuracy;
        let best = best_accuracy(&history);
        assert!(best > first + 0.1, "no learning: {first} -> {best}");
        assert!(best > 0.35, "final accuracy too low: {best}");
        // Simulated time strictly increases.
        for w in history.windows(2) {
            assert!(w[1].sim_time_s > w[0].sim_time_s);
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let run = || {
            let mut coord = Coordinator::from_config(&cfg()).unwrap();
            coord.run().unwrap()
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.test_accuracy, y.test_accuracy);
        }
    }

    #[test]
    fn semi_sync_outpaces_barrier_and_merges_stragglers_stale() {
        use crate::config::{AggPolicyKind, LatencyMode};
        use crate::netsim::StragglerSpec;
        let mut barrier = cfg();
        barrier.rounds = 6;
        barrier.latency = LatencyMode::EventDriven;
        barrier.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e4 });
        let mut semi = barrier.clone();
        // Healthy reports land in ~8 ms (upload-dominated); a 10⁴×
        // straggler needs ~26 ms of compute. K=3 closes a 4-device
        // cluster on its healthy majority and the 20 ms timeout bounds
        // the close even if the seed packs several stragglers into one
        // cluster — so the speedup bound below is placement-proof.
        semi.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.02 };
        semi.staleness_exp = 1.0;
        let hb = Coordinator::from_config(&barrier).unwrap().run().unwrap();
        let hs = Coordinator::from_config(&semi).unwrap().run().unwrap();
        // The barrier waits ~34 ms per edge round for the stragglers;
        // semi-sync closes in at most 20 ms — faster, with nothing
        // dropped: stragglers merge stale into later rounds instead.
        let (tb, ts) = (hb.last().unwrap().sim_time_s, hs.last().unwrap().sim_time_s);
        assert!(ts < tb * 0.75, "semi-sync not faster: {ts} !< 0.75·{tb}");
        assert_eq!(hs.iter().map(|r| r.dropped_devices).sum::<usize>(), 0);
        let late: usize = hs.iter().map(|r| r.late_devices).sum();
        let stale: usize = hs.iter().map(|r| r.stale_merged).sum();
        assert!(late > 0, "stragglers should miss the K-of-N close");
        assert!(stale > 0, "late reports should fold into later rounds");
        // Deferred-but-kept updates keep the run learning (10-class task:
        // chance is ~0.1).
        assert!(best_accuracy(&hs) > 0.25, "semi-sync run failed to learn");
    }

    #[test]
    fn gossip_tightens_consensus() {
        let mut c = cfg();
        c.rounds = 4;
        c.pi = 20; // strong mixing
        let mut coord = Coordinator::from_config(&c).unwrap();
        let hist = coord.run().unwrap();
        // With π=20 on a 4-ring, post-gossip consensus must be tiny
        // relative to the parameter scale.
        assert!(hist.last().unwrap().consensus < 1e-3, "{}", hist.last().unwrap().consensus);
    }

    #[test]
    fn reduces_to_fedavg_when_single_cluster() {
        // §4.3: m=1, q=1 ⇒ CE-FedAvg == FedAvg update rule. With one
        // cluster the gossip is a no-op and the intra-cluster average is
        // the global average, so per-round train losses must match the
        // FedAvg implementation exactly.
        let mut c = cfg();
        c.n_clusters = 1;
        c.n_devices = 8;
        c.q = 1;
        c.rounds = 3;
        c.topology = "ring".into();
        let mut ce = Coordinator::from_config(&c).unwrap();
        let h1 = ce.run().unwrap();
        let mut c2 = c.clone();
        c2.algorithm = AlgorithmKind::FedAvg;
        let mut fa = Coordinator::from_config(&c2).unwrap();
        let h2 = fa.run().unwrap();
        for (a, b) in h1.iter().zip(&h2) {
            assert!((a.train_loss - b.train_loss).abs() < 1e-9);
            assert!((a.test_accuracy - b.test_accuracy).abs() < 1e-9);
        }
    }
}
