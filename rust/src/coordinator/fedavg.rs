//! Cloud FedAvg baseline (paper §6.1 adaptation).
//!
//! Per global round every device runs qτ local epochs from the global
//! model, then uploads to the cloud for one size-weighted aggregation —
//! the traditional cloud-based FL framework. The configured close policy
//! applies to the cloud report phase like any edge phase: a semi-sync
//! K-of-N close lets the round finish on the fastest reporters' slow
//! 1 Mbps uploads and folds stragglers in stale next round. If the cloud
//! has been killed (Table 1 fault experiment) the aggregation is skipped
//! and devices keep drifting on their own cluster models.

use crate::coordinator::cefedavg::merge_steps;
use crate::coordinator::{Coordinator, RoundStats};
use crate::error::Result;
use crate::netsim::UploadChannel;

impl Coordinator {
    pub(crate) fn fedavg_round(&mut self, round: usize) -> Result<RoundStats> {
        let mut stats = RoundStats::default();
        let epochs = self.cfg.q * self.cfg.tau; // qτ local epochs per round
        let phase = round as u64;
        // All devices train concurrently; the per-cluster Eq. 6 average
        // is pure bookkeeping here — the real aggregation is the cloud
        // step below. Reports travel on the 1 Mbps device→cloud links.
        self.edge_phase(epochs, phase, UploadChannel::DeviceCloud, &mut stats)?;
        if self.aggregator_alive {
            self.cloud_aggregate()?;
        }
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{AlgorithmKind, ExperimentConfig, FaultSpec};
    use crate::coordinator::Coordinator;
    use crate::metrics::best_accuracy;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::quickstart();
        c.algorithm = AlgorithmKind::FedAvg;
        c.rounds = 6;
        c
    }

    #[test]
    fn learns_and_reaches_consensus() {
        let mut coord = Coordinator::from_config(&cfg()).unwrap();
        let h = coord.run().unwrap();
        assert!(best_accuracy(&h) > 0.3);
        // Cloud aggregation ⇒ all cluster models identical each round.
        assert!(h.last().unwrap().consensus < 1e-12);
    }

    #[test]
    fn cloud_upload_dominates_round_latency() {
        // 1 Mbps cloud links make FedAvg rounds slower than CE rounds on
        // the same workload (paper Fig. 2 runtime axis).
        let mut fa = Coordinator::from_config(&cfg()).unwrap();
        let hfa = fa.run().unwrap();
        let mut c = cfg();
        c.algorithm = AlgorithmKind::CeFedAvg;
        c.pi = 5;
        let mut ce = Coordinator::from_config(&c).unwrap();
        let hce = ce.run().unwrap();
        assert!(
            hfa.last().unwrap().sim_time_s > hce.last().unwrap().sim_time_s,
            "fedavg {} !> ce {}",
            hfa.last().unwrap().sim_time_s,
            hce.last().unwrap().sim_time_s
        );
    }

    #[test]
    fn semi_sync_bounds_the_cloud_report_wait() {
        use crate::config::{AggPolicyKind, LatencyMode};
        use crate::netsim::StragglerSpec;
        // Healthy cloud reports land in ~78 ms (1 Mbps uplink); the 10⁴×
        // stragglers need ~53 ms of extra compute first. The 100 ms
        // timeout caps every close below the straggler finish.
        let mut barrier = cfg();
        barrier.rounds = 4;
        barrier.latency = LatencyMode::EventDriven;
        barrier.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e4 });
        let mut semi = barrier.clone();
        semi.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.1 };
        let hb = Coordinator::from_config(&barrier).unwrap().run().unwrap();
        let hs = Coordinator::from_config(&semi).unwrap().run().unwrap();
        let (tb, ts) = (hb.last().unwrap().sim_time_s, hs.last().unwrap().sim_time_s);
        assert!(ts < tb, "semi-sync not faster on cloud uploads: {ts} !< {tb}");
        assert_eq!(hs.iter().map(|r| r.dropped_devices).sum::<usize>(), 0);
        assert!(hs.iter().map(|r| r.late_devices).sum::<usize>() > 0);
    }

    #[test]
    fn aggregator_death_freezes_cooperation() {
        let mut c = cfg();
        c.rounds = 8;
        c.fault = Some(FaultSpec::KillAggregator { at_round: 3 });
        let mut coord = Coordinator::from_config(&c).unwrap();
        let h = coord.run().unwrap();
        // Before the fault consensus is 0 (cloud sync); afterwards the
        // cluster models drift apart.
        assert!(h[2].consensus < 1e-12);
        assert!(h[7].consensus > 1e-12, "no drift after aggregator death");
    }
}
