//! The frozen PR 3 direct-dispatch round loop — the plan interpreter's
//! equivalence oracle.
//!
//! Before the [`Plan`](crate::plan::Plan) redesign, each of the paper's
//! four algorithms was a hand-written `impl Coordinator` method selected
//! by a closed `match` on `AlgorithmKind`, with algorithm-specific
//! latency dispatch and clock-barrier rules baked into the run loop. That
//! loop survives here, verbatim in behaviour, for two jobs:
//!
//! * `rust/tests/plan_equivalence.rs` pins every canned plan bit-identical
//!   to it — history rows, CSV, virtual times — under every close policy
//!   and `CFEL_THREADS` count, so the interpreter cannot silently drift
//!   from the paper semantics;
//! * `rust/benches/components.rs` runs both loops on the same system to
//!   pin the interpreter's dispatch overhead (it should be in the noise —
//!   both paths spend their time in the same `edge_phase`).
//!
//! Do not extend this module: new schedules are plans, not methods.

use std::time::Instant;

use crate::config::{AlgorithmKind, LatencyMode};
use crate::coordinator::{Coordinator, RoundStats};
use crate::error::{CfelError, Result};
use crate::metrics::{History, RoundRecord};
use crate::netsim::{EventDrivenEstimator, RoundLatency, UploadChannel};
use crate::util::stats::merge_steps;

impl Coordinator {
    /// One CE-FedAvg global round (Algorithm 1): q edge rounds, then π
    /// gossip steps over the backhaul.
    fn legacy_ce_fedavg_round(&mut self, round: usize) -> Result<RoundStats> {
        let mut stats = RoundStats::default();
        for r in 0..self.cfg.q {
            let phase = (round * self.cfg.q + r) as u64;
            self.edge_phase(self.cfg.tau, phase, UploadChannel::DeviceEdge, &mut stats)?;
        }
        self.gossip();
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }

    /// One cloud-FedAvg global round: qτ local epochs, one cloud upload,
    /// one cloud aggregation (skipped if the aggregator is dead).
    fn legacy_fedavg_round(&mut self, round: usize) -> Result<RoundStats> {
        let mut stats = RoundStats::default();
        let epochs = self.cfg.q * self.cfg.tau;
        let phase = round as u64;
        self.edge_phase(epochs, phase, UploadChannel::DeviceCloud, &mut stats)?;
        if self.aggregator_alive {
            self.cloud_aggregate()?;
        }
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }

    /// One Hier-FAvg global round: q−1 edge rounds, a final cloud-reported
    /// round, then the cloud aggregation.
    fn legacy_hier_favg_round(&mut self, round: usize) -> Result<RoundStats> {
        let mut stats = RoundStats::default();
        for r in 0..self.cfg.q {
            let phase = (round * self.cfg.q + r) as u64;
            let channel = if r + 1 == self.cfg.q {
                UploadChannel::DeviceCloud
            } else {
                UploadChannel::DeviceEdge
            };
            self.edge_phase(self.cfg.tau, phase, channel, &mut stats)?;
        }
        if self.aggregator_alive {
            self.cloud_aggregate()?;
        }
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }

    /// One Local-Edge global round: q edge rounds, no cooperation.
    fn legacy_local_edge_round(&mut self, round: usize) -> Result<RoundStats> {
        let mut stats = RoundStats::default();
        for r in 0..self.cfg.q {
            let phase = (round * self.cfg.q + r) as u64;
            self.edge_phase(self.cfg.tau, phase, UploadChannel::DeviceEdge, &mut stats)?;
        }
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }

    /// The pre-plan round latency: per-algorithm closed forms, or the
    /// event accumulator with gossip charged only to CE-FedAvg.
    fn legacy_round_latency(&self, stats: &RoundStats) -> RoundLatency {
        let steps = &stats.device_steps;
        let (q, pi) = (self.cfg.q, self.cfg.pi as usize);
        match self.cfg.latency {
            LatencyMode::ClosedForm => match self.cfg.algorithm {
                AlgorithmKind::CeFedAvg => self.net.ce_fedavg_round(steps, q, pi),
                AlgorithmKind::FedAvg => self.net.fedavg_round(steps),
                AlgorithmKind::HierFAvg => self.net.hier_favg_round(steps, q),
                AlgorithmKind::LocalEdge => self.net.local_edge_round(steps, q),
            },
            LatencyMode::EventDriven => {
                let timing = &stats.timing;
                let mut slowest = 0usize;
                let mut t = f64::NEG_INFINITY;
                for (i, &ct) in timing.cluster_time_s.iter().enumerate() {
                    if ct > t {
                        t = ct;
                        slowest = i;
                    }
                }
                let (compute, upload) = if timing.cluster_time_s.is_empty() {
                    (0.0, 0.0)
                } else {
                    (
                        timing.cluster_compute_s[slowest],
                        timing.cluster_upload_s[slowest],
                    )
                };
                let backhaul = match self.cfg.algorithm {
                    AlgorithmKind::CeFedAvg => {
                        EventDrivenEstimator::simulate_gossip(&self.net, pi).0
                    }
                    _ => 0.0,
                };
                RoundLatency { compute_s: compute, upload_s: upload, backhaul_s: backhaul }
            }
        }
    }

    /// The pre-plan end-of-round clock barrier: CE-FedAvg barriers at the
    /// gossip hops; FedAvg / Hier-FAvg at the cloud (only while the
    /// aggregator lives); Local-Edge never.
    fn legacy_sync_cluster_clocks(&mut self, lat: &RoundLatency) {
        let barriers = match self.cfg.algorithm {
            AlgorithmKind::CeFedAvg => true,
            AlgorithmKind::FedAvg | AlgorithmKind::HierFAvg => self.aggregator_alive,
            AlgorithmKind::LocalEdge => false,
        };
        if !barriers || self.cfg.latency != LatencyMode::EventDriven {
            return;
        }
        let end = self
            .alive_clusters()
            .iter()
            .map(|&ci| self.cluster_clock_s[ci])
            .fold(f64::NEG_INFINITY, f64::max)
            + lat.backhaul_s;
        if end.is_finite() {
            for &ci in &self.alive_clusters() {
                self.cluster_clock_s[ci] = end;
            }
        }
    }

    /// Run `cfg.rounds` global rounds through the frozen direct-dispatch
    /// loop — `cfg.algorithm` picks the hand-written round method,
    /// exactly as before the redesign. Configs carrying an explicit plan
    /// are rejected: the shared fault machinery keys gossip-matrix
    /// rebuilds off the *resolved plan*, which only matches this loop's
    /// `cfg.algorithm` dispatch when the plan is the canned one.
    pub fn run_legacy(&mut self) -> Result<History> {
        if self.cfg.plan.is_some() {
            return Err(CfelError::Config(
                "run_legacy replays the canned algorithm loops; clear the \
                 explicit plan (it is the new API this oracle predates)"
                    .into(),
            ));
        }
        if self.cfg.scenario.is_some() {
            return Err(CfelError::Config(
                "run_legacy predates the scenario API and never applies \
                 world timelines; clear the explicit scenario (flat \
                 configs lower to an equivalent static one)"
                    .into(),
            ));
        }
        let mut history = History::new();
        let mut sim_time = 0.0f64;
        let mut wall = 0.0f64;
        for round in 0..self.cfg.rounds {
            let t0 = Instant::now();
            self.apply_fault(round)?;
            let stats = match self.cfg.algorithm {
                AlgorithmKind::CeFedAvg => self.legacy_ce_fedavg_round(round)?,
                AlgorithmKind::FedAvg => self.legacy_fedavg_round(round)?,
                AlgorithmKind::HierFAvg => self.legacy_hier_favg_round(round)?,
                AlgorithmKind::LocalEdge => self.legacy_local_edge_round(round)?,
            };
            wall += t0.elapsed().as_secs_f64();
            let lat = self.legacy_round_latency(&stats);
            sim_time += lat.total();
            self.legacy_sync_cluster_clocks(&lat);

            let (acc, tloss) = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                self.evaluate()?
            } else {
                (f64::NAN, f64::NAN)
            };
            let (report_p50, report_p90, report_p99) =
                crate::metrics::report_quantiles(&stats.timing.device_timings.finish_s);
            let rec = RoundRecord {
                round: round + 1,
                sim_time_s: sim_time,
                wall_time_s: wall,
                compute_s: lat.compute_s,
                upload_s: lat.upload_s,
                backhaul_s: lat.backhaul_s,
                dropped_devices: stats.timing.dropped_devices,
                on_time_devices: stats.timing.on_time_devices,
                late_devices: stats.timing.late_devices,
                stale_merged: stats.timing.stale_merged,
                close_reason: stats.timing.close_reason_summary(),
                train_loss: stats.mean_loss(),
                test_accuracy: acc,
                test_loss: tloss,
                consensus: self.consensus(),
                steps: stats.step_count,
                report_p50_s: report_p50,
                report_p90_s: report_p90,
                report_p99_s: report_p99,
                // The legacy loop predates the control plane and never
                // hosts a controller.
                decision: "-".into(),
            };
            if self.verbose {
                eprintln!(
                    "[legacy {}] round {:>3}  loss {:.4}  sim {:.1}s",
                    self.cfg.algorithm.name(),
                    rec.round,
                    rec.train_loss,
                    sim_time
                );
            }
            history.push(rec);
        }
        Ok(history)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{AlgorithmKind, ExperimentConfig};
    use crate::coordinator::Coordinator;
    use crate::metrics::best_accuracy;

    #[test]
    fn legacy_loop_learns_like_the_interpreter() {
        // The heavy bit-for-bit pins live in rust/tests/plan_equivalence.rs;
        // this in-crate smoke check just keeps the oracle runnable.
        let mut cfg = ExperimentConfig::quickstart();
        cfg.algorithm = AlgorithmKind::CeFedAvg;
        cfg.rounds = 4;
        let h_new = Coordinator::from_config(&cfg).unwrap().run().unwrap();
        let h_old = Coordinator::from_config(&cfg).unwrap().run_legacy().unwrap();
        assert_eq!(h_new.len(), h_old.len());
        for (a, b) in h_new.iter().zip(&h_old) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits());
        }
        assert!(best_accuracy(&h_old) > 0.2);
    }
}
