//! Transport-agnostic cluster execution — the seam the multi-process
//! runtime splits the plan interpreter along.
//!
//! [`ClusterExecutor`] abstracts "run an edge phase for the clusters you
//! own": [`LocalExecutor`] runs it in-process (a full [`Coordinator`]
//! restricted to a subset), and `rpc::RemoteExecutor` ships the same
//! calls over a socket to a `cfel-edge` process. [`DistRunner`] is the
//! cloud-side interpreter: it mirrors the full world (every piece of the
//! world is a deterministic function of the config, which round-trips
//! f64-exactly through JSON), fans each [`crate::plan::Step::EdgePhase`]
//! out to the executors, folds the returned [`ClusterPhase`]s back in
//! ascending cluster order, and runs gossip / cloud aggregation / eval
//! on the mirror. Because the fold order is fixed cloud-side — not by
//! message arrival — the distributed history is bit-identical to
//! [`Coordinator::run`] (pinned by `rust/tests/distributed_equivalence.rs`).

use std::time::Instant;

use crate::config::{ExperimentConfig, LatencyMode, SecaggMode};
use crate::coordinator::{ClusterPhase, Coordinator, RoundStats};
use crate::error::{CfelError, Result};
use crate::metrics::{report_quantiles, History, RoundRecord};
use crate::netsim::{DeviceTimings, EventDrivenEstimator, RoundTiming, UploadChannel};
use crate::plan::Step;
use crate::util::stats::merge_steps;

/// One party that executes edge phases for a fixed set of clusters.
///
/// The phase API is split into `start_phase` / `finish_phase` so a
/// driver can issue the work order to *every* executor before collecting
/// any result — remote edges then train concurrently, while the collect
/// loop (executor order = ascending cluster order) keeps the merge
/// deterministic.
pub trait ClusterExecutor {
    /// The clusters this executor owns (ascending).
    fn clusters(&self) -> &[usize];

    /// Apply the round boundary (scheduled fault + timeline events) for
    /// `round`, then install `policies` — the driver's full per-cluster
    /// close-policy override set for the round (empty = config-wide
    /// policy everywhere). Each executor replays the boundary itself —
    /// world changes are a deterministic function of (config, round), so
    /// no state needs shipping; the overrides *are* shipped because the
    /// controller decides cloud-side only (edges never see telemetry).
    fn begin_round(&mut self, round: usize, policies: &[(usize, String)]) -> Result<()>;

    /// Issue the edge-phase work order (may return before the work is
    /// done).
    fn start_phase(&mut self, phase: u64, epochs: usize, channel: UploadChannel) -> Result<()>;

    /// Collect the outcome of the last `start_phase`: one
    /// [`ClusterPhase`] per owned alive cluster, ascending, with models
    /// collected.
    fn finish_phase(&mut self) -> Result<Vec<ClusterPhase>>;

    /// Install models / virtual clocks computed elsewhere (gossip and
    /// cloud aggregation happen on the driver's mirror).
    fn set_state(&mut self, models: &[(usize, &[f32])], clocks: &[(usize, f64)]) -> Result<()>;

    /// Rebuild the executor's world from scratch: fresh state from the
    /// config, the round boundaries `0..rounds_applied` replayed, then
    /// `models` / `clocks` / `policies` installed. Used when a failed
    /// round is retried — every executor restarts from the driver's
    /// snapshot. The recovery path replays `reinit` *without* a
    /// `begin_round`, so the current policy overrides must ride here too.
    fn reinit(
        &mut self,
        rounds_applied: usize,
        models: &[(usize, &[f32])],
        clocks: &[(usize, f64)],
        policies: &[(usize, String)],
    ) -> Result<()>;

    /// Release the executor (close connections; no-op in-process).
    fn shutdown(&mut self) -> Result<()>;
}

/// Partition `n_clusters` clusters over `n_executors` parties into
/// contiguous ascending ranges, spreading the remainder over the first
/// ranges — the same remainder-spread as the device layout
/// (`ExperimentConfig::cluster_sizes`).
pub fn partition_clusters(n_clusters: usize, n_executors: usize) -> Vec<Vec<usize>> {
    let n_executors = n_executors.max(1);
    let base = n_clusters / n_executors;
    let extra = n_clusters % n_executors;
    let mut out = Vec::with_capacity(n_executors);
    let mut next = 0usize;
    for slot in 0..n_executors {
        let take = base + usize::from(slot < extra);
        out.push((next..next + take).collect());
        next += take;
    }
    out
}

/// In-process [`ClusterExecutor`]: a full [`Coordinator`] that only ever
/// runs edge phases for its owned subset. This is both the reference
/// implementation the remote one is pinned against and the cheap way to
/// exercise the distributed driver without sockets.
pub struct LocalExecutor {
    cfg: ExperimentConfig,
    coord: Coordinator,
    owned: Vec<usize>,
    pending_phase: Option<(u64, usize, UploadChannel)>,
}

impl LocalExecutor {
    /// Build the executor's world from the config; `owned` are the
    /// cluster indices it will execute (ascending).
    pub fn new(cfg: &ExperimentConfig, owned: Vec<usize>) -> Result<LocalExecutor> {
        let coord = Coordinator::from_config(cfg)?;
        Ok(LocalExecutor {
            cfg: cfg.clone(),
            coord,
            owned,
            pending_phase: None,
        })
    }
}

/// Install `(cluster, state)` pairs into a coordinator.
pub(crate) fn install_state(
    coord: &mut Coordinator,
    models: &[(usize, &[f32])],
    clocks: &[(usize, f64)],
) -> Result<()> {
    for &(ci, m) in models {
        let dst = coord
            .clusters
            .get_mut(ci)
            .ok_or_else(|| CfelError::Runtime(format!("set_state: no cluster {ci}")))?;
        if dst.model.len() != m.len() {
            return Err(CfelError::Runtime(format!(
                "set_state: cluster {ci} model has {} params, got {}",
                dst.model.len(),
                m.len()
            )));
        }
        dst.model.copy_from_slice(m);
    }
    for &(ci, t) in clocks {
        if ci >= coord.cluster_clock_s.len() {
            return Err(CfelError::Runtime(format!("set_state: no cluster {ci}")));
        }
        coord.cluster_clock_s[ci] = t;
    }
    Ok(())
}

/// Rebuild a coordinator from its config and replay the round boundaries
/// `0..rounds_applied` (fault + timeline, in round order) so its world
/// matches a driver that has started round `rounds_applied - 1`.
pub(crate) fn rebuild_world(cfg: &ExperimentConfig, rounds_applied: usize) -> Result<Coordinator> {
    let mut coord = Coordinator::from_config(cfg)?;
    for round in 0..rounds_applied {
        coord.apply_fault(round)?;
        coord.apply_timeline(round)?;
    }
    Ok(coord)
}

impl ClusterExecutor for LocalExecutor {
    fn clusters(&self) -> &[usize] {
        &self.owned
    }

    fn begin_round(&mut self, round: usize, policies: &[(usize, String)]) -> Result<()> {
        self.coord.apply_fault(round)?;
        self.coord.apply_timeline(round)?;
        self.coord.set_cluster_policies(policies)
    }

    fn start_phase(&mut self, phase: u64, epochs: usize, channel: UploadChannel) -> Result<()> {
        self.pending_phase = Some((phase, epochs, channel));
        Ok(())
    }

    fn finish_phase(&mut self) -> Result<Vec<ClusterPhase>> {
        let (phase, epochs, channel) = self
            .pending_phase
            .take()
            .ok_or_else(|| CfelError::Runtime("finish_phase without start_phase".into()))?;
        let owned = self.owned.clone();
        self.coord.edge_phase_on(&owned, epochs, phase, channel, true)
    }

    fn set_state(&mut self, models: &[(usize, &[f32])], clocks: &[(usize, f64)]) -> Result<()> {
        install_state(&mut self.coord, models, clocks)
    }

    fn reinit(
        &mut self,
        rounds_applied: usize,
        models: &[(usize, &[f32])],
        clocks: &[(usize, f64)],
        policies: &[(usize, String)],
    ) -> Result<()> {
        self.coord = rebuild_world(&self.cfg, rounds_applied)?;
        self.pending_phase = None;
        install_state(&mut self.coord, models, clocks)?;
        self.coord.set_cluster_policies(policies)
    }

    fn shutdown(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Replacement-executor factory used when a round is retried after a
/// transport failure: given the failed executor's slot, produce a fresh
/// executor owning the same clusters (e.g. accept a reconnecting
/// `cfel-edge`).
pub type RecoverFn = Box<dyn FnMut(usize) -> Result<Box<dyn ClusterExecutor>>>;

/// Snapshot of the mirror's per-cluster state at a round boundary (after
/// fault/timeline application) — what a retried round restarts from.
struct BoundarySnapshot {
    models: Vec<Vec<f32>>,
    clocks: Vec<f64>,
    /// The global edge-phase cursor at the boundary (the controller may
    /// have rewritten the plan, so the cursor is state, not arithmetic).
    cursor: u64,
}

/// The cloud-side distributed plan interpreter. See the module docs.
pub struct DistRunner {
    coord: Coordinator,
    executors: Vec<Box<dyn ClusterExecutor>>,
    /// Executor slot owning each cluster.
    owner: Vec<usize>,
    recovery: Option<RecoverFn>,
    /// Transport failures tolerated per run (each consumes a full
    /// round retry).
    max_retries: usize,
    /// Per-cluster pending-report depth after the last edge phase. A
    /// retry is only sound from an empty pending state: kept-late model
    /// payloads live edge-side only and die with the edge process.
    last_pending: Vec<usize>,
    /// The controller's per-cluster policy overrides for the round in
    /// flight — decided once per boundary on the mirror, shipped with
    /// every `begin_round`/`reinit` so retries replay the same decision.
    current_policies: Vec<(usize, String)>,
    pub verbose: bool,
}

impl DistRunner {
    /// Build the driver: a full mirror world from `cfg` plus the
    /// executors. The executors' cluster sets must concatenate, in
    /// order, to exactly `0..n_clusters` — executor order is merge
    /// order, and the merge must be ascending cluster order.
    pub fn new(
        cfg: &ExperimentConfig,
        executors: Vec<Box<dyn ClusterExecutor>>,
    ) -> Result<DistRunner> {
        let coord = Coordinator::from_config(cfg)?;
        let n = coord.clusters.len();
        let mut owner = vec![0usize; n];
        let mut next = 0usize;
        for (slot, ex) in executors.iter().enumerate() {
            for &ci in ex.clusters() {
                if ci != next {
                    return Err(CfelError::Config(format!(
                        "executor {slot}: expected cluster {next}, owns {ci} — executor \
                         cluster sets must concatenate to 0..{n} in ascending order"
                    )));
                }
                owner[ci] = slot;
                next += 1;
            }
        }
        if next != n {
            return Err(CfelError::Config(format!(
                "executors cover {next} of {n} clusters"
            )));
        }
        Ok(DistRunner {
            coord,
            executors,
            owner,
            recovery: None,
            max_retries: 0,
            last_pending: vec![0; n],
            current_policies: Vec::new(),
            verbose: false,
        })
    }

    /// Enable round-retry recovery: on a transport failure, `recover` is
    /// called with the failed executor's slot to produce a replacement,
    /// every executor is reinitialized from the boundary snapshot, and
    /// the round is re-run. At most `max_retries` failures are tolerated.
    pub fn with_recovery(mut self, recover: RecoverFn, max_retries: usize) -> DistRunner {
        self.recovery = Some(recover);
        self.max_retries = max_retries;
        self
    }

    /// Read access to the mirror world (tests).
    pub fn mirror(&self) -> &Coordinator {
        &self.coord
    }

    fn begin_all(&mut self, round: usize) -> Result<()> {
        for ex in &mut self.executors {
            ex.begin_round(round, &self.current_policies)?;
        }
        Ok(())
    }

    /// Push the mirror's models + clocks to every executor (after a
    /// gossip or cloud-aggregation step rewrote them cloud-side).
    fn push_state(&mut self) -> Result<()> {
        let models: Vec<(usize, &[f32])> = self
            .coord
            .clusters
            .iter()
            .enumerate()
            .map(|(ci, c)| (ci, c.model.as_slice()))
            .collect();
        let clocks: Vec<(usize, f64)> = self
            .coord
            .cluster_clock_s
            .iter()
            .copied()
            .enumerate()
            .collect();
        for ex in &mut self.executors {
            ex.set_state(&models, &clocks)?;
        }
        Ok(())
    }

    /// Distributed mirror of [`Coordinator::plan_round`].
    fn plan_round_dist(&mut self, _round: usize) -> Result<RoundStats> {
        let plan = self.coord.plan.clone();
        // Same running cursor as `Coordinator::plan_round`; advanced only
        // on success, so a retried round restarts from the same phase
        // numbering.
        let base_phase = self.coord.phase_cursor;
        let mut stats = RoundStats {
            timing: RoundTiming {
                device_timings: DeviceTimings::acquire(0),
                ..RoundTiming::default()
            },
            ..RoundStats::default()
        };
        let mut idx = 0u64;
        self.exec_steps_dist(&plan.steps, base_phase, &mut idx, &mut stats)?;
        self.coord.phase_cursor = base_phase + idx;
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }

    fn exec_steps_dist(
        &mut self,
        steps: &[Step],
        base_phase: u64,
        idx: &mut u64,
        stats: &mut RoundStats,
    ) -> Result<()> {
        for step in steps {
            match step {
                Step::EdgePhase { epochs, channel } => {
                    let phase = base_phase + *idx;
                    // Fan out first so remote edges train concurrently …
                    for ex in &mut self.executors {
                        ex.start_phase(phase, *epochs, *channel)?;
                    }
                    // … then collect in executor order = ascending
                    // cluster order: the merge order is fixed here, not
                    // by message arrival.
                    let mut phases: Vec<ClusterPhase> = Vec::new();
                    for ex in &mut self.executors {
                        phases.extend(ex.finish_phase()?);
                    }
                    for p in &mut phases {
                        let ci = p.cluster;
                        if let Some(sum) = p.masked.take() {
                            // Masked phase: the wire carried the encoded
                            // sum instead of a plain model. Decode with
                            // the same deterministic function the edge
                            // used for its local mirror — both sides land
                            // on the identical f32 model bit-for-bit.
                            let SecaggMode::Mask(bits) = self.coord.cfg.secagg else {
                                return Err(CfelError::Runtime(format!(
                                    "phase result for cluster {ci} carries a masked \
                                     sum, but secagg mask mode is not enabled"
                                )));
                            };
                            if sum.words.len() != self.coord.clusters[ci].model.len()
                                || !p.model.is_empty()
                            {
                                return Err(CfelError::Runtime(format!(
                                    "masked phase result for cluster {ci} carries {} \
                                     words + {} params, expected {} words and an \
                                     empty model",
                                    sum.words.len(),
                                    p.model.len(),
                                    self.coord.clusters[ci].model.len()
                                )));
                            }
                            let decoded = crate::secagg::decode_sum(&sum, bits);
                            self.coord.clusters[ci].model.copy_from_slice(&decoded);
                        } else {
                            if p.model.len() != self.coord.clusters[ci].model.len() {
                                return Err(CfelError::Runtime(format!(
                                    "phase result for cluster {ci} carries {} params, \
                                     expected {}",
                                    p.model.len(),
                                    self.coord.clusters[ci].model.len()
                                )));
                            }
                            self.coord.clusters[ci].model = std::mem::take(&mut p.model);
                        }
                        if p.timing.is_some() {
                            self.coord.cluster_clock_s[ci] = p.clock_s;
                        }
                        self.last_pending[ci] = p.pending_after;
                    }
                    Coordinator::fold_phases(stats, &phases, self.coord.clusters.len());
                    for p in phases {
                        if let Some(pt) = p.timing {
                            pt.devices.recycle();
                        }
                    }
                    *idx += 1;
                }
                Step::Gossip { pi } => {
                    self.coord.mix_gossip(*pi);
                    if self.coord.cfg.latency == LatencyMode::EventDriven {
                        let hops_s =
                            EventDrivenEstimator::simulate_gossip(&self.coord.net, *pi as usize).0;
                        stats.timing.gossip_s += hops_s;
                        self.coord.barrier_clocks(hops_s);
                    }
                    self.push_state()?;
                }
                Step::CloudAggregate => {
                    if self.coord.aggregator_alive {
                        self.coord.cloud_aggregate()?;
                        self.coord.barrier_clocks(0.0);
                        self.push_state()?;
                    }
                }
                Step::Repeat { n, body } => {
                    for _ in 0..*n {
                        self.exec_steps_dist(body, base_phase, idx, stats)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Restore the mirror to the boundary snapshot, replace the failed
    /// executor, and reinitialize every executor from the snapshot with
    /// rounds `0..=round` boundaries replayed.
    fn recover_round(
        &mut self,
        round: usize,
        snap: &BoundarySnapshot,
        failed_cluster: Option<usize>,
    ) -> Result<()> {
        for (ci, m) in snap.models.iter().enumerate() {
            self.coord.clusters[ci].model.copy_from_slice(m);
        }
        self.coord.cluster_clock_s.copy_from_slice(&snap.clocks);
        self.coord.phase_cursor = snap.cursor;
        if let Some(ci) = failed_cluster {
            let slot = self.owner[ci];
            let recover = self
                .recovery
                .as_mut()
                .expect("recover_round called without recovery");
            let fresh = recover(slot)?;
            if fresh.clusters() != self.executors[slot].clusters() {
                return Err(CfelError::Config(format!(
                    "replacement executor for slot {slot} owns {:?}, expected {:?}",
                    fresh.clusters(),
                    self.executors[slot].clusters()
                )));
            }
            let _ = self.executors[slot].shutdown();
            self.executors[slot] = fresh;
        }
        let models: Vec<(usize, &[f32])> = snap
            .models
            .iter()
            .enumerate()
            .map(|(ci, m)| (ci, m.as_slice()))
            .collect();
        let clocks: Vec<(usize, f64)> = snap.clocks.iter().copied().enumerate().collect();
        for ex in &mut self.executors {
            ex.reinit(round + 1, &models, &clocks, &self.current_policies)?;
        }
        Ok(())
    }

    /// Drive the configured number of global rounds; bit-identical to
    /// [`Coordinator::run`] on the same config (all but `wall_time_s`,
    /// which is real elapsed time in both modes).
    pub fn run(&mut self) -> Result<History> {
        let label = self.coord.cfg.run_label();
        let mut history = History::new();
        let mut sim_time = 0.0f64;
        let mut wall = 0.0f64;
        let rounds = self.coord.cfg.rounds;
        let mut retries_left = self.max_retries;
        let mut round = 0usize;
        let mut boundary_done = false;
        let mut skip_begin = false;
        let mut snapshot = BoundarySnapshot {
            models: Vec::new(),
            clocks: Vec::new(),
            cursor: 0,
        };
        while round < rounds {
            let t0 = Instant::now();
            if !boundary_done {
                self.coord.apply_fault(round)?;
                self.coord.apply_timeline(round)?;
                // The controller decides exactly once per boundary, on
                // the mirror; a retried round replays the same override
                // set from `current_policies`, never re-decides.
                self.coord.control_round(round)?;
                self.current_policies = self.coord.policy_overrides();
                // Snapshot *after* the boundary: fault/timeline events
                // must apply exactly once, so a retried round restores
                // this state and skips re-application.
                snapshot = BoundarySnapshot {
                    models: self.coord.clusters.iter().map(|c| c.model.clone()).collect(),
                    clocks: self.coord.cluster_clock_s.clone(),
                    cursor: self.coord.phase_cursor,
                };
                boundary_done = true;
            }
            let res = if skip_begin {
                self.plan_round_dist(round)
            } else {
                self.begin_all(round).and_then(|()| self.plan_round_dist(round))
            };
            let mut stats = match res {
                Ok(s) => s,
                Err(e) => {
                    let retryable = matches!(e, CfelError::Transport { .. })
                        && self.recovery.is_some()
                        && retries_left > 0
                        && self.last_pending.iter().all(|&p| p == 0);
                    if !retryable {
                        return Err(e);
                    }
                    let CfelError::Transport { cluster, message } = e else {
                        unreachable!("retryable implies Transport");
                    };
                    retries_left -= 1;
                    if self.verbose {
                        eprintln!(
                            "[dist] round {round}: transport failure ({message}); \
                             recovering and retrying"
                        );
                    }
                    self.recover_round(round, &snapshot, cluster)?;
                    skip_begin = true;
                    continue;
                }
            };
            wall += t0.elapsed().as_secs_f64();
            let lat = self.coord.round_latency(&stats);
            sim_time += lat.total();

            let (acc, tloss) =
                if (round + 1) % self.coord.cfg.eval_every == 0 || round + 1 == rounds {
                    self.coord.evaluate()?
                } else {
                    (f64::NAN, f64::NAN)
                };
            let (report_p50_s, report_p90_s, report_p99_s) =
                report_quantiles(&stats.timing.device_timings.finish_s);
            let rec = RoundRecord {
                round: round + 1,
                sim_time_s: sim_time,
                wall_time_s: wall,
                compute_s: lat.compute_s,
                upload_s: lat.upload_s,
                backhaul_s: lat.backhaul_s,
                dropped_devices: stats.timing.dropped_devices,
                on_time_devices: stats.timing.on_time_devices,
                late_devices: stats.timing.late_devices,
                stale_merged: stats.timing.stale_merged,
                close_reason: stats.timing.close_reason_summary(),
                train_loss: stats.mean_loss(),
                test_accuracy: acc,
                test_loss: tloss,
                consensus: self.coord.consensus(),
                steps: stats.step_count,
                report_p50_s,
                report_p90_s,
                report_p99_s,
                secagg_mask_s: stats.timing.secagg_mask_s,
                secagg_extra_bits: stats.timing.secagg_extra_bits,
                decision: self.coord.take_decision_note(),
            };
            if self.verbose {
                eprintln!(
                    "[{}|dist] round {:>3}  loss {:.4}  acc {}  sim {:.1}s",
                    label,
                    rec.round,
                    rec.train_loss,
                    if acc.is_nan() {
                        "  -  ".to_string()
                    } else {
                        format!("{acc:.4}")
                    },
                    sim_time
                );
            }
            history.push(rec);
            // Telemetry extraction must precede the recycle — the mirror
            // feeds the next boundary's decision exactly as the
            // in-process interpreter does.
            self.coord.capture_telemetry(round, &stats, &lat);
            stats.timing.recycle();
            boundary_done = false;
            skip_begin = false;
            round += 1;
        }
        for ex in &mut self.executors {
            let _ = ex.shutdown();
        }
        Ok(history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_spreads_remainder_over_first_slots() {
        assert_eq!(partition_clusters(5, 2), vec![vec![0, 1, 2], vec![3, 4]]);
        assert_eq!(partition_clusters(4, 4), vec![vec![0], vec![1], vec![2], vec![3]]);
        assert_eq!(partition_clusters(2, 3), vec![vec![0], vec![1], vec![]]);
        let flat: Vec<usize> = partition_clusters(7, 3).concat();
        assert_eq!(flat, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn runner_rejects_bad_partitions() {
        let cfg = ExperimentConfig::quickstart();
        let a = LocalExecutor::new(&cfg, vec![0, 1]).unwrap();
        let b = LocalExecutor::new(&cfg, vec![3, 2]).unwrap();
        let exs: Vec<Box<dyn ClusterExecutor>> = vec![Box::new(a), Box::new(b)];
        let err = DistRunner::new(&cfg, exs).unwrap_err();
        assert!(err.to_string().contains("ascending order"), "{err}");

        let a = LocalExecutor::new(&cfg, vec![0, 1]).unwrap();
        let exs: Vec<Box<dyn ClusterExecutor>> = vec![Box::new(a)];
        let err = DistRunner::new(&cfg, exs).unwrap_err();
        assert!(err.to_string().contains("covers 2 of 4"), "{err}");
    }
}
