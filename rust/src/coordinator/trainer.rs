//! Device-local training: τ epochs of mini-batch SGD from the edge model
//! (paper Eqs. 4–5, epoch semantics following Reddi et al. [42]).

use crate::coordinator::Coordinator;
use crate::data::sampler::EpochSampler;
use crate::data::Dataset;
use crate::error::Result;
use crate::model::ModelState;
use crate::runtime::TrainBackend;
use crate::util::rng::Rng;
use crate::util::threadpool::{default_threads, parallel_map};

/// Result of one device's local run within an edge round.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    /// Final local model x^{(k)}_{l,r,τ}.
    pub params: Vec<f32>,
    /// SGD steps executed (netsim Eq. 8 workload).
    pub steps: usize,
    pub loss_sum: f64,
    /// Local sample count |D_k| (aggregation weight).
    pub n_samples: usize,
}

/// Train one device for `epochs` local epochs starting from `init_params`
/// (momentum starts at zero — devices are stateless between rounds).
pub fn train_device(
    backend: &dyn TrainBackend,
    data: &Dataset,
    init_params: &[f32],
    epochs: usize,
    lr: f32,
    rng: Rng,
) -> Result<LocalOutcome> {
    let mut state = ModelState::from_params(init_params.to_vec());
    let mut sampler = EpochSampler::new(data.len(), backend.batch_size(), rng);
    let mut steps = 0usize;
    let mut loss_sum = 0.0f64;
    for _ in 0..epochs {
        for batch in sampler.epoch_batches(data) {
            let loss = backend.train_step(&mut state, &batch, lr)?;
            loss_sum += loss as f64;
            steps += 1;
        }
    }
    Ok(LocalOutcome {
        params: state.params,
        steps,
        loss_sum,
        n_samples: data.len(),
    })
}

impl Coordinator {
    /// Run one edge round for cluster `ci`: the sampled participants
    /// (config `participation`, classic FedAvg client sampling) each
    /// train `epochs` epochs from the current edge model, in parallel
    /// when the backend allows it. RNG streams are derived from
    /// (seed, device, phase) so results are identical regardless of
    /// thread count. Returns `(device_id, outcome)` pairs; the uploads
    /// have already been passed through the configured lossy compressor
    /// (what the edge server actually receives).
    pub(crate) fn train_cluster(
        &self,
        ci: usize,
        epochs: usize,
        phase: u64,
    ) -> Result<Vec<(usize, LocalOutcome)>> {
        let cluster = &self.clusters[ci];
        let participants = self.sample_participants(ci, phase);
        let n = participants.len();
        let threads = if self.backend.parallel_devices() {
            default_threads(n)
        } else {
            1
        };
        let results: Vec<Result<LocalOutcome>> = parallel_map(n, threads, |slot| {
            let dev = participants[slot];
            let rng = self
                .rng
                .split(0x5EED_0000 + dev as u64)
                .split(phase);
            let mut out = train_device(
                &*self.backend,
                &self.fed.device_train[dev],
                &cluster.model,
                epochs,
                self.cfg.lr,
                rng,
            )?;
            // Device -> edge upload: the server sees the lossy model.
            self.cfg.compression.roundtrip(&mut out.params);
            Ok(out)
        });
        results
            .into_iter()
            .zip(participants)
            .map(|(r, dev)| r.map(|o| (dev, o)))
            .collect()
    }

    /// Deterministic participant sample for (cluster, phase).
    fn sample_participants(&self, ci: usize, phase: u64) -> Vec<usize> {
        let ids = &self.clusters[ci].device_ids;
        if self.cfg.participation >= 1.0 {
            return ids.clone();
        }
        let k = ((ids.len() as f64 * self.cfg.participation).ceil() as usize)
            .clamp(1, ids.len());
        let mut rng = self
            .rng
            .split(0x9A27_0000 + ci as u64)
            .split(phase);
        let mut picks = rng.choose(ids.len(), k);
        picks.sort_unstable(); // stable aggregation order
        picks.into_iter().map(|slot| ids[slot]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Prototypes, SyntheticSpec};
    use crate::runtime::MockBackend;

    fn fixture() -> (MockBackend, Dataset) {
        let be = MockBackend::mlp_synth();
        let protos = Prototypes::new(SyntheticSpec::mlp_synth(), &Rng::new(1));
        let ds = protos.global_pool(48, &Rng::new(2));
        (be, ds)
    }

    #[test]
    fn steps_match_epoch_math() {
        let (be, ds) = fixture();
        let init = be.init_state(&Rng::new(3)).params;
        let out = train_device(&be, &ds, &init, 2, 0.05, Rng::new(4)).unwrap();
        // 48 samples / batch 16 = 3 batches per epoch; 2 epochs = 6 steps.
        assert_eq!(out.steps, 6);
        assert_eq!(out.n_samples, 48);
        assert_eq!(out.params.len(), be.param_count());
    }

    #[test]
    fn training_moves_params_and_reduces_loss() {
        let (be, ds) = fixture();
        let init = be.init_state(&Rng::new(3)).params;
        let out1 = train_device(&be, &ds, &init, 1, 0.1, Rng::new(4)).unwrap();
        let out8 = train_device(&be, &ds, &init, 8, 0.1, Rng::new(4)).unwrap();
        assert_ne!(out1.params, init);
        let mean1 = out1.loss_sum / out1.steps as f64;
        let mean8 = out8.loss_sum / out8.steps as f64;
        assert!(mean8 < mean1, "{mean8} !< {mean1}");
    }

    #[test]
    fn deterministic_given_rng() {
        let (be, ds) = fixture();
        let init = be.init_state(&Rng::new(3)).params;
        let a = train_device(&be, &ds, &init, 2, 0.1, Rng::new(7)).unwrap();
        let b = train_device(&be, &ds, &init, 2, 0.1, Rng::new(7)).unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(a.loss_sum, b.loss_sum);
    }

    #[test]
    fn momentum_starts_fresh() {
        // Two successive calls from the same init give identical results —
        // no hidden state leaks between local rounds.
        let (be, ds) = fixture();
        let init = be.init_state(&Rng::new(3)).params;
        let a = train_device(&be, &ds, &init, 1, 0.1, Rng::new(9)).unwrap();
        let b = train_device(&be, &ds, &init, 1, 0.1, Rng::new(9)).unwrap();
        assert_eq!(a.params, b.params);
    }
}
