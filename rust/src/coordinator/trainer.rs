//! Device-local training: τ epochs of mini-batch SGD from the edge model
//! (paper Eqs. 4–5, epoch semantics following Reddi et al. [42]).

use crate::aggregation::policy::{AggregationPolicy, ReportVerdict};
use crate::config::SecaggMode;
use crate::coordinator::{
    ClusterState, Coordinator, PendingReport, RoundContext, RoundStats, WeightedReport,
};
use crate::data::sampler::EpochSampler;
use crate::data::Dataset;
use crate::error::Result;
use crate::model::ModelState;
use crate::netsim::{PhaseTiming, UploadChannel};
use crate::runtime::TrainBackend;
use crate::secagg::{self, MaskedSum};
use crate::util::rng::Rng;
use crate::util::threadpool::{default_threads, parallel_map};

/// Result of one device's local run within an edge round.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    /// Final local model x^{(k)}_{l,r,τ}.
    pub params: Vec<f32>,
    /// SGD steps executed (netsim Eq. 8 workload).
    pub steps: usize,
    pub loss_sum: f64,
    /// Local sample count |D_k| (aggregation weight).
    pub n_samples: usize,
}

/// Everything one cluster produced in one edge phase: per-device training
/// reports, the post-aggregation edge model, the advanced virtual clock,
/// and the phase timing columns. This is the unit of work a
/// [`ClusterExecutor`](crate::coordinator::executor::ClusterExecutor)
/// hands back — computed in-process or shipped over the wire — and the
/// cloud folds phases into round stats in ascending cluster order, which
/// is what keeps distributed mode bit-identical to the single process
/// (docs/DETERMINISM.md).
#[derive(Debug, Clone, Default)]
pub struct ClusterPhase {
    /// Cluster index this phase belongs to.
    pub cluster: usize,
    /// `(device, sgd_steps, loss_sum)` for every *trained* participant in
    /// deterministic participant order — including reports a close policy
    /// later dropped, because round-level loss/step stats count all
    /// trained work (exactly as the in-process merge loop always has).
    pub reports: Vec<(usize, usize, f64)>,
    /// Post-aggregation edge model. Left empty unless the caller asked
    /// for models to be collected (the in-process path reads the cluster
    /// state directly and skips the copy).
    pub model: Vec<f32>,
    /// The cluster's absolute virtual clock after the phase close (event
    /// mode; unchanged in closed-form mode).
    pub clock_s: f64,
    /// Event-mode phase timing columns; `None` in closed-form mode. The
    /// consumer owns the buffers and must recycle `timing.devices`.
    pub timing: Option<PhaseTiming>,
    /// Kept-late reports folded into this close (semi-sync).
    pub stale_merged: usize,
    /// Reports still parked in the cluster's pending queue afterwards.
    pub pending_after: usize,
    /// The masked (still-encoded) aggregate sum, shipped instead of
    /// `model` when the phase ran over the masked channel in mask mode
    /// and the caller asked for models: the wire carries only masked
    /// fixed-point words, and the consumer decodes with the same
    /// deterministic [`crate::secagg::decode_sum`] the edge used for its
    /// local mirror, so both sides land on the identical f32 model.
    pub masked: Option<MaskedSum>,
    /// Mask-generation + fixed-point-encode compute charged to this
    /// phase's participants, seconds (mask mode only; zero otherwise).
    pub secagg_mask_s: f64,
    /// Upload inflation the masked encoding added over the plain
    /// (post-compression) model payload, bits across all participants.
    pub secagg_extra_bits: f64,
}

/// Train one device for `epochs` local epochs starting from `init_params`
/// (momentum starts at zero — devices are stateless between rounds).
pub fn train_device(
    backend: &dyn TrainBackend,
    data: &Dataset,
    init_params: &[f32],
    epochs: usize,
    lr: f32,
    rng: Rng,
) -> Result<LocalOutcome> {
    let mut state = ModelState::from_params(init_params.to_vec());
    let mut sampler = EpochSampler::new(data.len(), backend.batch_size(), rng);
    let mut steps = 0usize;
    let mut loss_sum = 0.0f64;
    for _ in 0..epochs {
        for batch in sampler.epoch_batches(data) {
            let loss = backend.train_step(&mut state, &batch, lr)?;
            loss_sum += loss as f64;
            steps += 1;
        }
    }
    Ok(LocalOutcome {
        params: state.params,
        steps,
        loss_sum,
        n_samples: data.len(),
    })
}

/// Sum one cluster's surviving masked uploads (Bonawitz-style pairwise
/// masking, [`crate::secagg`]). Each on-time device contributes its
/// fixed-point-encoded, sample-weighted, masked upload; pair masks
/// between two survivors cancel in the wrapping-u64 sum, and every
/// participant that dropped between sampling and the phase close leaves
/// dangling shares that [`secagg::recover_dropouts`] re-derives from the
/// run RNG and subtracts. Wrapping addition is associative and
/// commutative, so the sum is independent of accumulation order — the
/// masked path inherits the engine's bit-determinism for free.
fn masked_cluster_sum(
    on_time: &[(usize, LocalOutcome)],
    participants: &[usize],
    bits: u32,
    root: &Rng,
    phase: u64,
) -> MaskedSum {
    let mut words: Vec<u64> = Vec::new();
    let mut total_weight = 0u64;
    for (dev, out) in on_time {
        let upload = secagg::masked_upload(
            &out.params,
            bits,
            out.n_samples as u64,
            root,
            phase,
            *dev,
            participants,
        );
        secagg::accumulate(&mut words, &upload);
        total_weight += out.n_samples as u64;
    }
    let survivors: Vec<usize> = on_time.iter().map(|(dev, _)| *dev).collect();
    let dropped: Vec<usize> = participants
        .iter()
        .copied()
        .filter(|dev| !survivors.contains(dev))
        .collect();
    if !dropped.is_empty() {
        secagg::recover_dropouts(&mut words, root, phase, &survivors, &dropped);
    }
    MaskedSum { words, total_weight }
}

impl RoundContext<'_> {
    /// Deterministic participant sample for (cluster, phase) — classic
    /// FedAvg client sampling over the cluster's device roster.
    pub(crate) fn sample_participants(
        &self,
        cluster: &ClusterState,
        ci: usize,
        phase: u64,
    ) -> Vec<usize> {
        let ids = &cluster.device_ids;
        if self.cfg.participation >= 1.0 || ids.is_empty() {
            // A depopulated roster (timeline mass-leave) samples nobody;
            // clamping `k` to [1, 0] below would panic.
            return ids.clone();
        }
        let k = ((ids.len() as f64 * self.cfg.participation).ceil() as usize)
            .clamp(1, ids.len());
        let mut rng = self.cluster_rng(ci, phase);
        let mut picks = rng.choose(ids.len(), k);
        picks.sort_unstable(); // stable aggregation order
        picks.into_iter().map(|slot| ids[slot]).collect()
    }
}

impl Coordinator {
    /// One edge phase of a global round: every alive cluster trains its
    /// sampled participants `epochs` local epochs from its current edge
    /// model and aggregates intra-cluster (Eq. 6).
    ///
    /// This is the parallel cluster execution engine: the (cluster,
    /// device) work items of *all* alive clusters are flattened into one
    /// work list and run concurrently (when the backend allows it —
    /// the mock backend does; the non-`Send` PJRT executables keep the
    /// inline single-thread mode). Each device draws its RNG stream from
    /// the immutable [`RoundContext`] keyed by (device, phase), and both
    /// `RoundStats` and the per-cluster models are merged after the join
    /// in deterministic (alive-cluster, participant) order, so the
    /// result is bit-identical for any `CFEL_THREADS`.
    ///
    /// Device→edge uploads pass through the configured lossy compressor
    /// before aggregation (what the edge server actually receives).
    ///
    /// `channel` names the uplink this phase's reports travel over (edge
    /// for CE-FedAvg / Local-Edge / Hier-FAvg edge rounds, cloud for
    /// FedAvg and Hier-FAvg's final round). In event-driven latency mode
    /// every alive cluster's phase is additionally simulated after the
    /// join — one batched `phase_timings` call, each cluster a shard of
    /// the event engine — and closed by the configured
    /// `AggregationPolicy`: reports that miss
    /// the close are dropped from Eq. 6 (deadline-drop; survivor weights
    /// renormalize) or parked and folded into a *later* phase close of
    /// the same cluster with a `1/(1+s)^a` staleness discount
    /// (semi-sync). A cluster whose close yields no mergeable report
    /// keeps its previous edge model. Per-cluster virtual time
    /// accumulates into `stats.timing`, and each cluster's absolute
    /// clock advances to its close so late-report arrivals stay
    /// well-ordered across phases and rounds.
    pub(crate) fn edge_phase(
        &mut self,
        epochs: usize,
        phase: u64,
        channel: UploadChannel,
        stats: &mut RoundStats,
    ) -> Result<()> {
        let all: Vec<usize> = (0..self.clusters.len()).collect();
        let phases = self.edge_phase_on(&all, epochs, phase, channel, false)?;
        Self::fold_phases(stats, &phases, self.clusters.len());
        // The per-device columns were copied into `stats.timing` by the
        // fold; hand the phase buffers back to the free list so next
        // phase's expansion reuses the capacity.
        for p in phases {
            if let Some(pt) = p.timing {
                pt.devices.recycle();
            }
        }
        Ok(())
    }

    /// Fold per-cluster phase results into the round accumulator, in the
    /// order `phases` was produced (ascending cluster order). Distributed
    /// mode calls this cloud-side with phases collected from remote
    /// executors; because the fold — not the transport — fixes the merge
    /// order, the wire cannot reorder aggregation, and the f64
    /// `loss_sum` additions replay in the exact flattened
    /// (alive-cluster, participant) sequence of the single process.
    pub(crate) fn fold_phases(stats: &mut RoundStats, phases: &[ClusterPhase], n_clusters: usize) {
        for p in phases {
            for &(dev, steps, loss) in &p.reports {
                stats.device_steps.push((dev, steps));
                stats.loss_sum += loss;
                stats.step_count += steps;
            }
            // Secagg overhead accumulates in both latency modes (the
            // closed-form path has no `PhaseTiming`, so this sits outside
            // the conditional below).
            stats.timing.secagg_mask_s += p.secagg_mask_s;
            stats.timing.secagg_extra_bits += p.secagg_extra_bits;
            if let Some(pt) = &p.timing {
                stats.timing.record_phase(p.cluster, n_clusters, pt);
                stats.timing.stale_merged += p.stale_merged;
            }
        }
    }

    /// [`Coordinator::edge_phase`] restricted to the clusters in `subset`
    /// (ascending): train, close, and aggregate only those clusters,
    /// returning one [`ClusterPhase`] per alive subset member and leaving
    /// the round accumulator to the caller ([`Self::fold_phases`]). This
    /// is the executor building block: in-process mode passes every
    /// cluster; a distributed edge process passes the clusters it owns
    /// and ships the results back. Each cluster's training, close
    /// simulation, and Eq. 6 merge are pure functions of that cluster's
    /// own inputs, so a partitioned run is bit-identical to the
    /// single-process one.
    pub(crate) fn edge_phase_on(
        &mut self,
        subset: &[usize],
        epochs: usize,
        phase: u64,
        channel: UploadChannel,
        collect_models: bool,
    ) -> Result<Vec<ClusterPhase>> {
        let alive: Vec<usize> = subset
            .iter()
            .copied()
            .filter(|&ci| self.alive[ci])
            .collect();
        if alive.is_empty() {
            return Ok(Vec::new());
        }
        let parallel = self.backend.parallel_devices();

        // Secure aggregation engages only on the masked channel (config
        // validation guarantees a masked plan runs with secagg enabled
        // and vice versa, so the two flags below are never both set and
        // plain/cloud phases stay untouched bitwise).
        let mask_bits = match self.cfg.secagg {
            SecaggMode::Mask(b) if channel == UploadChannel::DeviceEdgeMasked => Some(b),
            _ => None,
        };
        let lossless = self.cfg.secagg == SecaggMode::Lossless
            && channel == UploadChannel::DeviceEdgeMasked;

        // ---- train: one flattened work item per (cluster, device) -----
        let ctx = self.round_ctx();
        let participants: Vec<Vec<usize>> = alive
            .iter()
            .map(|&ci| ctx.sample_participants(&self.clusters[ci], ci, phase))
            .collect();
        let items: Vec<(usize, usize)> = participants
            .iter()
            .enumerate()
            .flat_map(|(slot, devs)| devs.iter().map(move |&dev| (slot, dev)))
            .collect();
        let threads = if parallel {
            default_threads(items.len())
        } else {
            1
        };
        let clusters = &self.clusters;
        let trained: Vec<Result<LocalOutcome>> = parallel_map(items.len(), threads, |w| {
            let (slot, dev) = items[w];
            let mut out = train_device(
                ctx.backend,
                &ctx.fed.device_train[dev],
                &clusters[alive[slot]].model,
                epochs,
                ctx.cfg.lr,
                ctx.device_rng(dev, phase),
            )?;
            // Device -> edge upload: the server sees the lossy model.
            ctx.cfg.compression.roundtrip(&mut out.params);
            if lossless {
                // Degenerate secure aggregation: mask and unmask the raw
                // f32 bit patterns in place — a protocol identity (pinned
                // bitwise-equal to a plain run by
                // tests/secagg_equivalence.rs) that still walks every
                // pairwise seed derivation.
                secagg::lossless_roundtrip(
                    &mut out.params,
                    ctx.rng,
                    phase,
                    dev,
                    &participants[slot],
                );
            }
            Ok(out)
        });

        // ---- record reports + group per cluster (deterministic order) --
        let mut per_cluster: Vec<Vec<(usize, LocalOutcome)>> =
            participants.iter().map(|p| Vec::with_capacity(p.len())).collect();
        let mut phases: Vec<ClusterPhase> = alive
            .iter()
            .map(|&ci| ClusterPhase {
                cluster: ci,
                clock_s: self.cluster_clock_s[ci],
                pending_after: self.pending[ci].len(),
                ..ClusterPhase::default()
            })
            .collect();
        for (&(slot, dev), r) in items.iter().zip(trained) {
            let out = r?;
            phases[slot].reports.push((dev, out.steps, out.loss_sum));
            per_cluster[slot].push((dev, out));
        }

        // Charge the masking overhead (mask mode only — lossless leaves
        // `secagg_upload_bits` at 0 and costs nothing): every participant
        // pays the PRG + fixed-point-encode compute, and every upload
        // inflates from the plain `model_bits` payload to the dense
        // 64-bit masked encoding. The same costs flow into the latency
        // estimates via `NetworkModel::mask_seconds` / `upload_bits`;
        // these columns make the overhead visible in the round CSV.
        if mask_bits.is_some() && self.net.secagg_upload_bits > 0.0 {
            for (slot, devs) in participants.iter().enumerate() {
                phases[slot].secagg_mask_s = devs
                    .iter()
                    .map(|&d| self.net.mask_seconds(d, devs.len()))
                    .sum();
                phases[slot].secagg_extra_bits =
                    devs.len() as f64 * (self.net.secagg_upload_bits - self.net.model_bits);
            }
        }

        // ---- simulate the phase close + aggregate (Eq. 6) -------------
        // Event mode simulates every alive cluster's phase in one batched
        // `phase_timings` call (the event engine drains each cluster's
        // calendar shard on its own worker thread and merges the results
        // back in cluster order); closed-form mode (phase_timings →
        // None) keeps the Eq. 8 round-level path and aggregates every
        // outcome. Each shard's simulation is a pure function of its
        // cluster's inputs and the classify/aggregate loop below runs
        // single-threaded in alive-cluster order, so timing — including
        // which devices a policy drops or defers, and which stale reports
        // land in which phase — is independent of CFEL_THREADS
        // (docs/DETERMINISM.md). Aggregation writes straight into
        // each cluster's existing model buffer (O(m·p) averages are cheap
        // next to training); weights renormalize over the reports
        // present, and a cluster whose close produced no mergeable report
        // keeps its previous model (the `CfelError::Aggregation`
        // empty-set contract — here expressed as a skip rather than an
        // error).
        let work_lists: Vec<Vec<(usize, usize)>> = per_cluster
            .iter()
            .map(|outs| outs.iter().map(|(dev, out)| (*dev, out.steps)).collect())
            .collect();
        // Controller-installed per-cluster policies take the grouped
        // path; without overrides (every static run) this is the exact
        // single batched call the interpreter has always made.
        let has_overrides = alive.iter().any(|&ci| self.cluster_policy[ci].is_some());
        let pts_opt = if has_overrides {
            self.phase_timings_grouped(&alive, &work_lists, channel)
        } else {
            self.latency
                .phase_timings(&self.net, &work_lists, channel, &*self.policy)
        };
        let Some(pts) = pts_opt
        else {
            // Closed-form: no close policy in play, everyone merges.
            for (slot, &ci) in alive.iter().enumerate() {
                if !per_cluster[slot].is_empty() {
                    if let Some(bits) = mask_bits {
                        let sum = masked_cluster_sum(
                            &per_cluster[slot],
                            &participants[slot],
                            bits,
                            &self.rng,
                            phase,
                        );
                        let decoded = secagg::decode_sum(&sum, bits);
                        self.clusters[ci].model.copy_from_slice(&decoded);
                        if collect_models {
                            phases[slot].masked = Some(sum);
                        }
                    } else {
                        ClusterState::aggregate_into(
                            &per_cluster[slot],
                            &mut self.clusters[ci].model,
                        )?;
                    }
                }
                if collect_models && phases[slot].masked.is_none() {
                    phases[slot].model = self.clusters[ci].model.clone();
                }
            }
            return Ok(phases);
        };

        for ((slot, &ci), pt) in alive.iter().enumerate().zip(pts) {
            // Advance this cluster's absolute clock to the phase close.
            let start_abs = self.cluster_clock_s[ci];
            let close_abs = start_abs + pt.duration_s;
            self.cluster_clock_s[ci] = close_abs;

            // Drain kept-late reports that have arrived by this close
            // (semi-sync). Push order — (origin phase, work slot) — is
            // preserved, so the merge order is deterministic. Draining
            // *before* this phase's own late reports are parked below
            // makes it structurally impossible for a report to fold back
            // into the phase it just missed, even when f64 rounding of
            // `start_abs + finish_s` on a large clock would let the
            // arrival-time comparison claim otherwise.
            let queued = std::mem::take(&mut self.pending[ci]);
            let (stale, still_pending): (Vec<PendingReport>, Vec<PendingReport>) =
                queued.into_iter().partition(|p| p.arrive_abs_s <= close_abs);
            self.pending[ci] = still_pending;

            // Classify this phase's fresh outcomes against the close.
            let mut on_time: Vec<(usize, LocalOutcome)> =
                Vec::with_capacity(per_cluster[slot].len());
            for (i, outcome) in per_cluster[slot].drain(..).enumerate() {
                debug_assert_eq!(outcome.0, pt.devices.device[i]);
                match pt.devices.verdict[i] {
                    ReportVerdict::OnTime => on_time.push(outcome),
                    // Mask mode never sees Late: config validation
                    // rejects the semi-sync policy (the only verdict
                    // source) for `--secagg mask:<bits>`.
                    ReportVerdict::Late => self.pending[ci].push(PendingReport {
                        params: outcome.1.params,
                        n_samples: outcome.1.n_samples,
                        arrive_abs_s: start_abs + pt.devices.finish_s[i],
                        origin_phase: phase,
                    }),
                    ReportVerdict::Dropped => {}
                }
            }

            phases[slot].clock_s = close_abs;
            phases[slot].stale_merged = stale.len();
            phases[slot].pending_after = self.pending[ci].len();

            if on_time.is_empty() && stale.is_empty() {
                // Timeout/deadline fired before any report (and nothing
                // stale arrived): keep the previous edge model.
            } else if let Some(bits) = mask_bits {
                // Masked close: on-time devices are the survivors; every
                // participant the policy dropped leaves dangling pair
                // masks that `masked_cluster_sum` re-derives and cancels
                // deterministically. Stale merges cannot occur here —
                // validation excludes the only policy that parks reports.
                debug_assert!(stale.is_empty(), "mask mode cannot stale-merge");
                let sum = masked_cluster_sum(
                    &on_time,
                    &participants[slot],
                    bits,
                    &self.rng,
                    phase,
                );
                let decoded = secagg::decode_sum(&sum, bits);
                self.clusters[ci].model.copy_from_slice(&decoded);
                if collect_models {
                    phases[slot].masked = Some(sum);
                }
            } else {
                // Stale merges discount with the cluster's *effective*
                // policy — the controller override when installed, the
                // config-wide policy otherwise (the only policy that can
                // have parked the report in a static run).
                let pol: &dyn AggregationPolicy = match &self.cluster_policy[ci] {
                    Some((_, p)) => &**p,
                    None => &*self.policy,
                };
                let reports: Vec<WeightedReport> = on_time
                    .iter()
                    .map(|(_, o)| WeightedReport {
                        params: &o.params,
                        n_samples: o.n_samples,
                        discount: 1.0,
                    })
                    .chain(stale.iter().map(|p| WeightedReport {
                        params: &p.params,
                        n_samples: p.n_samples,
                        discount: pol.staleness_discount(phase - p.origin_phase),
                    }))
                    .collect();
                ClusterState::aggregate_reports_into(&reports, &mut self.clusters[ci].model)?;
            }
            if collect_models && phases[slot].masked.is_none() {
                phases[slot].model = self.clusters[ci].model.clone();
            }
            phases[slot].timing = Some(pt);
        }
        Ok(phases)
    }

    /// [`LatencyEstimator::phase_timings`](crate::netsim::LatencyEstimator::phase_timings)
    /// with controller-installed per-cluster policies: alive slots are
    /// grouped by effective policy spec (first-occurrence order) and each
    /// group rides one batched call, results scattered back into slot
    /// order. Each cluster's phase is simulated on its own independent
    /// event-engine shard, so the grouping cannot change any cluster's
    /// timing — only how the shards are batched into calls.
    fn phase_timings_grouped(
        &self,
        alive: &[usize],
        work_lists: &[Vec<(usize, usize)>],
        channel: UploadChannel,
    ) -> Option<Vec<PhaseTiming>> {
        let spec_of = |ci: usize| -> &str {
            self.cluster_policy[ci].as_ref().map_or("", |(s, _)| s.as_str())
        };
        // (representative cluster, member slots) per distinct spec.
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for (slot, &ci) in alive.iter().enumerate() {
            match groups.iter_mut().find(|(rep, _)| spec_of(*rep) == spec_of(ci)) {
                Some((_, slots)) => slots.push(slot),
                None => groups.push((ci, vec![slot])),
            }
        }
        let mut out: Vec<Option<PhaseTiming>> = (0..alive.len()).map(|_| None).collect();
        for (rep, slots) in groups {
            let policy: &dyn AggregationPolicy = match &self.cluster_policy[rep] {
                Some((_, p)) => &**p,
                None => &*self.policy,
            };
            let sub: Vec<Vec<(usize, usize)>> =
                slots.iter().map(|&s| work_lists[s].clone()).collect();
            let pts = self.latency.phase_timings(&self.net, &sub, channel, policy)?;
            for (s, pt) in slots.into_iter().zip(pts) {
                out[s] = Some(pt);
            }
        }
        Some(out.into_iter().map(|p| p.expect("every alive slot grouped")).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Prototypes, SyntheticSpec};
    use crate::runtime::MockBackend;

    fn fixture() -> (MockBackend, Dataset) {
        let be = MockBackend::mlp_synth();
        let protos = Prototypes::new(SyntheticSpec::mlp_synth(), &Rng::new(1));
        let ds = protos.global_pool(48, &Rng::new(2));
        (be, ds)
    }

    #[test]
    fn steps_match_epoch_math() {
        let (be, ds) = fixture();
        let init = be.init_state(&Rng::new(3)).params;
        let out = train_device(&be, &ds, &init, 2, 0.05, Rng::new(4)).unwrap();
        // 48 samples / batch 16 = 3 batches per epoch; 2 epochs = 6 steps.
        assert_eq!(out.steps, 6);
        assert_eq!(out.n_samples, 48);
        assert_eq!(out.params.len(), be.param_count());
    }

    #[test]
    fn training_moves_params_and_reduces_loss() {
        let (be, ds) = fixture();
        let init = be.init_state(&Rng::new(3)).params;
        let out1 = train_device(&be, &ds, &init, 1, 0.1, Rng::new(4)).unwrap();
        let out8 = train_device(&be, &ds, &init, 8, 0.1, Rng::new(4)).unwrap();
        assert_ne!(out1.params, init);
        let mean1 = out1.loss_sum / out1.steps as f64;
        let mean8 = out8.loss_sum / out8.steps as f64;
        assert!(mean8 < mean1, "{mean8} !< {mean1}");
    }

    #[test]
    fn deterministic_given_rng() {
        let (be, ds) = fixture();
        let init = be.init_state(&Rng::new(3)).params;
        let a = train_device(&be, &ds, &init, 2, 0.1, Rng::new(7)).unwrap();
        let b = train_device(&be, &ds, &init, 2, 0.1, Rng::new(7)).unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(a.loss_sum, b.loss_sum);
    }

    #[test]
    fn momentum_starts_fresh() {
        // Two successive calls from the same init give identical results —
        // no hidden state leaks between local rounds.
        let (be, ds) = fixture();
        let init = be.init_state(&Rng::new(3)).params;
        let a = train_device(&be, &ds, &init, 1, 0.1, Rng::new(9)).unwrap();
        let b = train_device(&be, &ds, &init, 1, 0.1, Rng::new(9)).unwrap();
        assert_eq!(a.params, b.params);
    }
}
