//! Local-Edge baseline: the edge-based FL framework with *no* cooperation
//! between edge servers — each cluster runs FedAvg over its own devices
//! only. Lowest per-round latency (no backhaul, no cloud) but each edge
//! model only ever sees 1/m of the data, which caps its accuracy (the
//! paper's motivation for CFEL). Close policies apply per cluster; with
//! no inter-cluster barrier the per-cluster virtual clocks stay fully
//! independent, which is exactly what anchors each cluster's stale-merge
//! arrivals under semi-sync.

use crate::coordinator::cefedavg::merge_steps;
use crate::coordinator::{Coordinator, RoundStats};
use crate::error::Result;
use crate::netsim::UploadChannel;

impl Coordinator {
    pub(crate) fn local_edge_round(&mut self, round: usize) -> Result<RoundStats> {
        let mut stats = RoundStats::default();
        for r in 0..self.cfg.q {
            let phase = (round * self.cfg.q + r) as u64;
            // Fully independent clusters: the ideal case for the
            // parallel round engine.
            self.edge_phase(self.cfg.tau, phase, UploadChannel::DeviceEdge, &mut stats)?;
        }
        // No inter-cluster aggregation of any kind.
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{AlgorithmKind, DataScheme, ExperimentConfig};
    use crate::coordinator::Coordinator;
    use crate::metrics::best_accuracy;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::quickstart();
        c.algorithm = AlgorithmKind::LocalEdge;
        c.rounds = 6;
        c
    }

    #[test]
    fn clusters_never_converge_to_each_other() {
        let mut coord = Coordinator::from_config(&cfg()).unwrap();
        let h = coord.run().unwrap();
        // No cooperation ⇒ models stay apart under non-IID writers.
        assert!(h.last().unwrap().consensus > 1e-9);
    }

    #[test]
    fn accuracy_below_cooperative_ce_on_noniid_data() {
        // The paper's headline qualitative result (Fig. 2): Local-Edge
        // plateaus below CE-FedAvg because each edge model sees a skewed
        // fraction of the data. Use a strongly skewed cluster split.
        let mut le_cfg = cfg();
        le_cfg.rounds = 10;
        le_cfg.data = DataScheme::ClusterNonIid { c_labels: 2 };
        let mut ce_cfg = le_cfg.clone();
        ce_cfg.algorithm = AlgorithmKind::CeFedAvg;
        let mut le = Coordinator::from_config(&le_cfg).unwrap();
        let mut ce = Coordinator::from_config(&ce_cfg).unwrap();
        let hl = le.run().unwrap();
        let hc = ce.run().unwrap();
        let (ble, bce) = (best_accuracy(&hl), best_accuracy(&hc));
        assert!(bce > ble + 0.05, "ce {bce} !>> local {ble}");
    }

    #[test]
    fn semi_sync_runs_on_unsynced_cluster_clocks() {
        use crate::config::{AggPolicyKind, LatencyMode};
        use crate::netsim::StragglerSpec;
        // No inter-cluster barrier ever syncs the clocks here; the
        // stale-merge bookkeeping must still be stable and reproducible.
        let mut c = cfg();
        c.rounds = 5;
        c.latency = LatencyMode::EventDriven;
        c.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e4 });
        c.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.02 };
        let run = || Coordinator::from_config(&c).unwrap().run().unwrap();
        let a = run();
        let b = run();
        assert_eq!(a.iter().map(|r| r.dropped_devices).sum::<usize>(), 0);
        assert!(a.iter().map(|r| r.late_devices).sum::<usize>() > 0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
            assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits());
            assert_eq!(x.stale_merged, y.stale_merged);
        }
    }

    #[test]
    fn cheapest_per_round() {
        let mut le = Coordinator::from_config(&cfg()).unwrap();
        let hl = le.run().unwrap();
        for alg in [AlgorithmKind::CeFedAvg, AlgorithmKind::FedAvg, AlgorithmKind::HierFAvg] {
            let mut c = cfg();
            c.algorithm = alg;
            let mut coord = Coordinator::from_config(&c).unwrap();
            let h = coord.run().unwrap();
            assert!(
                hl.last().unwrap().sim_time_s <= h.last().unwrap().sim_time_s + 1e-9,
                "local-edge not cheapest vs {alg:?}"
            );
        }
    }
}
