//! The L3 coordinator — the paper's system contribution.
//!
//! [`Coordinator`] owns the whole CFEL system: the federated data, the
//! cluster/device layout, the edge-backhaul graph with its gossip matrix,
//! the network latency model, and the execution backend. [`Coordinator::run`]
//! drives `rounds` global rounds of one [`Plan`] — a declarative sequence
//! of [`Step`]s (edge phases, gossip, cloud aggregation, repetition) that
//! a single interpreter executes. The paper's four algorithms are canned
//! plans (`plan::canned`) selected by `AlgorithmKind`; any other schedule
//! is just a different plan (`--plan` / `ExperimentConfig::plan`).
//!
//! Shared machinery (local training, intra-cluster aggregation, eval,
//! fault bookkeeping) lives here and in `trainer.rs` / `cluster.rs`; the
//! frozen pre-plan direct-dispatch loop survives in `legacy.rs` as the
//! equivalence oracle (`rust/tests/plan_equivalence.rs`).

pub mod cluster;
pub mod executor;
mod legacy;
pub mod trainer;

pub use cluster::{ClusterState, WeightedReport};
pub use executor::{ClusterExecutor, DistRunner, LocalExecutor};
pub use trainer::{ClusterPhase, LocalOutcome};

use std::time::Instant;

use crate::aggregation;
use crate::aggregation::policy::{AggregationPolicy, ReportVerdict};
use crate::config::{
    AggPolicyKind, BackendKind, DataScheme, ExperimentConfig, FaultSpec, LatencyMode,
    SecaggMode,
};
use crate::control::{ClusterTelemetry, Controller, Decision, RoundTelemetry};
use crate::data::sampler::eval_batches;
use crate::data::synthetic::{
    femnist_federation, pool_federation, FederatedData, SyntheticSpec,
};
use crate::data::{partition, Batch};
use crate::error::{CfelError, Result};
use crate::metrics::{report_quantiles, History, RoundRecord};
use crate::netsim::{
    ClosedFormEstimator, DeviceTimings, EventDrivenEstimator, LatencyEstimator, NetworkModel,
    RoundLatency, RoundTiming,
};
use crate::plan::{Plan, Step};
use crate::runtime::{EvalResult, Manifest, MockBackend, PjrtBackend, TrainBackend};
use crate::scenario::{LinkKind, Scenario, WorldEvent};
use crate::topology::{Graph, MixingMatrix};
use crate::util::rng::Rng;
use crate::util::stats::merge_steps;
use crate::util::threadpool::{default_threads, parallel_map};

/// Immutable per-round view of the coordinator, shared by the parallel
/// cluster tasks. Splitting the round state this way lets every alive
/// cluster train concurrently against shared read-only data while the
/// mutable [`ClusterState`] shards are only written after the join, in
/// deterministic cluster order — so results are bit-identical for any
/// `CFEL_THREADS`.
pub(crate) struct RoundContext<'a> {
    pub backend: &'a dyn TrainBackend,
    pub fed: &'a FederatedData,
    pub cfg: &'a ExperimentConfig,
    pub rng: &'a Rng,
}

impl RoundContext<'_> {
    /// Deterministic per-(round-phase, cluster) stream: participant
    /// sampling. Stable no matter how many clusters run concurrently or
    /// in which order the scheduler interleaves them.
    pub(crate) fn cluster_rng(&self, ci: usize, phase: u64) -> Rng {
        self.rng.split(0x9A27_0000 + ci as u64).split(phase)
    }

    /// Deterministic per-(round-phase, device) stream: local SGD batch
    /// order. Derived from the root seed, not from any worker-thread
    /// state, so a device's trajectory is independent of thread count.
    pub(crate) fn device_rng(&self, dev: usize, phase: u64) -> Rng {
        self.rng.split(0x5EED_0000 + dev as u64).split(phase)
    }
}

/// Aggregate statistics of one global round's local-training phase.
#[derive(Debug, Default, Clone)]
pub struct RoundStats {
    /// (device_id, sgd_steps) for every participating device.
    pub device_steps: Vec<(usize, usize)>,
    pub loss_sum: f64,
    pub step_count: usize,
    /// Per-device/per-cluster virtual timing, filled by the event-driven
    /// latency estimator (empty in closed-form mode).
    pub timing: RoundTiming,
}

impl RoundStats {
    pub fn mean_loss(&self) -> f64 {
        if self.step_count == 0 {
            f64::NAN
        } else {
            self.loss_sum / self.step_count as f64
        }
    }
}

/// A kept-late model report awaiting a stale merge (semi-sync policy):
/// the device's trained parameters, its Eq. 6 weight, when the report
/// arrives on the cluster's virtual clock, and which edge phase produced
/// it (the staleness anchor).
#[derive(Debug, Clone)]
pub(crate) struct PendingReport {
    pub params: Vec<f32>,
    pub n_samples: usize,
    /// Arrival instant on the cluster's *absolute* virtual clock.
    pub arrive_abs_s: f64,
    /// Global edge-phase counter the report was trained in.
    pub origin_phase: u64,
}

/// The CFEL system runtime.
pub struct Coordinator {
    pub cfg: ExperimentConfig,
    /// The per-round schedule the interpreter executes — the config's
    /// explicit plan, or the canned plan its `algorithm` names.
    pub plan: Plan,
    /// The resolved world description (the config's explicit scenario, or
    /// the static lowering of its flat knobs). Owns the rosters the
    /// clusters were built from and the event timeline
    /// [`Coordinator::apply_timeline`] replays at round boundaries.
    pub scenario: Scenario,
    /// Current cluster of every device (`None` = dormant / left). Kept in
    /// lockstep with the clusters' `device_ids` by the timeline events.
    pub(crate) device_cluster: Vec<Option<usize>>,
    pub backend: Box<dyn TrainBackend>,
    pub fed: FederatedData,
    pub clusters: Vec<ClusterState>,
    pub graph: Graph,
    /// H^π over the *current* alive subgraph, for the config's default π
    /// (`cfg.pi` — what the canned CE-FedAvg plan gossips with).
    pub h_pi: MixingMatrix,
    /// Lazily built H^π powers for plan gossip steps whose π differs from
    /// `cfg.pi`; invalidated whenever a fault rebuilds the graph.
    pub(crate) h_cache: Vec<(u32, MixingMatrix)>,
    pub net: NetworkModel,
    /// Round-latency estimator (closed-form Eq. 8 or the event sim),
    /// selected by the config's `latency` field.
    pub latency: Box<dyn LatencyEstimator>,
    /// Edge-round close policy (full barrier / deadline-drop / semi-sync),
    /// from the config's `agg_policy` / `deadline_s` fields.
    pub policy: Box<dyn AggregationPolicy>,
    pub eval_set: Vec<Batch>,
    pub rng: Rng,
    /// Alive flag per cluster (fault injection).
    pub alive: Vec<bool>,
    /// Whether the central aggregator (cloud/hub) is alive.
    pub aggregator_alive: bool,
    /// Absolute virtual time per cluster, advanced at every simulated
    /// phase close and re-synced at inter-cluster barriers (event mode;
    /// stays 0 in closed-form mode). Anchors late-report arrivals.
    pub(crate) cluster_clock_s: Vec<f64>,
    /// Kept-late reports per cluster, awaiting their stale merge.
    pub(crate) pending: Vec<Vec<PendingReport>>,
    /// Per-cluster close-policy overrides installed by the controller:
    /// `(spec string, built policy)`; `None` falls back to the
    /// config-wide `policy`. The spec string is the wire/provenance form
    /// ([`AggPolicyKind`] grammar).
    pub(crate) cluster_policy: Vec<Option<(String, Box<dyn AggregationPolicy>)>>,
    /// Round-boundary controller (config `controller`; `Static` default).
    pub(crate) controller: Box<dyn Controller>,
    /// Telemetry captured from the last completed round (non-static
    /// controllers only), consumed by the next boundary's decision.
    pub(crate) last_telemetry: Option<RoundTelemetry>,
    /// Provenance note of the decision applied at this round's boundary.
    pub(crate) decision_note: Option<String>,
    /// Global edge-phase counter. Plan rewriting can change the per-round
    /// phase count, so phase numbering is a running cursor; for a fixed
    /// plan it equals `round · plan.edge_phases()` exactly — the
    /// historical numbering, bit for bit.
    pub(crate) phase_cursor: u64,
    /// Scratch buffer reused by gossip.
    pub(crate) scratch: Vec<f32>,
    /// Verbose per-round logging.
    pub verbose: bool,
}

impl Coordinator {
    /// Build the full system from a config (backend, data, topology, net).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Coordinator> {
        cfg.validate()?;
        let backend: Box<dyn TrainBackend> = match &cfg.backend {
            BackendKind::Mock { hidden } => {
                // The mock MLP trains on the mlp_synth-shaped task.
                Box::new(MockBackend::new(64, *hidden, 10, 16))
            }
            BackendKind::Pjrt { model, artifacts_dir } => {
                let dir = artifacts_dir
                    .clone()
                    .unwrap_or_else(Manifest::default_dir);
                Box::new(PjrtBackend::load(&dir, model)?)
            }
        };
        Self::with_backend(cfg.clone(), backend)
    }

    /// Build with an explicit backend (tests inject custom mocks here).
    pub fn with_backend(
        cfg: ExperimentConfig,
        backend: Box<dyn TrainBackend>,
    ) -> Result<Coordinator> {
        cfg.validate()?;
        let plan = cfg.resolved_plan();
        plan.validate()?;
        // The world is always built from a Scenario — the flat knobs
        // lower into a static one (`Scenario::from_flat`), so the flat
        // spelling and explicit scenarios share this single code path
        // (pinned bit-identical by `rust/tests/scenario_equivalence.rs`).
        let scenario = cfg.resolved_scenario();
        scenario.validate()?;
        let rng = Rng::new(cfg.seed);
        let fed = Self::build_data(&cfg, &scenario, &*backend, &rng)?;

        // Clusters own the scenario's rosters (the flat lowering keeps
        // the paper's §5.2 contiguous layout).
        let param_count = backend.param_count();
        let init = backend.init_state(&rng.split(0x1217)).params;
        let clusters: Vec<ClusterState> = scenario
            .rosters
            .iter()
            .map(|roster| {
                let n_samples = roster
                    .iter()
                    .map(|&d| fed.device_train[d].len())
                    .sum();
                ClusterState {
                    device_ids: roster.clone(),
                    model: init.clone(),
                    n_samples,
                }
            })
            .collect();
        debug_assert_eq!(init.len(), param_count);
        let mut device_cluster = vec![None; cfg.n_devices];
        for (ci, roster) in scenario.rosters.iter().enumerate() {
            for &d in roster {
                device_cluster[d] = Some(ci);
            }
        }

        let graph = Graph::by_name(&scenario.topology, cfg.n_clusters, &rng.split(0x706F))?;
        if !graph.is_connected() {
            return Err(CfelError::Topology(format!(
                "backhaul {} is not connected",
                scenario.topology
            )));
        }
        let h_pi = MixingMatrix::metropolis(&graph).power(cfg.pi);

        let mut net = NetworkModel::paper_defaults(
            cfg.n_devices,
            backend.flops_per_sample(),
            backend.batch_size(),
            param_count,
        );
        // Lossy upload compression shrinks every transmitted model.
        net.model_bits *= cfg.compression.ratio();
        // Secure aggregation (mask mode): masked uploads are dense
        // 64-bit words, one per parameter, regardless of compression,
        // and every participant pays the PRG/encode compute. Lossless
        // mode leaves `secagg_upload_bits` at 0, so the cost model stays
        // bitwise equal to a plain run (docs/DETERMINISM.md).
        if let SecaggMode::Mask(bits) = cfg.secagg {
            net.secagg_upload_bits = 64.0 * param_count as f64;
            // Closed-form group size: the largest per-cluster participant
            // set (roster × participation, at least one device).
            net.secagg_group_size = scenario
                .rosters
                .iter()
                .map(|r| {
                    ((r.len() as f64 * cfg.participation).ceil() as usize)
                        .clamp(1, r.len().max(1))
                })
                .max()
                .unwrap_or(0) as f64;
            // Overflow headroom: each upload word is q·weight with
            // |q| ≤ clip·2^bits (clip = 64 = 2^6) and weight ≤ the
            // cluster's sample total; their wrapping sum must stay
            // inside i64 (docs in `secagg`).
            let max_samples = clusters.iter().map(|c| c.n_samples).max().unwrap_or(1).max(1);
            let weight_bits = 64 - (max_samples as u64).leading_zeros();
            if bits + 6 + weight_bits > 62 {
                return Err(CfelError::Config(format!(
                    "secagg mask:{bits} overflows the 64-bit accumulator: \
                     mask bits + log2(clip 64) + log2(max cluster samples \
                     {max_samples}) = {} > 62; lower the mask bits or \
                     shrink the clusters",
                    bits + 6 + weight_bits
                )));
            }
        }
        // Capability profiles (the scenario's per-device world view; the
        // derived kind replays the flat heterogeneity/straggler draws
        // from the same root-RNG splits) and link overrides.
        scenario.capabilities.apply(&mut net, &rng)?;
        if let Some(links) = &scenario.links {
            links.apply(&mut net);
        }
        let latency: Box<dyn LatencyEstimator> = match cfg.latency {
            LatencyMode::ClosedForm => Box::new(ClosedFormEstimator),
            LatencyMode::EventDriven => Box::new(EventDrivenEstimator),
        };
        let policy = cfg.resolved_policy().build(cfg.staleness_exp);

        let eval_set = eval_batches(&fed.test, backend.batch_size());
        let n_clusters = cfg.n_clusters;
        let controller = crate::control::build(cfg.controller, cfg.pi);
        Ok(Coordinator {
            cfg,
            plan,
            scenario,
            device_cluster,
            backend,
            fed,
            clusters,
            graph,
            h_pi,
            h_cache: Vec::new(),
            net,
            latency,
            policy,
            eval_set,
            rng,
            alive: vec![true; n_clusters],
            aggregator_alive: true,
            cluster_clock_s: vec![0.0; n_clusters],
            pending: vec![Vec::new(); n_clusters],
            cluster_policy: (0..n_clusters).map(|_| None).collect(),
            controller,
            last_telemetry: None,
            decision_note: None,
            phase_cursor: 0,
            scratch: Vec::new(),
            verbose: false,
        })
    }

    fn build_data(
        cfg: &ExperimentConfig,
        scenario: &Scenario,
        backend: &dyn TrainBackend,
        rng: &Rng,
    ) -> Result<FederatedData> {
        // The synthetic spec must match the backend's input shape.
        let mut spec = SyntheticSpec {
            dim: backend.flat_dim(),
            num_classes: backend.num_classes(),
            ..SyntheticSpec::mlp_synth()
        };
        if let Some(n) = cfg.data_noise {
            spec.noise = n;
        }
        if let Some(s) = cfg.writer_style {
            spec.writer_style = s;
        }
        let data_rng = rng.split(0xDA7A);
        let fed = match &cfg.data {
            DataScheme::FemnistWriters { label_alpha } => femnist_federation(
                spec,
                cfg.n_devices,
                cfg.samples_per_device,
                *label_alpha,
                &data_rng,
            ),
            scheme => {
                let pool_size = cfg.n_devices * cfg.samples_per_device;
                // Build the index partition over a balanced pool whose
                // labels are i % num_classes (global_pool's layout).
                let labels: Vec<u32> = (0..pool_size)
                    .map(|i| (i % backend.num_classes()) as u32)
                    .collect();
                let parts = match scheme {
                    DataScheme::PoolIid => partition::iid(pool_size, cfg.n_devices, &data_rng),
                    DataScheme::PoolDirichlet { alpha } => partition::dirichlet(
                        &labels,
                        backend.num_classes(),
                        cfg.n_devices,
                        *alpha,
                        &data_rng,
                    ),
                    DataScheme::ClusterIid => partition::cluster_iid(
                        &labels,
                        &scenario.rosters,
                        cfg.n_devices,
                        &data_rng,
                    )?,
                    DataScheme::ClusterNonIid { c_labels } => partition::cluster_noniid(
                        &labels,
                        &scenario.rosters,
                        cfg.n_devices,
                        *c_labels,
                        &data_rng,
                    )?,
                    DataScheme::FemnistWriters { .. } => unreachable!(),
                };
                partition::validate_partition(&parts, pool_size, true)
                    .map_err(|e| CfelError::Data(format!("partition invalid: {e}")))?;
                pool_federation(spec, pool_size, cfg.test_size, &parts, &data_rng)
            }
        };
        for (k, d) in fed.device_train.iter().enumerate() {
            if d.is_empty() {
                return Err(CfelError::Data(format!("device {k} got no data")));
            }
        }
        Ok(fed)
    }

    // ----- shared round machinery ------------------------------------------

    /// Indices of currently alive clusters.
    pub fn alive_clusters(&self) -> Vec<usize> {
        (0..self.clusters.len()).filter(|&i| self.alive[i]).collect()
    }

    /// Borrow the immutable round context the parallel cluster tasks share.
    pub(crate) fn round_ctx(&self) -> RoundContext<'_> {
        RoundContext {
            backend: &*self.backend,
            fed: &self.fed,
            cfg: &self.cfg,
            rng: &self.rng,
        }
    }

    /// Cloud aggregation: size-weighted average over alive clusters,
    /// broadcast back to every alive cluster. A no-op when every cluster
    /// is dead (nothing to average).
    pub(crate) fn cloud_aggregate(&mut self) -> Result<()> {
        let alive = self.alive_clusters();
        if alive.is_empty() {
            return Ok(());
        }
        let sizes: Vec<usize> = alive.iter().map(|&i| self.clusters[i].n_samples).collect();
        let mut global = std::mem::take(&mut self.scratch);
        {
            let rows: Vec<&[f32]> = alive
                .iter()
                .map(|&i| self.clusters[i].model.as_slice())
                .collect();
            global.resize(rows[0].len(), 0.0);
            let res = aggregation::global_average_into(&rows, &sizes, &mut global);
            drop(rows);
            self.scratch = global;
            res?;
        }
        for &i in &alive {
            self.clusters[i].model.copy_from_slice(&self.scratch);
        }
        Ok(())
    }

    /// Inter-cluster gossip (Eq. 7) over the alive subgraph with the
    /// default H^π (`cfg.pi`) — what the canned CE-FedAvg plan and the
    /// legacy loop run.
    pub(crate) fn gossip(&mut self) {
        self.mix_gossip(self.cfg.pi);
    }

    /// Gossip with `pi` hops. The default π uses the precomputed `h_pi`;
    /// any other π gets its mixing matrix built for the current graph on
    /// first use and cached (`h_cache` is cleared when a fault rebuilds
    /// the graph). Backhaul messages go through the configured compressor
    /// first (what the neighbouring servers actually receive).
    pub(crate) fn mix_gossip(&mut self, pi: u32) {
        let alive = self.alive_clusters();
        if alive.len() <= 1 {
            return;
        }
        if pi != self.cfg.pi && !self.h_cache.iter().any(|(p, _)| *p == pi) {
            let h = MixingMatrix::metropolis(&self.graph).power(pi);
            self.h_cache.push((pi, h));
        }
        let mut models: Vec<Vec<f32>> = alive
            .iter()
            .map(|&i| std::mem::take(&mut self.clusters[i].model))
            .collect();
        for m in &mut models {
            self.cfg.compression.roundtrip(m);
        }
        let h = if pi == self.cfg.pi {
            &self.h_pi
        } else {
            &self
                .h_cache
                .iter()
                .find(|(p, _)| *p == pi)
                .expect("cached above")
                .1
        };
        aggregation::gossip_mix(&mut models, h, &mut self.scratch);
        for (slot, &i) in alive.iter().enumerate() {
            self.clusters[i].model = std::mem::take(&mut models[slot]);
        }
    }

    /// Apply any scheduled fault at the start of `round`.
    pub(crate) fn apply_fault(&mut self, round: usize) -> Result<()> {
        match self.cfg.fault {
            Some(FaultSpec::KillCluster { at_round, cluster }) if at_round == round => {
                if self.plan.has_gossip() {
                    // Rebuild the gossip matrices over the surviving graph.
                    let (sub, _map) = self.graph.remove_node(self.count_alive_before(cluster))?;
                    if !sub.is_connected() {
                        return Err(CfelError::Topology(
                            "fault disconnected the backhaul".into(),
                        ));
                    }
                    self.h_pi = MixingMatrix::metropolis(&sub).power(self.cfg.pi);
                    self.h_cache.clear();
                    self.graph = sub;
                }
                self.alive[cluster] = false;
                if self.verbose {
                    eprintln!("[fault] cluster {cluster} killed at round {round}");
                }
            }
            Some(FaultSpec::KillAggregator { at_round }) if at_round == round => {
                self.aggregator_alive = false;
                if self.verbose {
                    eprintln!("[fault] central aggregator killed at round {round}");
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Graph-node index of `cluster` among currently alive clusters.
    fn count_alive_before(&self, cluster: usize) -> usize {
        (0..cluster).filter(|&i| self.alive[i]).count()
    }

    /// Apply the scenario timeline's events for the start of `round`
    /// (membership, capability and link changes), then re-derive what
    /// hangs off membership: every cluster's Eq. 6 / cloud weight
    /// (`n_samples` over its current roster) and the gossip mixing
    /// matrices. Runs single-threaded at the round boundary, so world
    /// changes are bit-identical for any `CFEL_THREADS`.
    pub(crate) fn apply_timeline(&mut self, round: usize) -> Result<()> {
        let events = self.scenario.timeline.at(round);
        if events.is_empty() {
            return Ok(());
        }
        let mut membership_changed = false;
        for e in events {
            if self.verbose {
                eprintln!("[scenario] round {round}: {}", e.event.describe());
            }
            membership_changed |= self.apply_world_event(&e.event)?;
        }
        if membership_changed {
            let fed = &self.fed;
            for c in &mut self.clusters {
                c.n_samples = c
                    .device_ids
                    .iter()
                    .map(|&d| fed.device_train[d].len())
                    .sum();
            }
            // Membership events do not rewire the backhaul graph (devices
            // move, edge servers stay), but the mixing matrices are
            // rebuilt with the weights so any roster-dependent weighting
            // added later cannot silently go stale.
            if self.plan.has_gossip() {
                self.h_pi = MixingMatrix::metropolis(&self.graph).power(self.cfg.pi);
                self.h_cache.clear();
            }
        }
        Ok(())
    }

    /// Apply one world event; returns whether cluster membership changed.
    /// Rosters stay sorted ascending (the canonical Eq. 6 merge order),
    /// so a device re-joining lands in the same position it would have
    /// held all along.
    fn apply_world_event(&mut self, ev: &WorldEvent) -> Result<bool> {
        match *ev {
            WorldEvent::Join { device, cluster } => {
                if self.device_cluster[device].is_some() {
                    return Err(CfelError::Config(format!(
                        "timeline join: device {device} is already active"
                    )));
                }
                let ids = &mut self.clusters[cluster].device_ids;
                let pos = ids.binary_search(&device).unwrap_or_else(|p| p);
                ids.insert(pos, device);
                self.device_cluster[device] = Some(cluster);
                Ok(true)
            }
            WorldEvent::Leave { device } => {
                let ci = self.device_cluster[device].ok_or_else(|| {
                    CfelError::Config(format!(
                        "timeline leave: device {device} is not active"
                    ))
                })?;
                let ids = &mut self.clusters[ci].device_ids;
                if let Ok(pos) = ids.binary_search(&device) {
                    ids.remove(pos);
                }
                self.device_cluster[device] = None;
                Ok(true)
            }
            WorldEvent::Handover { device, from, to } => {
                if self.device_cluster[device] != Some(from) {
                    return Err(CfelError::Config(format!(
                        "timeline handover: device {device} is not in cluster {from}"
                    )));
                }
                let ids = &mut self.clusters[from].device_ids;
                if let Ok(pos) = ids.binary_search(&device) {
                    ids.remove(pos);
                }
                let ids = &mut self.clusters[to].device_ids;
                let pos = ids.binary_search(&device).unwrap_or_else(|p| p);
                ids.insert(pos, device);
                self.device_cluster[device] = Some(to);
                Ok(true)
            }
            WorldEvent::CapacityChange { device, factor } => {
                self.net.device_flops[device] *= factor;
                Ok(false)
            }
            WorldEvent::LinkChange { link, bps } => {
                match link {
                    LinkKind::DeviceEdge => self.net.b_d2e = bps,
                    LinkKind::EdgeEdge => self.net.b_e2e = bps,
                    LinkKind::DeviceCloud => self.net.b_d2c = bps,
                }
                Ok(false)
            }
        }
    }

    /// Simulated latency of this round under the active plan, via the
    /// configured estimator (closed-form Eq. 8 or the event simulator).
    pub(crate) fn round_latency(&self, stats: &RoundStats) -> RoundLatency {
        self.latency
            .round_latency(&self.net, &self.plan, &stats.device_steps, &stats.timing)
    }

    /// Re-sync per-cluster virtual clocks at an inter-cluster barrier
    /// (event mode only; closed-form clocks stay 0). Every alive cluster
    /// waits for the slowest one, then the shared step — `extra_s` of
    /// gossip backhaul, or 0 for a cloud aggregation — completes, so all
    /// alive clocks jump to the common end. Plans without barriers
    /// (Local-Edge, a dead cloud aggregator) never call this: the
    /// independent clocks are what keep each cluster's late-report
    /// arrival phases well defined.
    pub(crate) fn barrier_clocks(&mut self, extra_s: f64) {
        if self.cfg.latency != LatencyMode::EventDriven {
            return;
        }
        let alive = self.alive_clusters();
        let end = alive
            .iter()
            .map(|&ci| self.cluster_clock_s[ci])
            .fold(f64::NEG_INFINITY, f64::max)
            + extra_s;
        if end.is_finite() {
            for &ci in &alive {
                self.cluster_clock_s[ci] = end;
            }
        }
    }

    // ----- the control plane -----------------------------------------------

    /// Install the controller's per-cluster close-policy overrides: a
    /// full replacement set (clusters absent from `overrides` fall back
    /// to the config-wide policy). Specs go through
    /// [`AggPolicyKind::parse`], so decisions, the decision log, and the
    /// distributed wire all share one grammar — and f64 `Display` being
    /// shortest-roundtrip makes install(spec) bit-identical on every
    /// host that parses the same string.
    pub fn set_cluster_policies(&mut self, overrides: &[(usize, String)]) -> Result<()> {
        for slot in self.cluster_policy.iter_mut() {
            *slot = None;
        }
        for (ci, spec) in overrides {
            if *ci >= self.cluster_policy.len() {
                return Err(CfelError::Config(format!(
                    "policy override for unknown cluster {ci}"
                )));
            }
            let built = AggPolicyKind::parse(spec)?.build(self.cfg.staleness_exp);
            self.cluster_policy[*ci] = Some((spec.clone(), built));
        }
        Ok(())
    }

    /// The currently installed per-cluster overrides as `(cluster, spec)`
    /// pairs — what the distributed driver ships to its edges.
    pub fn policy_overrides(&self) -> Vec<(usize, String)> {
        self.cluster_policy
            .iter()
            .enumerate()
            .filter_map(|(ci, s)| s.as_ref().map(|(spec, _)| (ci, spec.clone())))
            .collect()
    }

    /// Consult the controller at the boundary of `round` (after fault and
    /// timeline application) and apply its decision. A static controller
    /// returns immediately — the run is untouched, instruction for
    /// instruction. Decisions are pure functions of the telemetry
    /// stream, so every replay — any `CFEL_THREADS`, either side of the
    /// executor seam — rewrites identically (docs/DETERMINISM.md).
    pub(crate) fn control_round(&mut self, round: usize) -> Result<()> {
        if self.controller.is_static() {
            return Ok(());
        }
        // Refresh the world-state half of the telemetry: rosters and
        // links must reflect what the *next* round actually sees, i.e.
        // this boundary's timeline events.
        let telemetry = self.last_telemetry.take().map(|mut t| {
            for ct in &mut t.clusters {
                ct.alive = self.alive[ct.cluster];
                ct.roster = self.clusters[ct.cluster].device_ids.len();
            }
            t.b_d2c = self.net.b_d2c;
            t.b_e2e = self.net.b_e2e;
            t.aggregator_alive = self.aggregator_alive;
            t
        });
        let decision = self.controller.decide(round, telemetry.as_ref(), &self.plan);
        self.apply_decision(decision)
    }

    /// Install one [`Decision`]: validate and swap the plan (rebuilding
    /// the gossip mixing matrices when the rewrite introduces gossip),
    /// install the policy overrides, and park the provenance note for
    /// this round's CSV row.
    pub(crate) fn apply_decision(&mut self, d: Decision) -> Result<()> {
        let Decision { plan, policies, aggregator: _, note } = d;
        if let Some(new_plan) = plan {
            new_plan.validate()?;
            if new_plan.has_gossip() && !self.plan.has_gossip() {
                // The constructor builds H^π eagerly, but fault/timeline
                // rebuilds skip gossip-free plans — entering gossip
                // re-derives it from the current graph.
                self.h_pi = MixingMatrix::metropolis(&self.graph).power(self.cfg.pi);
                self.h_cache.clear();
            }
            self.plan = new_plan;
        }
        if let Some(overrides) = policies {
            self.set_cluster_policies(&overrides)?;
        }
        if note != "-" {
            self.decision_note = Some(note);
        }
        Ok(())
    }

    /// The decision note to log for the round now closing (`"-"` if the
    /// boundary kept everything).
    pub(crate) fn take_decision_note(&mut self) -> String {
        self.decision_note.take().unwrap_or_else(|| "-".into())
    }

    /// Extract the finished round's telemetry for the next boundary's
    /// decision. Skipped entirely for static controllers — zero overhead,
    /// zero behavioural delta. Device→cluster attribution uses the
    /// current membership map, which is exactly the membership the round
    /// trained under: timeline events only run at boundaries.
    pub(crate) fn capture_telemetry(
        &mut self,
        round: usize,
        stats: &RoundStats,
        lat: &RoundLatency,
    ) {
        if self.controller.is_static() {
            return;
        }
        let mut clusters: Vec<ClusterTelemetry> = (0..self.clusters.len())
            .map(|ci| ClusterTelemetry { cluster: ci, ..ClusterTelemetry::default() })
            .collect();
        let dt = &stats.timing.device_timings;
        for i in 0..dt.device.len() {
            let Some(ci) = self.device_cluster[dt.device[i]] else {
                continue;
            };
            let ct = &mut clusters[ci];
            ct.report_s.push(dt.finish_s[i]);
            match dt.verdict[i] {
                ReportVerdict::OnTime => ct.on_time += 1,
                ReportVerdict::Late => ct.late += 1,
                ReportVerdict::Dropped => ct.dropped += 1,
            }
        }
        // Roster / link fields are refreshed at the next boundary, after
        // its timeline events; see `control_round`.
        self.last_telemetry = Some(RoundTelemetry {
            round,
            clusters,
            close_reasons: stats.timing.close_reasons,
            backhaul_s: lat.backhaul_s,
            b_d2c: self.net.b_d2c,
            b_e2e: self.net.b_e2e,
            aggregator_alive: self.aggregator_alive,
        });
    }

    // ----- the plan interpreter --------------------------------------------

    /// Execute one global round of the active plan. This is the single
    /// round loop all algorithms share: edge phases thread `RoundStats`,
    /// close policies, pending-report buffers and per-cluster virtual
    /// clocks through `edge_phase`; gossip and cloud steps aggregate
    /// across clusters and barrier the clocks.
    ///
    /// Edge phases are numbered globally — phase = `round ·
    /// plan.edge_phases() + index-within-round` — which keys the
    /// deterministic per-(phase, device) RNG streams and the staleness
    /// arithmetic exactly as the retired per-algorithm loops did.
    pub(crate) fn plan_round(&mut self, _round: usize) -> Result<RoundStats> {
        let plan = self.plan.clone();
        // Phase numbering comes from the running cursor so the control
        // plane can rewrite the plan mid-run without perturbing the
        // global counter; for a fixed plan the cursor equals
        // `round · edge_phases()`, the historical numbering, bit for bit.
        let base_phase = self.phase_cursor;
        // The round accumulator's device columns come from the free list
        // so steady-state rounds append into recycled capacity (paired
        // with `RoundTiming::recycle` in `run`).
        let mut stats = RoundStats {
            timing: RoundTiming {
                device_timings: DeviceTimings::acquire(0),
                ..RoundTiming::default()
            },
            ..RoundStats::default()
        };
        let mut idx = 0u64;
        self.exec_steps(&plan.steps, base_phase, &mut idx, &mut stats)?;
        self.phase_cursor = base_phase + idx;
        // Eq. 8 wants per-device steps of the *whole* global round.
        stats.device_steps = merge_steps(std::mem::take(&mut stats.device_steps));
        Ok(stats)
    }

    fn exec_steps(
        &mut self,
        steps: &[Step],
        base_phase: u64,
        idx: &mut u64,
        stats: &mut RoundStats,
    ) -> Result<()> {
        for step in steps {
            match step {
                Step::EdgePhase { epochs, channel } => {
                    self.edge_phase(*epochs, base_phase + *idx, *channel, stats)?;
                    *idx += 1;
                }
                Step::Gossip { pi } => {
                    self.mix_gossip(*pi);
                    // The gossip hops are an inter-cluster barrier: every
                    // alive cluster waits for the slowest, then the π
                    // backhaul hops run (event mode advances the clocks).
                    // The simulated hop time is recorded once here and
                    // reused by the event estimator's round breakdown.
                    if self.cfg.latency == LatencyMode::EventDriven {
                        let hops_s =
                            EventDrivenEstimator::simulate_gossip(&self.net, *pi as usize).0;
                        stats.timing.gossip_s += hops_s;
                        self.barrier_clocks(hops_s);
                    }
                }
                Step::CloudAggregate => {
                    // A killed cloud aggregator (Table 1 fault) skips both
                    // the aggregation and its barrier — clusters drift on
                    // independent clocks from then on.
                    if self.aggregator_alive {
                        self.cloud_aggregate()?;
                        self.barrier_clocks(0.0);
                    }
                }
                Step::Repeat { n, body } => {
                    for _ in 0..*n {
                        self.exec_steps(body, base_phase, idx, stats)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Evaluate the current models on the common test set.
    ///
    /// Plans without a global synchronizer (Local-Edge) report the
    /// size-weighted mean accuracy of edge models (paper §6.2); after a
    /// cloud aggregation every cluster model is the cloud model, so the
    /// same weighted-mean computation serves every plan.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let alive = self.alive_clusters();
        // Per-cluster evals are independent; run them concurrently when
        // the backend allows it and reduce in alive order afterwards so
        // the floating-point accumulation is deterministic.
        let threads = if self.backend.parallel_devices() {
            default_threads(alive.len())
        } else {
            1
        };
        let results: Vec<Result<EvalResult>> = parallel_map(alive.len(), threads, |slot| {
            self.backend
                .eval(&self.clusters[alive[slot]].model, &self.eval_set)
        });
        let mut acc = 0.0;
        let mut loss = 0.0;
        let mut total = 0usize;
        for (&ci, r) in alive.iter().zip(results) {
            let r = r?;
            let w = self.clusters[ci].n_samples;
            acc += r.accuracy * w as f64;
            loss += r.loss * w as f64;
            total += w;
        }
        if total == 0 {
            return Ok((f64::NAN, f64::NAN));
        }
        Ok((acc / total as f64, loss / total as f64))
    }

    /// Consensus distance across alive cluster models (diagnostic).
    pub fn consensus(&self) -> f64 {
        let alive = self.alive_clusters();
        let models: Vec<&[f32]> = alive
            .iter()
            .map(|&i| self.clusters[i].model.as_slice())
            .collect();
        aggregation::consensus_distance_refs(&models)
    }

    /// Run the configured number of global rounds; returns the history.
    pub fn run(&mut self) -> Result<History> {
        let label = self.cfg.run_label();
        let mut history = History::new();
        let mut sim_time = 0.0f64;
        let mut wall = 0.0f64;
        for round in 0..self.cfg.rounds {
            let t0 = Instant::now();
            self.apply_fault(round)?;
            self.apply_timeline(round)?;
            self.control_round(round)?;
            let mut stats = self.plan_round(round)?;
            wall += t0.elapsed().as_secs_f64();
            let lat = self.round_latency(&stats);
            sim_time += lat.total();

            let (acc, tloss) = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                self.evaluate()?
            } else {
                (f64::NAN, f64::NAN)
            };
            let (report_p50_s, report_p90_s, report_p99_s) =
                report_quantiles(&stats.timing.device_timings.finish_s);
            let rec = RoundRecord {
                round: round + 1,
                sim_time_s: sim_time,
                wall_time_s: wall,
                compute_s: lat.compute_s,
                upload_s: lat.upload_s,
                backhaul_s: lat.backhaul_s,
                dropped_devices: stats.timing.dropped_devices,
                on_time_devices: stats.timing.on_time_devices,
                late_devices: stats.timing.late_devices,
                stale_merged: stats.timing.stale_merged,
                close_reason: stats.timing.close_reason_summary(),
                train_loss: stats.mean_loss(),
                test_accuracy: acc,
                test_loss: tloss,
                consensus: self.consensus(),
                steps: stats.step_count,
                report_p50_s,
                report_p90_s,
                report_p99_s,
                secagg_mask_s: stats.timing.secagg_mask_s,
                secagg_extra_bits: stats.timing.secagg_extra_bits,
                decision: self.take_decision_note(),
            };
            if self.verbose {
                let mut extras = String::new();
                if rec.dropped_devices > 0 {
                    extras.push_str(&format!("  dropped {}", rec.dropped_devices));
                }
                if rec.late_devices > 0 || rec.stale_merged > 0 {
                    extras.push_str(&format!(
                        "  late {} stale-merged {}",
                        rec.late_devices,
                        rec.stale_merged
                    ));
                }
                eprintln!(
                    "[{}] round {:>3}  loss {:.4}  acc {}  sim {:.1}s{}",
                    label,
                    rec.round,
                    rec.train_loss,
                    if acc.is_nan() {
                        "  -  ".to_string()
                    } else {
                        format!("{:.4}", acc)
                    },
                    sim_time,
                    extras
                );
            }
            history.push(rec);
            // Telemetry extraction must precede the recycle below — the
            // per-device columns are about to go back to the free list.
            self.capture_telemetry(round, &stats, &lat);
            // The record is derived; return the round's device-timing
            // columns to the free list for the next round.
            stats.timing.recycle();
        }
        Ok(history)
    }
}
