//! The L3 coordinator — the paper's system contribution.
//!
//! [`Coordinator`] owns the whole CFEL system: the federated data, the
//! cluster/device layout, the edge-backhaul graph with its gossip matrix,
//! the network latency model, and the execution backend. [`Coordinator::run`]
//! drives `rounds` global rounds of whichever algorithm the config selects:
//!
//! * **CE-FedAvg** (Algorithm 1) — `cefedavg.rs`
//! * **FedAvg** (cloud baseline) — `fedavg.rs`
//! * **Hier-FAvg** (hierarchical baseline) — `hierfavg.rs`
//! * **Local-Edge** (no-cooperation baseline) — `localedge.rs`
//!
//! Shared machinery (local training, intra-cluster aggregation, eval,
//! fault bookkeeping) lives here and in `trainer.rs` / `cluster.rs`.

pub mod cefedavg;
pub mod cluster;
pub mod fedavg;
pub mod hierfavg;
pub mod localedge;
pub mod trainer;

pub use cluster::{ClusterState, WeightedReport};
pub use trainer::LocalOutcome;

use std::time::Instant;

use crate::aggregation;
use crate::aggregation::policy::AggregationPolicy;
use crate::config::{
    AlgorithmKind, BackendKind, DataScheme, ExperimentConfig, FaultSpec, LatencyMode,
};
use crate::data::sampler::eval_batches;
use crate::data::synthetic::{
    femnist_federation, pool_federation, FederatedData, SyntheticSpec,
};
use crate::data::{partition, Batch};
use crate::error::{CfelError, Result};
use crate::metrics::{History, RoundRecord};
use crate::netsim::{
    ClosedFormEstimator, EventDrivenEstimator, LatencyEstimator, NetworkModel, RoundLatency,
    RoundTiming,
};
use crate::runtime::{EvalResult, Manifest, MockBackend, PjrtBackend, TrainBackend};
use crate::topology::{Graph, MixingMatrix};
use crate::util::rng::Rng;
use crate::util::threadpool::{default_threads, parallel_map};

/// Immutable per-round view of the coordinator, shared by the parallel
/// cluster tasks. Splitting the round state this way lets every alive
/// cluster train concurrently against shared read-only data while the
/// mutable [`ClusterState`] shards are only written after the join, in
/// deterministic cluster order — so results are bit-identical for any
/// `CFEL_THREADS`.
pub(crate) struct RoundContext<'a> {
    pub backend: &'a dyn TrainBackend,
    pub fed: &'a FederatedData,
    pub cfg: &'a ExperimentConfig,
    pub rng: &'a Rng,
}

impl RoundContext<'_> {
    /// Deterministic per-(round-phase, cluster) stream: participant
    /// sampling. Stable no matter how many clusters run concurrently or
    /// in which order the scheduler interleaves them.
    pub(crate) fn cluster_rng(&self, ci: usize, phase: u64) -> Rng {
        self.rng.split(0x9A27_0000 + ci as u64).split(phase)
    }

    /// Deterministic per-(round-phase, device) stream: local SGD batch
    /// order. Derived from the root seed, not from any worker-thread
    /// state, so a device's trajectory is independent of thread count.
    pub(crate) fn device_rng(&self, dev: usize, phase: u64) -> Rng {
        self.rng.split(0x5EED_0000 + dev as u64).split(phase)
    }
}

/// Aggregate statistics of one global round's local-training phase.
#[derive(Debug, Default, Clone)]
pub struct RoundStats {
    /// (device_id, sgd_steps) for every participating device.
    pub device_steps: Vec<(usize, usize)>,
    pub loss_sum: f64,
    pub step_count: usize,
    /// Per-device/per-cluster virtual timing, filled by the event-driven
    /// latency estimator (empty in closed-form mode).
    pub timing: RoundTiming,
}

impl RoundStats {
    pub fn mean_loss(&self) -> f64 {
        if self.step_count == 0 {
            f64::NAN
        } else {
            self.loss_sum / self.step_count as f64
        }
    }
}

/// A kept-late model report awaiting a stale merge (semi-sync policy):
/// the device's trained parameters, its Eq. 6 weight, when the report
/// arrives on the cluster's virtual clock, and which edge phase produced
/// it (the staleness anchor).
#[derive(Debug, Clone)]
pub(crate) struct PendingReport {
    pub params: Vec<f32>,
    pub n_samples: usize,
    /// Arrival instant on the cluster's *absolute* virtual clock.
    pub arrive_abs_s: f64,
    /// Global edge-phase counter the report was trained in.
    pub origin_phase: u64,
}

/// The CFEL system runtime.
pub struct Coordinator {
    pub cfg: ExperimentConfig,
    pub backend: Box<dyn TrainBackend>,
    pub fed: FederatedData,
    pub clusters: Vec<ClusterState>,
    pub graph: Graph,
    /// H^π over the *current* alive subgraph.
    pub h_pi: MixingMatrix,
    pub net: NetworkModel,
    /// Round-latency estimator (closed-form Eq. 8 or the event sim),
    /// selected by the config's `latency` field.
    pub latency: Box<dyn LatencyEstimator>,
    /// Edge-round close policy (full barrier / deadline-drop / semi-sync),
    /// from the config's `agg_policy` / `deadline_s` fields.
    pub policy: Box<dyn AggregationPolicy>,
    pub eval_set: Vec<Batch>,
    pub rng: Rng,
    /// Alive flag per cluster (fault injection).
    pub alive: Vec<bool>,
    /// Whether the central aggregator (cloud/hub) is alive.
    pub aggregator_alive: bool,
    /// Absolute virtual time per cluster, advanced at every simulated
    /// phase close and re-synced at inter-cluster barriers (event mode;
    /// stays 0 in closed-form mode). Anchors late-report arrivals.
    pub(crate) cluster_clock_s: Vec<f64>,
    /// Kept-late reports per cluster, awaiting their stale merge.
    pub(crate) pending: Vec<Vec<PendingReport>>,
    /// Scratch buffer reused by gossip.
    pub(crate) scratch: Vec<f32>,
    /// Verbose per-round logging.
    pub verbose: bool,
}

impl Coordinator {
    /// Build the full system from a config (backend, data, topology, net).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Coordinator> {
        cfg.validate()?;
        let backend: Box<dyn TrainBackend> = match &cfg.backend {
            BackendKind::Mock { hidden } => {
                // The mock MLP trains on the mlp_synth-shaped task.
                Box::new(MockBackend::new(64, *hidden, 10, 16))
            }
            BackendKind::Pjrt { model, artifacts_dir } => {
                let dir = artifacts_dir
                    .clone()
                    .unwrap_or_else(Manifest::default_dir);
                Box::new(PjrtBackend::load(&dir, model)?)
            }
        };
        Self::with_backend(cfg.clone(), backend)
    }

    /// Build with an explicit backend (tests inject custom mocks here).
    pub fn with_backend(
        cfg: ExperimentConfig,
        backend: Box<dyn TrainBackend>,
    ) -> Result<Coordinator> {
        cfg.validate()?;
        let rng = Rng::new(cfg.seed);
        let fed = Self::build_data(&cfg, &*backend, &rng)?;

        // Devices are assigned to clusters contiguously (paper §5.2):
        // cluster i owns devices [i·dpc, (i+1)·dpc).
        let dpc = cfg.devices_per_cluster();
        let param_count = backend.param_count();
        let init = backend.init_state(&rng.split(0x1217)).params;
        let clusters: Vec<ClusterState> = (0..cfg.n_clusters)
            .map(|ci| {
                let device_ids: Vec<usize> = (ci * dpc..(ci + 1) * dpc).collect();
                let n_samples = device_ids
                    .iter()
                    .map(|&d| fed.device_train[d].len())
                    .sum();
                ClusterState {
                    device_ids,
                    model: init.clone(),
                    n_samples,
                }
            })
            .collect();
        debug_assert_eq!(init.len(), param_count);

        let graph = Graph::by_name(&cfg.topology, cfg.n_clusters, &rng.split(0x706F))?;
        if !graph.is_connected() {
            return Err(CfelError::Topology(format!(
                "backhaul {} is not connected",
                cfg.topology
            )));
        }
        let h_pi = MixingMatrix::metropolis(&graph).power(cfg.pi);

        let mut net = NetworkModel::paper_defaults(
            cfg.n_devices,
            backend.flops_per_sample(),
            backend.batch_size(),
            param_count,
        );
        // Lossy upload compression shrinks every transmitted model.
        net.model_bits *= cfg.compression.ratio();
        if let Some(lo) = cfg.heterogeneity {
            net = net.with_heterogeneity(lo, &rng.split(0x4E37));
        }
        if let Some(spec) = cfg.stragglers {
            net = net.with_stragglers(spec, &rng.split(0x5746));
        }
        let latency: Box<dyn LatencyEstimator> = match cfg.latency {
            LatencyMode::ClosedForm => Box::new(ClosedFormEstimator),
            LatencyMode::EventDriven => Box::new(EventDrivenEstimator),
        };
        let policy = cfg.resolved_policy().build(cfg.staleness_exp);

        let eval_set = eval_batches(&fed.test, backend.batch_size());
        let n_clusters = cfg.n_clusters;
        Ok(Coordinator {
            cfg,
            backend,
            fed,
            clusters,
            graph,
            h_pi,
            net,
            latency,
            policy,
            eval_set,
            rng,
            alive: vec![true; n_clusters],
            aggregator_alive: true,
            cluster_clock_s: vec![0.0; n_clusters],
            pending: vec![Vec::new(); n_clusters],
            scratch: Vec::new(),
            verbose: false,
        })
    }

    fn build_data(
        cfg: &ExperimentConfig,
        backend: &dyn TrainBackend,
        rng: &Rng,
    ) -> Result<FederatedData> {
        // The synthetic spec must match the backend's input shape.
        let mut spec = SyntheticSpec {
            dim: backend.flat_dim(),
            num_classes: backend.num_classes(),
            ..SyntheticSpec::mlp_synth()
        };
        if let Some(n) = cfg.data_noise {
            spec.noise = n;
        }
        if let Some(s) = cfg.writer_style {
            spec.writer_style = s;
        }
        let data_rng = rng.split(0xDA7A);
        let fed = match &cfg.data {
            DataScheme::FemnistWriters { label_alpha } => femnist_federation(
                spec,
                cfg.n_devices,
                cfg.samples_per_device,
                *label_alpha,
                &data_rng,
            ),
            scheme => {
                let pool_size = cfg.n_devices * cfg.samples_per_device;
                // Build the index partition over a balanced pool whose
                // labels are i % num_classes (global_pool's layout).
                let labels: Vec<u32> = (0..pool_size)
                    .map(|i| (i % backend.num_classes()) as u32)
                    .collect();
                let parts = match scheme {
                    DataScheme::PoolIid => partition::iid(pool_size, cfg.n_devices, &data_rng),
                    DataScheme::PoolDirichlet { alpha } => partition::dirichlet(
                        &labels,
                        backend.num_classes(),
                        cfg.n_devices,
                        *alpha,
                        &data_rng,
                    ),
                    DataScheme::ClusterIid => partition::cluster_iid(
                        &labels,
                        cfg.n_clusters,
                        cfg.devices_per_cluster(),
                        &data_rng,
                    )?,
                    DataScheme::ClusterNonIid { c_labels } => partition::cluster_noniid(
                        &labels,
                        cfg.n_clusters,
                        cfg.devices_per_cluster(),
                        *c_labels,
                        &data_rng,
                    )?,
                    DataScheme::FemnistWriters { .. } => unreachable!(),
                };
                partition::validate_partition(&parts, pool_size, true)
                    .map_err(|e| CfelError::Data(format!("partition invalid: {e}")))?;
                pool_federation(spec, pool_size, cfg.test_size, &parts, &data_rng)
            }
        };
        for (k, d) in fed.device_train.iter().enumerate() {
            if d.is_empty() {
                return Err(CfelError::Data(format!("device {k} got no data")));
            }
        }
        Ok(fed)
    }

    // ----- shared round machinery ------------------------------------------

    /// Indices of currently alive clusters.
    pub fn alive_clusters(&self) -> Vec<usize> {
        (0..self.clusters.len()).filter(|&i| self.alive[i]).collect()
    }

    /// Borrow the immutable round context the parallel cluster tasks share.
    pub(crate) fn round_ctx(&self) -> RoundContext<'_> {
        RoundContext {
            backend: &*self.backend,
            fed: &self.fed,
            cfg: &self.cfg,
            rng: &self.rng,
        }
    }

    /// Cloud aggregation (FedAvg / Hier-FAvg): size-weighted average over
    /// alive clusters, broadcast back to every alive cluster. A no-op when
    /// every cluster is dead (nothing to average).
    pub(crate) fn cloud_aggregate(&mut self) -> Result<()> {
        let alive = self.alive_clusters();
        if alive.is_empty() {
            return Ok(());
        }
        let models: Vec<Vec<f32>> = alive
            .iter()
            .map(|&i| self.clusters[i].model.clone())
            .collect();
        let sizes: Vec<usize> = alive.iter().map(|&i| self.clusters[i].n_samples).collect();
        let global = aggregation::global_average(&models, &sizes)?;
        for &i in &alive {
            self.clusters[i].model.copy_from_slice(&global);
        }
        Ok(())
    }

    /// Inter-cluster gossip (Eq. 7) over the alive subgraph. Backhaul
    /// messages go through the configured compressor first (what the
    /// neighbouring servers actually receive).
    pub(crate) fn gossip(&mut self) {
        let alive = self.alive_clusters();
        if alive.len() <= 1 {
            return;
        }
        let mut models: Vec<Vec<f32>> = alive
            .iter()
            .map(|&i| std::mem::take(&mut self.clusters[i].model))
            .collect();
        for m in &mut models {
            self.cfg.compression.roundtrip(m);
        }
        aggregation::gossip_mix(&mut models, &self.h_pi, &mut self.scratch);
        for (slot, &i) in alive.iter().enumerate() {
            self.clusters[i].model = std::mem::take(&mut models[slot]);
        }
    }

    /// Apply any scheduled fault at the start of `round`.
    pub(crate) fn apply_fault(&mut self, round: usize) -> Result<()> {
        match self.cfg.fault {
            Some(FaultSpec::KillCluster { at_round, cluster }) if at_round == round => {
                if self.cfg.algorithm == AlgorithmKind::CeFedAvg {
                    // Rebuild the gossip matrix over the surviving graph.
                    let (sub, _map) = self.graph.remove_node(self.count_alive_before(cluster))?;
                    if !sub.is_connected() {
                        return Err(CfelError::Topology(
                            "fault disconnected the backhaul".into(),
                        ));
                    }
                    self.h_pi = MixingMatrix::metropolis(&sub).power(self.cfg.pi);
                    self.graph = sub;
                }
                self.alive[cluster] = false;
                if self.verbose {
                    eprintln!("[fault] cluster {cluster} killed at round {round}");
                }
            }
            Some(FaultSpec::KillAggregator { at_round }) if at_round == round => {
                self.aggregator_alive = false;
                if self.verbose {
                    eprintln!("[fault] central aggregator killed at round {round}");
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Graph-node index of `cluster` among currently alive clusters.
    fn count_alive_before(&self, cluster: usize) -> usize {
        (0..cluster).filter(|&i| self.alive[i]).count()
    }

    /// Simulated latency of this round, via the configured estimator
    /// (closed-form Eq. 8 or the discrete-event simulator).
    pub(crate) fn round_latency(&self, stats: &RoundStats) -> RoundLatency {
        self.latency.round_latency(
            &self.net,
            self.cfg.algorithm,
            self.cfg.q,
            self.cfg.pi as usize,
            &stats.device_steps,
            &stats.timing,
        )
    }

    /// Re-sync per-cluster virtual clocks at the round's inter-cluster
    /// barrier (event mode only). CE-FedAvg clusters barrier at the π
    /// gossip hops; FedAvg / Hier-FAvg at the cloud aggregation —
    /// afterwards every alive cluster has waited for the slowest one, so
    /// all clocks jump to the round end. No barrier, no sync: Local-Edge
    /// clusters never cooperate, and a killed cloud aggregator (Table 1
    /// fault) stops FedAvg / Hier-FAvg from barriering too — in both
    /// cases the independent clocks are what keep each cluster's
    /// late-report arrival phases well defined.
    fn sync_cluster_clocks(&mut self, lat: &RoundLatency) {
        let barriers = match self.cfg.algorithm {
            AlgorithmKind::CeFedAvg => true,
            AlgorithmKind::FedAvg | AlgorithmKind::HierFAvg => self.aggregator_alive,
            AlgorithmKind::LocalEdge => false,
        };
        if !barriers || self.cfg.latency != LatencyMode::EventDriven {
            return;
        }
        let end = self
            .alive_clusters()
            .iter()
            .map(|&ci| self.cluster_clock_s[ci])
            .fold(f64::NEG_INFINITY, f64::max)
            + lat.backhaul_s;
        if end.is_finite() {
            for &ci in &self.alive_clusters() {
                self.cluster_clock_s[ci] = end;
            }
        }
    }

    /// Evaluate the current models on the common test set.
    ///
    /// CE-FedAvg / Local-Edge report the mean accuracy of edge models
    /// (paper §6.2); FedAvg / Hier-FAvg report the cloud model — which
    /// equals every cluster model right after cloud aggregation, so the
    /// same weighted-mean computation serves all four.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let alive = self.alive_clusters();
        // Per-cluster evals are independent; run them concurrently when
        // the backend allows it and reduce in alive order afterwards so
        // the floating-point accumulation is deterministic.
        let threads = if self.backend.parallel_devices() {
            default_threads(alive.len())
        } else {
            1
        };
        let results: Vec<Result<EvalResult>> = parallel_map(alive.len(), threads, |slot| {
            self.backend
                .eval(&self.clusters[alive[slot]].model, &self.eval_set)
        });
        let mut acc = 0.0;
        let mut loss = 0.0;
        let mut total = 0usize;
        for (&ci, r) in alive.iter().zip(results) {
            let r = r?;
            let w = self.clusters[ci].n_samples;
            acc += r.accuracy * w as f64;
            loss += r.loss * w as f64;
            total += w;
        }
        if total == 0 {
            return Ok((f64::NAN, f64::NAN));
        }
        Ok((acc / total as f64, loss / total as f64))
    }

    /// Consensus distance across alive cluster models (diagnostic).
    pub fn consensus(&self) -> f64 {
        let alive = self.alive_clusters();
        let models: Vec<Vec<f32>> = alive
            .iter()
            .map(|&i| self.clusters[i].model.clone())
            .collect();
        aggregation::consensus_distance(&models)
    }

    /// Run the configured number of global rounds; returns the history.
    pub fn run(&mut self) -> Result<History> {
        let mut history = History::new();
        let mut sim_time = 0.0f64;
        let mut wall = 0.0f64;
        for round in 0..self.cfg.rounds {
            let t0 = Instant::now();
            self.apply_fault(round)?;
            let stats = match self.cfg.algorithm {
                AlgorithmKind::CeFedAvg => self.ce_fedavg_round(round)?,
                AlgorithmKind::FedAvg => self.fedavg_round(round)?,
                AlgorithmKind::HierFAvg => self.hier_favg_round(round)?,
                AlgorithmKind::LocalEdge => self.local_edge_round(round)?,
            };
            wall += t0.elapsed().as_secs_f64();
            let lat = self.round_latency(&stats);
            sim_time += lat.total();
            self.sync_cluster_clocks(&lat);

            let (acc, tloss) = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                self.evaluate()?
            } else {
                (f64::NAN, f64::NAN)
            };
            let rec = RoundRecord {
                round: round + 1,
                sim_time_s: sim_time,
                wall_time_s: wall,
                compute_s: lat.compute_s,
                upload_s: lat.upload_s,
                backhaul_s: lat.backhaul_s,
                dropped_devices: stats.timing.dropped_devices,
                on_time_devices: stats.timing.on_time_devices,
                late_devices: stats.timing.late_devices,
                stale_merged: stats.timing.stale_merged,
                close_reason: stats.timing.close_reason_summary(),
                train_loss: stats.mean_loss(),
                test_accuracy: acc,
                test_loss: tloss,
                consensus: self.consensus(),
                steps: stats.step_count,
            };
            if self.verbose {
                let mut extras = String::new();
                if rec.dropped_devices > 0 {
                    extras.push_str(&format!("  dropped {}", rec.dropped_devices));
                }
                if rec.late_devices > 0 || rec.stale_merged > 0 {
                    extras.push_str(&format!(
                        "  late {} stale-merged {}",
                        rec.late_devices,
                        rec.stale_merged
                    ));
                }
                eprintln!(
                    "[{}] round {:>3}  loss {:.4}  acc {}  sim {:.1}s{}",
                    self.cfg.algorithm.name(),
                    rec.round,
                    rec.train_loss,
                    if acc.is_nan() {
                        "  -  ".to_string()
                    } else {
                        format!("{:.4}", acc)
                    },
                    sim_time,
                    extras
                );
            }
            history.push(rec);
        }
        Ok(history)
    }
}
