//! Per-cluster state: the edge server's model and its device roster.

use crate::aggregation;
use crate::coordinator::trainer::LocalOutcome;
use crate::error::Result;

/// One model report queued for an Eq. 6 merge: a flat parameter vector,
/// its sample-count weight, and the staleness discount the close policy
/// assigned (1.0 for fresh on-time reports).
#[derive(Debug, Clone, Copy)]
pub struct WeightedReport<'a> {
    pub params: &'a [f32],
    pub n_samples: usize,
    /// Positive multiplier on the sample-count weight (`1/(1+s)^a` for a
    /// report `s` phases stale under semi-sync).
    pub discount: f64,
}

/// One edge server's state (the paper's y^{(i)} plus bookkeeping).
#[derive(Debug, Clone)]
pub struct ClusterState {
    /// Global device ids S_i managed by this edge server.
    pub device_ids: Vec<usize>,
    /// The edge model y^{(i)} as a flat parameter vector.
    pub model: Vec<f32>,
    /// Σ_k |D_k| over the cluster's devices (aggregation weights).
    pub n_samples: usize,
}

impl ClusterState {
    pub fn n_devices(&self) -> usize {
        self.device_ids.len()
    }

    /// Intra-cluster aggregation (Eq. 6): the size-weighted average of
    /// the participating devices' freshly trained models, written into
    /// `out` (normally the cluster's existing model buffer). A pure
    /// shard-local operation the parallel round engine applies per alive
    /// cluster after the training join.
    ///
    /// Weights are normalised over the outcomes actually present, so when
    /// a reporting deadline drops part of the participant set the
    /// survivors renormalize automatically. An empty set (everyone
    /// dropped) is an error — callers skip the cluster and keep its
    /// previous model instead.
    pub fn aggregate_into(outcomes: &[(usize, LocalOutcome)], out: &mut [f32]) -> Result<()> {
        let reports: Vec<WeightedReport> = outcomes
            .iter()
            .map(|(_, o)| WeightedReport {
                params: &o.params,
                n_samples: o.n_samples,
                discount: 1.0,
            })
            .collect();
        Self::aggregate_reports_into(&reports, out)
    }

    /// Staleness-aware Eq. 6: the merge over fresh on-time reports plus
    /// any late reports a semi-sync policy deferred from earlier phases,
    /// weighted by `n_i · discount_i` and renormalized
    /// ([`aggregation::report_weights`]). With all discounts exactly 1.0
    /// this is bit-identical to [`ClusterState::aggregate_into`] — the
    /// plain path is implemented as a wrapper, which is what pins the
    /// semi-sync degenerate case to the full-barrier oracle.
    pub fn aggregate_reports_into(reports: &[WeightedReport], out: &mut [f32]) -> Result<()> {
        let ns: Vec<usize> = reports.iter().map(|r| r.n_samples).collect();
        let ds: Vec<f64> = reports.iter().map(|r| r.discount).collect();
        let weights = aggregation::report_weights(&ns, &ds)?;
        let rows: Vec<&[f32]> = reports.iter().map(|r| r.params).collect();
        aggregation::weighted_average_into(&rows, &weights, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let c = ClusterState { device_ids: vec![3, 4, 5], model: vec![0.0; 7], n_samples: 30 };
        assert_eq!(c.n_devices(), 3);
        assert_eq!(c.model.len(), 7);
    }

    #[test]
    fn aggregate_into_weights_by_sample_count() {
        let o = |params: Vec<f32>, n_samples: usize| LocalOutcome {
            params,
            steps: 1,
            loss_sum: 0.0,
            n_samples,
        };
        let outcomes = vec![(0usize, o(vec![0.0, 0.0], 30)), (1usize, o(vec![4.0, 8.0], 10))];
        let mut out = vec![9.0f32; 2];
        ClusterState::aggregate_into(&outcomes, &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0]); // 0.75 * 0 + 0.25 * [4, 8]
    }

    #[test]
    fn aggregate_empty_participants_errors_and_preserves_model() {
        // Regression for the deadline/fault path: an all-dropped cluster
        // must not panic and must leave the edge model untouched.
        let mut out = vec![3.0f32; 2];
        assert!(ClusterState::aggregate_into(&[], &mut out).is_err());
        assert_eq!(out, vec![3.0; 2]);
        assert!(ClusterState::aggregate_reports_into(&[], &mut out).is_err());
        assert_eq!(out, vec![3.0; 2]);
    }

    #[test]
    fn stale_reports_count_for_less() {
        // Equal sample counts, but the second report is two phases stale
        // at exponent 1 → discount 1/3 → weights 3/4 and 1/4.
        let a = vec![0.0f32, 0.0];
        let b = vec![4.0f32, 8.0];
        let reports = [
            WeightedReport { params: &a, n_samples: 10, discount: 1.0 },
            WeightedReport { params: &b, n_samples: 10, discount: 1.0 / 3.0 },
        ];
        let mut out = vec![9.0f32; 2];
        ClusterState::aggregate_reports_into(&reports, &mut out).unwrap();
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!((out[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn unit_discounts_match_plain_aggregate_bitwise() {
        let o = |params: Vec<f32>, n_samples: usize| LocalOutcome {
            params,
            steps: 1,
            loss_sum: 0.0,
            n_samples,
        };
        let outcomes =
            vec![(0usize, o(vec![0.1, 0.9], 30)), (1usize, o(vec![4.0, 8.0], 11))];
        let mut plain = vec![0.0f32; 2];
        ClusterState::aggregate_into(&outcomes, &mut plain).unwrap();
        let reports: Vec<WeightedReport> = outcomes
            .iter()
            .map(|(_, o)| WeightedReport {
                params: &o.params,
                n_samples: o.n_samples,
                discount: 1.0,
            })
            .collect();
        let mut stale = vec![0.0f32; 2];
        ClusterState::aggregate_reports_into(&reports, &mut stale).unwrap();
        for (p, s) in plain.iter().zip(&stale) {
            assert_eq!(p.to_bits(), s.to_bits());
        }
    }
}
