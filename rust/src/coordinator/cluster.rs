//! Per-cluster state: the edge server's model and its device roster.

/// One edge server's state (the paper's y^{(i)} plus bookkeeping).
#[derive(Debug, Clone)]
pub struct ClusterState {
    /// Global device ids S_i managed by this edge server.
    pub device_ids: Vec<usize>,
    /// The edge model y^{(i)} as a flat parameter vector.
    pub model: Vec<f32>,
    /// Σ_k |D_k| over the cluster's devices (aggregation weights).
    pub n_samples: usize,
}

impl ClusterState {
    pub fn n_devices(&self) -> usize {
        self.device_ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let c = ClusterState { device_ids: vec![3, 4, 5], model: vec![0.0; 7], n_samples: 30 };
        assert_eq!(c.n_devices(), 3);
        assert_eq!(c.model.len(), 7);
    }
}
