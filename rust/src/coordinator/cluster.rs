//! Per-cluster state: the edge server's model and its device roster.

use crate::aggregation;
use crate::coordinator::trainer::LocalOutcome;
use crate::error::{CfelError, Result};

/// One edge server's state (the paper's y^{(i)} plus bookkeeping).
#[derive(Debug, Clone)]
pub struct ClusterState {
    /// Global device ids S_i managed by this edge server.
    pub device_ids: Vec<usize>,
    /// The edge model y^{(i)} as a flat parameter vector.
    pub model: Vec<f32>,
    /// Σ_k |D_k| over the cluster's devices (aggregation weights).
    pub n_samples: usize,
}

impl ClusterState {
    pub fn n_devices(&self) -> usize {
        self.device_ids.len()
    }

    /// Intra-cluster aggregation (Eq. 6): the size-weighted average of
    /// the participating devices' freshly trained models, written into
    /// `out` (normally the cluster's existing model buffer). A pure
    /// shard-local operation the parallel round engine applies per alive
    /// cluster after the training join.
    ///
    /// Weights are normalised over the outcomes actually present, so when
    /// a reporting deadline drops part of the participant set the
    /// survivors renormalize automatically. An empty set (everyone
    /// dropped) is an error — callers skip the cluster and keep its
    /// previous model instead.
    pub fn aggregate_into(outcomes: &[(usize, LocalOutcome)], out: &mut [f32]) -> Result<()> {
        let total: usize = outcomes.iter().map(|(_, o)| o.n_samples).sum();
        if total == 0 {
            return Err(CfelError::Aggregation(
                "Eq. 6 aggregation over an empty participant set".into(),
            ));
        }
        let weights: Vec<f64> = outcomes
            .iter()
            .map(|(_, o)| o.n_samples as f64 / total as f64)
            .collect();
        let rows: Vec<&[f32]> = outcomes.iter().map(|(_, o)| o.params.as_slice()).collect();
        aggregation::weighted_average_into(&rows, &weights, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let c = ClusterState { device_ids: vec![3, 4, 5], model: vec![0.0; 7], n_samples: 30 };
        assert_eq!(c.n_devices(), 3);
        assert_eq!(c.model.len(), 7);
    }

    #[test]
    fn aggregate_into_weights_by_sample_count() {
        let o = |params: Vec<f32>, n_samples: usize| LocalOutcome {
            params,
            steps: 1,
            loss_sum: 0.0,
            n_samples,
        };
        let outcomes = vec![(0usize, o(vec![0.0, 0.0], 30)), (1usize, o(vec![4.0, 8.0], 10))];
        let mut out = vec![9.0f32; 2];
        ClusterState::aggregate_into(&outcomes, &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0]); // 0.75 * 0 + 0.25 * [4, 8]
    }

    #[test]
    fn aggregate_empty_participants_errors_and_preserves_model() {
        // Regression for the deadline/fault path: an all-dropped cluster
        // must not panic and must leave the edge model untouched.
        let mut out = vec![3.0f32; 2];
        assert!(ClusterState::aggregate_into(&[], &mut out).is_err());
        assert_eq!(out, vec![3.0; 2]);
    }
}
