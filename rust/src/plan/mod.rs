//! Composable federation plans — the algorithm layer as *data*.
//!
//! The paper's four algorithms (CE-FedAvg, FedAvg, Hier-FAvg, Local-Edge)
//! are different orderings of the same four primitives: local training
//! with an intra-cluster Eq. 6 aggregation ([`Step::EdgePhase`]), π-step
//! backhaul gossip (Eq. 7, [`Step::Gossip`]), a cloud aggregation
//! ([`Step::CloudAggregate`]), and repetition ([`Step::Repeat`]). A
//! [`Plan`] is one global round expressed as a sequence of those steps;
//! the coordinator runs it through a single interpreter
//! (`Coordinator::plan_round`) instead of a closed per-algorithm match.
//!
//! The canned constructors in [`canned`] reproduce the four paper
//! algorithms exactly (`AlgorithmKind` now merely selects one of them —
//! pinned bit-identical to the frozen direct-dispatch loop by
//! `rust/tests/plan_equivalence.rs`), and any other ordering — gossip
//! interleaved with edge rounds, cloud-assisted gossip, heterogeneous
//! cadences — is just a different `Plan`, written in the text grammar
//! ([`Plan::parse`] / `--plan`) or built programmatically.
//!
//! # Text grammar
//!
//! ```text
//! plan  := step (';' step)*
//! step  := atom ('*' N)*            repetition, left-associative
//! atom  := 'edge(' E ')'            E local epochs, report to the edge
//!        | 'edge(' E ')@cloud'      E local epochs, report to the cloud
//!        | 'edge(' E ')@masked'     E local epochs, masked (secure-agg) edge reports
//!        | 'gossip(' P ')'          P backhaul gossip steps (Eq. 7)
//!        | 'cloud'                  cloud aggregation over alive clusters
//!        | '(' plan ')'             grouping
//! ```
//!
//! Whitespace is insignificant. Examples:
//!
//! * CE-FedAvg (τ=2, q=2, π=10): `edge(2)*2; gossip(10)`
//! * FedAvg (qτ=4): `edge(4)@cloud; cloud`
//! * Hier-FAvg (τ=2, q=8): `edge(2)*7; edge(2)@cloud; cloud`
//! * Local-Edge: `edge(2)*2`
//! * A hybrid no enum variant can express: `(edge(2); gossip(3))*2; cloud`
//!
//! [`std::fmt::Display`] pretty-prints the canonical spelling, and
//! `parse(print(plan)) == plan` holds for every valid plan
//! (property-tested in `rust/tests/proptest_invariants.rs`).
//!
//! # Steps and the event engine
//!
//! [`Step::EdgePhase`] is where the sharded event engine runs: all alive
//! clusters' phases are simulated as shards of one calendar queue
//! (`netsim::calendar`), independent until the next [`Step::Gossip`] /
//! [`Step::CloudAggregate`] barrier merges them in deterministic order.
//! The interpreter walks steps single-threaded; only device training
//! inside an edge phase fans out. See `docs/ARCHITECTURE.md` for the
//! full round pipeline and `docs/DETERMINISM.md` for why any step
//! ordering stays bit-identical under `CFEL_THREADS`.

pub mod canned;
mod parse;

use std::fmt;

use crate::error::{CfelError, Result};
use crate::netsim::UploadChannel;

/// One primitive of a global round. See the module docs for the grammar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Step {
    /// Every alive cluster trains its sampled participants `epochs` local
    /// epochs from its edge model, then aggregates intra-cluster (Eq. 6)
    /// under the configured close policy. `channel` names the uplink the
    /// reports travel over (edge server or cloud).
    EdgePhase { epochs: usize, channel: UploadChannel },
    /// `pi` gossip steps with the doubly-stochastic H over the alive
    /// backhaul subgraph (Eq. 7), applied as one H^π multiplication.
    Gossip { pi: u32 },
    /// Size-weighted cloud aggregation over alive clusters, broadcast
    /// back (skipped while the central aggregator is dead). Charged no
    /// latency of its own — transport is what costs in the paper's model,
    /// so route a phase's reports `@cloud` to pay the 1 Mbps uplink
    /// (exactly how the canned FedAvg / Hier-FAvg plans are built).
    CloudAggregate,
    /// Run `body` in order, `n` times (`n = 0` executes nothing).
    Repeat { n: usize, body: Vec<Step> },
}

/// A global round as a sequence of [`Step`]s — the unit the coordinator's
/// interpreter executes `rounds` times.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    pub steps: Vec<Step>,
}

/// Per-round communication totals of a plan (closed-form Eq. 8 inputs):
/// how many report phases ride each uplink and how many gossip steps the
/// backhaul carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PlanComms {
    /// Edge phases reporting device→edge (counted with repetition).
    pub edge_uploads: usize,
    /// Edge phases reporting device→cloud (counted with repetition).
    pub cloud_uploads: usize,
    /// Edge phases reporting device→edge under secure aggregation
    /// (counted with repetition).
    pub masked_uploads: usize,
    /// Total gossip steps Σπ over the round (counted with repetition).
    pub gossip_pi: usize,
}

impl Plan {
    pub fn from_steps(steps: Vec<Step>) -> Plan {
        Plan { steps }
    }

    /// Effective number of edge phases one round executes (with
    /// repetition) — how far one round advances the coordinator's phase
    /// cursor, i.e. the stride of the deterministic per-(phase, device)
    /// RNG streams. (The cursor accumulates executed phases, so a
    /// controller may swap plans between rounds without reusing a
    /// stream; for a fixed plan the cursor equals `round * edge_phases()`
    /// exactly as before.)
    pub fn edge_phases(&self) -> usize {
        let c = self.comms();
        c.edge_uploads + c.cloud_uploads + c.masked_uploads
    }

    /// Per-round communication totals (see [`PlanComms`]).
    pub fn comms(&self) -> PlanComms {
        fn walk(steps: &[Step], mult: usize, c: &mut PlanComms) {
            for s in steps {
                match s {
                    Step::EdgePhase { channel, .. } => match channel {
                        UploadChannel::DeviceEdge => c.edge_uploads += mult,
                        UploadChannel::DeviceCloud => c.cloud_uploads += mult,
                        UploadChannel::DeviceEdgeMasked => c.masked_uploads += mult,
                    },
                    Step::Gossip { pi } => c.gossip_pi += mult * *pi as usize,
                    Step::CloudAggregate => {}
                    Step::Repeat { n, body } => walk(body, mult * n, c),
                }
            }
        }
        let mut c = PlanComms::default();
        walk(&self.steps, 1, &mut c);
        c
    }

    /// Whether any gossip step executes (decides fault-time gossip-matrix
    /// rebuilds and the inter-cluster clock barrier).
    pub fn has_gossip(&self) -> bool {
        fn walk(steps: &[Step]) -> bool {
            steps.iter().any(|s| match s {
                Step::Gossip { .. } => true,
                Step::Repeat { n, body } => *n > 0 && walk(body),
                _ => false,
            })
        }
        walk(&self.steps)
    }

    /// Whether any cloud aggregation executes.
    pub fn has_cloud_aggregate(&self) -> bool {
        fn walk(steps: &[Step]) -> bool {
            steps.iter().any(|s| match s {
                Step::CloudAggregate => true,
                Step::Repeat { n, body } => *n > 0 && walk(body),
                _ => false,
            })
        }
        walk(&self.steps)
    }

    /// Visit every executed gossip step's π in execution order (the
    /// event-driven estimator simulates each separately).
    pub fn for_each_gossip<F: FnMut(u32)>(&self, f: &mut F) {
        fn walk<F: FnMut(u32)>(steps: &[Step], f: &mut F) {
            for s in steps {
                match s {
                    Step::Gossip { pi } => f(*pi),
                    Step::Repeat { n, body } => {
                        for _ in 0..*n {
                            walk(body, f);
                        }
                    }
                    _ => {}
                }
            }
        }
        walk(&self.steps, f);
    }

    /// Structural validity: at least one edge phase actually executes
    /// (otherwise no device ever trains), every edge phase runs ≥ 1
    /// epoch, and every gossip step takes ≥ 1 hop.
    pub fn validate(&self) -> Result<()> {
        fn walk(steps: &[Step]) -> Result<()> {
            for s in steps {
                match s {
                    Step::EdgePhase { epochs, .. } => {
                        if *epochs == 0 {
                            return Err(CfelError::Config(
                                "plan edge phase needs >= 1 epoch".into(),
                            ));
                        }
                    }
                    Step::Gossip { pi } => {
                        if *pi == 0 {
                            return Err(CfelError::Config(
                                "plan gossip step needs >= 1 hop".into(),
                            ));
                        }
                    }
                    Step::CloudAggregate => {}
                    Step::Repeat { body, .. } => {
                        // An empty body would print as `()*N`, which the
                        // grammar rejects — it would break the
                        // parse(print(plan)) round trip (and JSON
                        // persistence) for an otherwise runnable plan.
                        if body.is_empty() {
                            return Err(CfelError::Config(
                                "plan repeat body must not be empty".into(),
                            ));
                        }
                        walk(body)?
                    }
                }
            }
            Ok(())
        }
        walk(&self.steps)?;
        if self.edge_phases() == 0 {
            return Err(CfelError::Config(format!(
                "plan {self} never trains: it needs at least one edge \
                 phase that executes (a repeat count of 0 runs nothing)"
            )));
        }
        Ok(())
    }

    /// Parse the text grammar (see the module docs). Errors spell the
    /// grammar out so an unknown `--plan` spec is self-documenting.
    pub fn parse(spec: &str) -> Result<Plan> {
        parse::parse(spec)
    }

    /// Canonical spec string (the [`fmt::Display`] output).
    pub fn spec(&self) -> String {
        self.to_string()
    }

    // ----- Controller rewrites ----------------------------------------------
    //
    // The adaptive control plane (`control`) rewrites plans between
    // rounds; these two renderings are the floating-aggregation-point
    // moves of arXiv:2203.13950. Both preserve the edge-phase count, so
    // a swapped plan consumes the same number of per-phase RNG streams
    // as the one it replaces.

    /// Decentralized rendering: every `cloud` aggregate becomes a
    /// `gossip(pi)` consensus step and `@cloud` report phases come back
    /// to the edge uplink — aggregation floats off the cloud entirely.
    pub fn decentralize(&self, pi: u32) -> Plan {
        let pi = pi.max(1);
        fn walk(steps: &[Step], pi: u32) -> Vec<Step> {
            steps
                .iter()
                .map(|s| match s {
                    Step::CloudAggregate => Step::Gossip { pi },
                    // Only `@cloud` reports come back to the edge uplink;
                    // a masked phase keeps its secure-aggregation channel
                    // (the privacy property must survive controller moves).
                    Step::EdgePhase { epochs, channel: UploadChannel::DeviceCloud } => {
                        Step::EdgePhase {
                            epochs: *epochs,
                            channel: UploadChannel::DeviceEdge,
                        }
                    }
                    Step::Repeat { n, body } => {
                        Step::Repeat { n: *n, body: walk(body, pi) }
                    }
                    other => other.clone(),
                })
                .collect()
        }
        Plan { steps: walk(&self.steps, pi) }
    }

    /// Secure-aggregation rendering: every plain device→edge report phase
    /// switches to the masked channel (`--secagg` sugar; `@cloud` phases
    /// are left alone — the cloud uplink has no pairwise-masking tier).
    /// Preserves the edge-phase count, so the phase cursor and every
    /// per-(phase, device) RNG stream line up with the unmasked plan.
    pub fn mask_edges(&self) -> Plan {
        fn walk(steps: &[Step]) -> Vec<Step> {
            steps
                .iter()
                .map(|s| match s {
                    Step::EdgePhase { epochs, channel: UploadChannel::DeviceEdge } => {
                        Step::EdgePhase {
                            epochs: *epochs,
                            channel: UploadChannel::DeviceEdgeMasked,
                        }
                    }
                    Step::Repeat { n, body } => Step::Repeat { n: *n, body: walk(body) },
                    other => other.clone(),
                })
                .collect()
        }
        Plan { steps: walk(&self.steps) }
    }

    /// Centralized rendering: every `gossip` step becomes a cloud
    /// aggregation (the inverse move of [`Plan::decentralize`]; report
    /// channels are left as written).
    pub fn centralize(&self) -> Plan {
        fn walk(steps: &[Step]) -> Vec<Step> {
            steps
                .iter()
                .map(|s| match s {
                    Step::Gossip { .. } => Step::CloudAggregate,
                    Step::Repeat { n, body } => Step::Repeat { n: *n, body: walk(body) },
                    other => other.clone(),
                })
                .collect()
        }
        Plan { steps: walk(&self.steps) }
    }
}

impl fmt::Display for Step {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Step::EdgePhase { epochs, channel: UploadChannel::DeviceEdge } => {
                write!(f, "edge({epochs})")
            }
            Step::EdgePhase { epochs, channel: UploadChannel::DeviceCloud } => {
                write!(f, "edge({epochs})@cloud")
            }
            Step::EdgePhase { epochs, channel: UploadChannel::DeviceEdgeMasked } => {
                write!(f, "edge({epochs})@masked")
            }
            Step::Gossip { pi } => write!(f, "gossip({pi})"),
            Step::CloudAggregate => write!(f, "cloud"),
            Step::Repeat { n, body } => {
                if let [only] = body.as_slice() {
                    // Single-step bodies chain left-associatively:
                    // `edge(2)*2*3` is Repeat{3, [Repeat{2, [edge(2)]}]}.
                    write!(f, "{only}*{n}")
                } else {
                    write!(f, "(")?;
                    for (i, s) in body.iter().enumerate() {
                        if i > 0 {
                            write!(f, "; ")?;
                        }
                        write!(f, "{s}")?;
                    }
                    write!(f, ")*{n}")
                }
            }
        }
    }
}

impl fmt::Display for Plan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, s) in self.steps.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            write!(f, "{s}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edge(epochs: usize) -> Step {
        Step::EdgePhase { epochs, channel: UploadChannel::DeviceEdge }
    }

    #[test]
    fn comms_count_with_repetition() {
        let p = Plan::from_steps(vec![
            Step::Repeat { n: 3, body: vec![edge(2), Step::Gossip { pi: 4 }] },
            Step::EdgePhase { epochs: 1, channel: UploadChannel::DeviceCloud },
            Step::CloudAggregate,
        ]);
        let c = p.comms();
        assert_eq!(c.edge_uploads, 3);
        assert_eq!(c.cloud_uploads, 1);
        assert_eq!(c.masked_uploads, 0);
        assert_eq!(c.gossip_pi, 12);
        assert_eq!(p.edge_phases(), 4);
        assert!(p.has_gossip());
        assert!(p.has_cloud_aggregate());
    }

    #[test]
    fn masked_phases_count_into_comms_and_the_phase_cursor() {
        let p = Plan::from_steps(vec![
            Step::Repeat {
                n: 2,
                body: vec![Step::EdgePhase {
                    epochs: 3,
                    channel: UploadChannel::DeviceEdgeMasked,
                }],
            },
            edge(1),
        ]);
        let c = p.comms();
        assert_eq!(c.masked_uploads, 2);
        assert_eq!(c.edge_uploads, 1);
        // Masked phases consume per-phase RNG streams like any other edge
        // phase — edge_phases() is the phase-cursor stride.
        assert_eq!(p.edge_phases(), 3);
        p.validate().unwrap();
    }

    #[test]
    fn zero_repeat_executes_nothing() {
        let p = Plan::from_steps(vec![
            Step::Repeat { n: 0, body: vec![Step::Gossip { pi: 5 }] },
            edge(1),
        ]);
        assert!(!p.has_gossip());
        assert_eq!(p.comms().gossip_pi, 0);
        let mut seen = Vec::new();
        p.for_each_gossip(&mut |pi| seen.push(pi));
        assert!(seen.is_empty());
        p.validate().unwrap();
    }

    #[test]
    fn gossip_walk_follows_execution_order() {
        let p = Plan::from_steps(vec![
            edge(1),
            Step::Repeat {
                n: 2,
                body: vec![Step::Gossip { pi: 3 }, Step::Gossip { pi: 7 }],
            },
        ]);
        let mut seen = Vec::new();
        p.for_each_gossip(&mut |pi| seen.push(pi));
        assert_eq!(seen, vec![3, 7, 3, 7]);
    }

    #[test]
    fn validate_rejects_trainless_and_degenerate_steps() {
        assert!(Plan::from_steps(vec![Step::Gossip { pi: 2 }]).validate().is_err());
        assert!(Plan::from_steps(vec![edge(0)]).validate().is_err());
        assert!(Plan::from_steps(vec![edge(1), Step::Gossip { pi: 0 }])
            .validate()
            .is_err());
        // An edge phase hidden behind a zero repeat never executes.
        let p = Plan::from_steps(vec![Step::Repeat { n: 0, body: vec![edge(2)] }]);
        assert!(p.validate().is_err());
        // An empty repeat body would not survive the grammar round trip.
        let p = Plan::from_steps(vec![edge(1), Step::Repeat { n: 2, body: vec![] }]);
        assert!(p.validate().is_err(), "empty repeat body accepted");
        Plan::from_steps(vec![edge(1)]).validate().unwrap();
    }

    #[test]
    fn controller_rewrites_swap_aggregation_point() {
        // FedAvg's canned shape decentralizes into pure edge + gossip...
        let p = Plan::parse("edge(4)@cloud; cloud").unwrap();
        let d = p.decentralize(10);
        assert_eq!(d.to_string(), "edge(4); gossip(10)");
        assert_eq!(d.edge_phases(), p.edge_phases());
        d.validate().unwrap();
        // ...and the inverse move recentralizes a gossip plan.
        let g = Plan::parse("(edge(2); gossip(3))*2").unwrap();
        let c = g.centralize();
        assert_eq!(c.to_string(), "(edge(2); cloud)*2");
        assert_eq!(c.edge_phases(), g.edge_phases());
        c.validate().unwrap();
        // Rewrites are idempotent on already-converted plans.
        assert_eq!(d.decentralize(10), d);
        assert_eq!(c.centralize(), c);
        // pi 0 is clamped, never emitting an invalid gossip step.
        assert_eq!(p.decentralize(0).to_string(), "edge(4); gossip(1)");
        // Masked phases keep their channel through both rewrites.
        let m = Plan::parse("edge(2)@masked; cloud").unwrap();
        assert_eq!(m.decentralize(5).to_string(), "edge(2)@masked; gossip(5)");
        assert_eq!(m.centralize().to_string(), "edge(2)@masked; cloud");
    }

    #[test]
    fn mask_edges_rewrites_only_plain_edge_phases() {
        let p = Plan::parse("edge(2)*2; edge(1)@cloud; gossip(3); cloud").unwrap();
        let m = p.mask_edges();
        assert_eq!(
            m.to_string(),
            "edge(2)@masked*2; edge(1)@cloud; gossip(3); cloud"
        );
        assert_eq!(m.edge_phases(), p.edge_phases());
        m.validate().unwrap();
        // Idempotent: already-masked phases are untouched.
        assert_eq!(m.mask_edges(), m);
        // A pure-cloud plan has nothing to mask.
        let cloudy = Plan::parse("edge(4)@cloud; cloud").unwrap();
        assert_eq!(cloudy.mask_edges(), cloudy);
    }

    #[test]
    fn display_is_canonical() {
        let p = Plan::from_steps(vec![
            Step::Repeat { n: 2, body: vec![edge(2)] },
            Step::Repeat {
                n: 3,
                body: vec![edge(1), Step::Gossip { pi: 4 }],
            },
            Step::EdgePhase { epochs: 5, channel: UploadChannel::DeviceCloud },
            Step::EdgePhase { epochs: 2, channel: UploadChannel::DeviceEdgeMasked },
            Step::CloudAggregate,
        ]);
        assert_eq!(
            p.to_string(),
            "edge(2)*2; (edge(1); gossip(4))*3; edge(5)@cloud; edge(2)@masked; cloud"
        );
        // Nested single-step repeats chain with `*`.
        let nested = Plan::from_steps(vec![Step::Repeat {
            n: 3,
            body: vec![Step::Repeat { n: 2, body: vec![edge(2)] }],
        }]);
        assert_eq!(nested.to_string(), "edge(2)*2*3");
    }
}
