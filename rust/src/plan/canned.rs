//! Canned plans — the paper's four algorithms expressed as [`Plan`]s.
//!
//! `AlgorithmKind` no longer selects a hand-written round loop; it merely
//! names one of these constructors, and the coordinator's single plan
//! interpreter runs the result. Each constructor documents the paper
//! semantics it encodes; `rust/tests/plan_equivalence.rs` pins every one
//! bit-identical — history rows, CSV, virtual times, all close policies,
//! any `CFEL_THREADS` — to the frozen pre-plan direct-dispatch loop
//! (`Coordinator::run_legacy`).

use crate::config::{AlgorithmKind, ExperimentConfig};
use crate::netsim::UploadChannel;
use crate::plan::{Plan, Step};

impl Plan {
    /// CE-FedAvg (Algorithm 1): q edge rounds of τ local epochs with
    /// intra-cluster Eq. 6 aggregation, then π gossip steps with the
    /// doubly-stochastic H over the edge backhaul (Eq. 7).
    pub fn ce_fedavg(cfg: &ExperimentConfig) -> Plan {
        Plan::from_steps(vec![
            Step::Repeat {
                n: cfg.q,
                body: vec![Step::EdgePhase {
                    epochs: cfg.tau,
                    channel: UploadChannel::DeviceEdge,
                }],
            },
            Step::Gossip { pi: cfg.pi },
        ])
    }

    /// Cloud FedAvg (§6.1 baseline): qτ local epochs straight from the
    /// global model, reported over the slow device→cloud links, then one
    /// cloud aggregation.
    pub fn fedavg(cfg: &ExperimentConfig) -> Plan {
        Plan::from_steps(vec![
            Step::EdgePhase {
                epochs: cfg.q * cfg.tau,
                channel: UploadChannel::DeviceCloud,
            },
            Step::CloudAggregate,
        ])
    }

    /// Hier-FAvg (Liu et al. [19]): q−1 edge rounds of τ epochs, one more
    /// τ-epoch round reporting to the cloud, then a cloud aggregation.
    pub fn hier_favg(cfg: &ExperimentConfig) -> Plan {
        Plan::from_steps(vec![
            Step::Repeat {
                n: cfg.q.saturating_sub(1),
                body: vec![Step::EdgePhase {
                    epochs: cfg.tau,
                    channel: UploadChannel::DeviceEdge,
                }],
            },
            Step::EdgePhase { epochs: cfg.tau, channel: UploadChannel::DeviceCloud },
            Step::CloudAggregate,
        ])
    }

    /// Local-Edge baseline: q edge rounds per global round and no
    /// inter-cluster cooperation of any kind.
    pub fn local_edge(cfg: &ExperimentConfig) -> Plan {
        Plan::from_steps(vec![Step::Repeat {
            n: cfg.q,
            body: vec![Step::EdgePhase {
                epochs: cfg.tau,
                channel: UploadChannel::DeviceEdge,
            }],
        }])
    }

    /// The canned plan an [`AlgorithmKind`] names.
    pub fn for_algorithm(alg: AlgorithmKind, cfg: &ExperimentConfig) -> Plan {
        match alg {
            AlgorithmKind::CeFedAvg => Plan::ce_fedavg(cfg),
            AlgorithmKind::FedAvg => Plan::fedavg(cfg),
            AlgorithmKind::HierFAvg => Plan::hier_favg(cfg),
            AlgorithmKind::LocalEdge => Plan::local_edge(cfg),
        }
    }
}

// The behavioural suites of the four retired algorithm files
// (`coordinator/{cefedavg,fedavg,hierfavg,localedge}.rs`) live on here:
// every test drives the same canned plan through the interpreter that the
// old hand-written round methods implemented.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AggPolicyKind, DataScheme, ExperimentConfig, FaultSpec, LatencyMode};
    use crate::coordinator::Coordinator;
    use crate::metrics::best_accuracy;
    use crate::netsim::StragglerSpec;

    fn cfg_for(alg: AlgorithmKind, rounds: usize) -> ExperimentConfig {
        let mut c = ExperimentConfig::quickstart();
        c.algorithm = alg;
        c.rounds = rounds;
        c
    }

    #[test]
    fn canned_plans_have_the_papers_shape() {
        let cfg = ExperimentConfig::quickstart(); // tau=2, q=2, pi=10
        assert_eq!(Plan::ce_fedavg(&cfg).to_string(), "edge(2)*2; gossip(10)");
        assert_eq!(Plan::fedavg(&cfg).to_string(), "edge(4)@cloud; cloud");
        assert_eq!(
            Plan::hier_favg(&cfg).to_string(),
            "edge(2)*1; edge(2)@cloud; cloud"
        );
        assert_eq!(Plan::local_edge(&cfg).to_string(), "edge(2)*2");
        for alg in AlgorithmKind::all() {
            let p = Plan::for_algorithm(alg, &cfg);
            p.validate().unwrap();
            assert_eq!(p.edge_phases(), if alg == AlgorithmKind::FedAvg { 1 } else { cfg.q });
            // Round-trip through the grammar.
            assert_eq!(Plan::parse(&p.to_string()).unwrap(), p);
        }
    }

    // ---- CE-FedAvg (was coordinator/cefedavg.rs) -----------------------

    #[test]
    fn ce_learns_on_quickstart() {
        let c = cfg_for(AlgorithmKind::CeFedAvg, 8);
        let mut coord = Coordinator::from_config(&c).unwrap();
        let history = coord.run().unwrap();
        assert_eq!(history.len(), 8);
        let first = history[0].test_accuracy;
        let best = best_accuracy(&history);
        assert!(best > first + 0.1, "no learning: {first} -> {best}");
        assert!(best > 0.35, "final accuracy too low: {best}");
        // Simulated time strictly increases.
        for w in history.windows(2) {
            assert!(w[1].sim_time_s > w[0].sim_time_s);
        }
    }

    #[test]
    fn ce_deterministic_under_seed() {
        let c = cfg_for(AlgorithmKind::CeFedAvg, 8);
        let run = || {
            let mut coord = Coordinator::from_config(&c).unwrap();
            coord.run().unwrap()
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.test_accuracy, y.test_accuracy);
        }
    }

    #[test]
    fn ce_semi_sync_outpaces_barrier_and_merges_stragglers_stale() {
        let mut barrier = cfg_for(AlgorithmKind::CeFedAvg, 6);
        barrier.latency = LatencyMode::EventDriven;
        barrier.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e4 });
        let mut semi = barrier.clone();
        // Healthy reports land in ~8 ms (upload-dominated); a 10⁴×
        // straggler needs ~26 ms of compute. K=3 closes a 4-device
        // cluster on its healthy majority and the 20 ms timeout bounds
        // the close even if the seed packs several stragglers into one
        // cluster — so the speedup bound below is placement-proof.
        semi.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.02 };
        semi.staleness_exp = 1.0;
        let hb = Coordinator::from_config(&barrier).unwrap().run().unwrap();
        let hs = Coordinator::from_config(&semi).unwrap().run().unwrap();
        // The barrier waits ~34 ms per edge round for the stragglers;
        // semi-sync closes in at most 20 ms — faster, with nothing
        // dropped: stragglers merge stale into later rounds instead.
        let (tb, ts) = (hb.last().unwrap().sim_time_s, hs.last().unwrap().sim_time_s);
        assert!(ts < tb * 0.75, "semi-sync not faster: {ts} !< 0.75·{tb}");
        assert_eq!(hs.iter().map(|r| r.dropped_devices).sum::<usize>(), 0);
        let late: usize = hs.iter().map(|r| r.late_devices).sum();
        let stale: usize = hs.iter().map(|r| r.stale_merged).sum();
        assert!(late > 0, "stragglers should miss the K-of-N close");
        assert!(stale > 0, "late reports should fold into later rounds");
        // Deferred-but-kept updates keep the run learning (10-class task:
        // chance is ~0.1).
        assert!(best_accuracy(&hs) > 0.25, "semi-sync run failed to learn");
    }

    #[test]
    fn ce_gossip_tightens_consensus() {
        let mut c = cfg_for(AlgorithmKind::CeFedAvg, 4);
        c.pi = 20; // strong mixing
        let mut coord = Coordinator::from_config(&c).unwrap();
        let hist = coord.run().unwrap();
        // With π=20 on a 4-ring, post-gossip consensus must be tiny
        // relative to the parameter scale.
        assert!(hist.last().unwrap().consensus < 1e-3, "{}", hist.last().unwrap().consensus);
    }

    #[test]
    fn ce_reduces_to_fedavg_when_single_cluster() {
        // §4.3: m=1, q=1 ⇒ CE-FedAvg == FedAvg update rule. With one
        // cluster the gossip is a no-op and the intra-cluster average is
        // the global average, so per-round train losses must match the
        // FedAvg plan exactly.
        let mut c = cfg_for(AlgorithmKind::CeFedAvg, 3);
        c.n_clusters = 1;
        c.n_devices = 8;
        c.q = 1;
        c.topology = "ring".into();
        let mut ce = Coordinator::from_config(&c).unwrap();
        let h1 = ce.run().unwrap();
        let mut c2 = c.clone();
        c2.algorithm = AlgorithmKind::FedAvg;
        let mut fa = Coordinator::from_config(&c2).unwrap();
        let h2 = fa.run().unwrap();
        for (a, b) in h1.iter().zip(&h2) {
            assert!((a.train_loss - b.train_loss).abs() < 1e-9);
            assert!((a.test_accuracy - b.test_accuracy).abs() < 1e-9);
        }
    }

    // ---- FedAvg (was coordinator/fedavg.rs) ----------------------------

    #[test]
    fn fedavg_learns_and_reaches_consensus() {
        let mut coord = Coordinator::from_config(&cfg_for(AlgorithmKind::FedAvg, 6)).unwrap();
        let h = coord.run().unwrap();
        assert!(best_accuracy(&h) > 0.3);
        // Cloud aggregation ⇒ all cluster models identical each round.
        assert!(h.last().unwrap().consensus < 1e-12);
    }

    #[test]
    fn fedavg_cloud_upload_dominates_round_latency() {
        // 1 Mbps cloud links make FedAvg rounds slower than CE rounds on
        // the same workload (paper Fig. 2 runtime axis).
        let mut fa = Coordinator::from_config(&cfg_for(AlgorithmKind::FedAvg, 6)).unwrap();
        let hfa = fa.run().unwrap();
        let mut c = cfg_for(AlgorithmKind::CeFedAvg, 6);
        c.pi = 5;
        let mut ce = Coordinator::from_config(&c).unwrap();
        let hce = ce.run().unwrap();
        assert!(
            hfa.last().unwrap().sim_time_s > hce.last().unwrap().sim_time_s,
            "fedavg {} !> ce {}",
            hfa.last().unwrap().sim_time_s,
            hce.last().unwrap().sim_time_s
        );
    }

    #[test]
    fn fedavg_semi_sync_bounds_the_cloud_report_wait() {
        // Healthy cloud reports land in ~78 ms (1 Mbps uplink); the 10⁴×
        // stragglers need ~53 ms of extra compute first. The 100 ms
        // timeout caps every close below the straggler finish.
        let mut barrier = cfg_for(AlgorithmKind::FedAvg, 4);
        barrier.latency = LatencyMode::EventDriven;
        barrier.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e4 });
        let mut semi = barrier.clone();
        semi.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.1 };
        let hb = Coordinator::from_config(&barrier).unwrap().run().unwrap();
        let hs = Coordinator::from_config(&semi).unwrap().run().unwrap();
        let (tb, ts) = (hb.last().unwrap().sim_time_s, hs.last().unwrap().sim_time_s);
        assert!(ts < tb, "semi-sync not faster on cloud uploads: {ts} !< {tb}");
        assert_eq!(hs.iter().map(|r| r.dropped_devices).sum::<usize>(), 0);
        assert!(hs.iter().map(|r| r.late_devices).sum::<usize>() > 0);
    }

    #[test]
    fn fedavg_aggregator_death_freezes_cooperation() {
        let mut c = cfg_for(AlgorithmKind::FedAvg, 8);
        c.fault = Some(FaultSpec::KillAggregator { at_round: 3 });
        let mut coord = Coordinator::from_config(&c).unwrap();
        let h = coord.run().unwrap();
        // Before the fault consensus is 0 (cloud sync); afterwards the
        // cluster models drift apart.
        assert!(h[2].consensus < 1e-12);
        assert!(h[7].consensus > 1e-12, "no drift after aggregator death");
    }

    // ---- Hier-FAvg (was coordinator/hierfavg.rs) -----------------------

    #[test]
    fn hier_learns_and_synchronises() {
        let mut coord = Coordinator::from_config(&cfg_for(AlgorithmKind::HierFAvg, 6)).unwrap();
        let h = coord.run().unwrap();
        assert!(best_accuracy(&h) > 0.3);
        assert!(h.last().unwrap().consensus < 1e-12);
    }

    #[test]
    fn hier_equals_ce_fedavg_under_complete_strong_gossip() {
        // §4.3: fully-connected backhaul + full averaging ⇒ CE-FedAvg's
        // update rule coincides with Hier-FAvg. Uniform H (π irrelevant)
        // averages exactly, so losses must match round for round —
        // *almost*: Hier weights the cloud average by cluster sample
        // counts while gossip with doubly-stochastic H is uniform. Use
        // equal cluster sizes so both weightings coincide.
        let hier_cfg = cfg_for(AlgorithmKind::HierFAvg, 3);
        let mut ce_cfg = hier_cfg.clone();
        ce_cfg.algorithm = AlgorithmKind::CeFedAvg;
        ce_cfg.topology = "complete".into();
        ce_cfg.pi = 60; // H^60 of a complete-graph Metropolis ≈ uniform
        let mut hier = Coordinator::from_config(&hier_cfg).unwrap();
        let hh = hier.run().unwrap();
        let mut ce = Coordinator::from_config(&ce_cfg).unwrap();
        let hc = ce.run().unwrap();
        for (a, b) in hh.iter().zip(&hc) {
            assert!(
                (a.train_loss - b.train_loss).abs() < 1e-3,
                "round {}: hier {} vs ce {}",
                a.round,
                a.train_loss,
                b.train_loss
            );
        }
    }

    #[test]
    fn hier_semi_sync_timeout_splits_edge_and_cloud_phase_closes() {
        // Hier-FAvg is the one canned plan whose phases ride two
        // different uplinks per global round: q−1 edge phases (~8 ms
        // healthy reports on 10 Mbps) and one cloud phase (~77 ms on
        // 1 Mbps). A 20 ms semi-sync timeout therefore lands *between*
        // the two — edge phases close with every report in, cloud phases
        // time out with everyone late-but-kept — so the round's close
        // reasons are genuinely mixed and nothing is ever dropped.
        let mut c = cfg_for(AlgorithmKind::HierFAvg, 4);
        c.latency = LatencyMode::EventDriven;
        c.agg_policy = AggPolicyKind::SemiSync {
            k: c.devices_per_cluster(),
            timeout_s: 0.02,
        };
        let h = Coordinator::from_config(&c).unwrap().run().unwrap();
        for rec in &h {
            assert_eq!(rec.close_reason, "mixed", "round {}", rec.round);
            assert_eq!(rec.dropped_devices, 0, "semi-sync never drops");
            // Every cloud report misses the timeout; every edge report
            // makes it.
            assert_eq!(rec.late_devices, c.n_devices);
            assert_eq!(rec.on_time_devices, (c.q - 1) * c.n_devices);
        }
    }

    #[test]
    fn hier_per_round_slower_than_local_edge() {
        let mut hier = Coordinator::from_config(&cfg_for(AlgorithmKind::HierFAvg, 6)).unwrap();
        let mut le = Coordinator::from_config(&cfg_for(AlgorithmKind::LocalEdge, 6)).unwrap();
        let hh = hier.run().unwrap();
        let hl = le.run().unwrap();
        assert!(hh.last().unwrap().sim_time_s > hl.last().unwrap().sim_time_s);
    }

    // ---- Local-Edge (was coordinator/localedge.rs) ---------------------

    #[test]
    fn local_clusters_never_converge_to_each_other() {
        let mut coord = Coordinator::from_config(&cfg_for(AlgorithmKind::LocalEdge, 6)).unwrap();
        let h = coord.run().unwrap();
        // No cooperation ⇒ models stay apart under non-IID writers.
        assert!(h.last().unwrap().consensus > 1e-9);
    }

    #[test]
    fn local_accuracy_below_cooperative_ce_on_noniid_data() {
        // The paper's headline qualitative result (Fig. 2): Local-Edge
        // plateaus below CE-FedAvg because each edge model sees a skewed
        // fraction of the data. Use a strongly skewed cluster split.
        let mut le_cfg = cfg_for(AlgorithmKind::LocalEdge, 10);
        le_cfg.data = DataScheme::ClusterNonIid { c_labels: 2 };
        let mut ce_cfg = le_cfg.clone();
        ce_cfg.algorithm = AlgorithmKind::CeFedAvg;
        let mut le = Coordinator::from_config(&le_cfg).unwrap();
        let mut ce = Coordinator::from_config(&ce_cfg).unwrap();
        let hl = le.run().unwrap();
        let hc = ce.run().unwrap();
        let (ble, bce) = (best_accuracy(&hl), best_accuracy(&hc));
        assert!(bce > ble + 0.05, "ce {bce} !>> local {ble}");
    }

    #[test]
    fn local_semi_sync_runs_on_unsynced_cluster_clocks() {
        // No inter-cluster barrier ever syncs the clocks here; the
        // stale-merge bookkeeping must still be stable and reproducible.
        let mut c = cfg_for(AlgorithmKind::LocalEdge, 5);
        c.latency = LatencyMode::EventDriven;
        c.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e4 });
        c.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.02 };
        let run = || Coordinator::from_config(&c).unwrap().run().unwrap();
        let a = run();
        let b = run();
        assert_eq!(a.iter().map(|r| r.dropped_devices).sum::<usize>(), 0);
        assert!(a.iter().map(|r| r.late_devices).sum::<usize>() > 0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
            assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits());
            assert_eq!(x.stale_merged, y.stale_merged);
        }
    }

    #[test]
    fn local_cheapest_per_round() {
        let mut le = Coordinator::from_config(&cfg_for(AlgorithmKind::LocalEdge, 6)).unwrap();
        let hl = le.run().unwrap();
        for alg in [AlgorithmKind::CeFedAvg, AlgorithmKind::FedAvg, AlgorithmKind::HierFAvg] {
            let c = cfg_for(alg, 6);
            let mut coord = Coordinator::from_config(&c).unwrap();
            let h = coord.run().unwrap();
            assert!(
                hl.last().unwrap().sim_time_s <= h.last().unwrap().sim_time_s + 1e-9,
                "local-edge not cheapest vs {alg:?}"
            );
        }
    }
}
