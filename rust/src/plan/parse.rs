//! Recursive-descent parser for the plan text grammar (module docs of
//! [`crate::plan`]). Hand-rolled like the rest of the offline build — no
//! parser-combinator dependency — with errors that quote the grammar so a
//! bad `--plan` spec teaches its own syntax.

use crate::error::{CfelError, Result};
use crate::netsim::UploadChannel;
use crate::plan::{Plan, Step};

/// The grammar, verbatim, for error messages and `--help` text.
pub const GRAMMAR: &str = "plan grammar:\n\
    \x20 plan  := step (';' step)*\n\
    \x20 step  := atom ('*' N)*\n\
    \x20 atom  := edge(E) | edge(E)@cloud | edge(E)@masked | gossip(P) | cloud | (plan)\n\
    examples: \"edge(2)*2; gossip(10)\" (CE-FedAvg), \
    \"edge(4)@cloud; cloud\" (FedAvg), \
    \"edge(2)@masked*2; gossip(10)\" (secure-aggregation CE-FedAvg), \
    \"(edge(2); gossip(3))*2; cloud\" (a hybrid)";

pub fn parse(spec: &str) -> Result<Plan> {
    let mut p = Parser { bytes: spec.as_bytes(), pos: 0, spec };
    let steps = p.seq()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("unexpected trailing input"));
    }
    let plan = Plan::from_steps(steps);
    plan.validate()?;
    Ok(plan)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    spec: &'a str,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> CfelError {
        CfelError::Config(format!(
            "invalid plan spec {:?} at byte {}: {msg}\n{GRAMMAR}",
            self.spec, self.pos
        ))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn eat_keyword(&mut self, kw: &str) -> bool {
        self.skip_ws();
        if self.spec[self.pos..].starts_with(kw) {
            self.pos += kw.len();
            true
        } else {
            false
        }
    }

    fn number(&mut self) -> Result<usize> {
        self.skip_ws();
        let start = self.pos;
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_digit())
        {
            self.pos += 1;
        }
        if start == self.pos {
            return Err(self.err("expected a number"));
        }
        self.spec[start..self.pos]
            .parse()
            .map_err(|_| self.err("number out of range"))
    }

    /// `plan := step (';' step)*` — a paren group without `*` splices its
    /// steps inline, so this returns a flat Vec.
    fn seq(&mut self) -> Result<Vec<Step>> {
        let mut steps = self.step()?;
        while self.peek() == Some(b';') {
            self.pos += 1;
            steps.extend(self.step()?);
        }
        Ok(steps)
    }

    /// `step := atom ('*' N)*`, left-associative: `edge(2)*2*3` is
    /// `Repeat{3, [Repeat{2, [edge(2)]}]}`.
    fn step(&mut self) -> Result<Vec<Step>> {
        let mut steps = self.atom()?;
        while self.peek() == Some(b'*') {
            self.pos += 1;
            let n = self.number()?;
            steps = vec![Step::Repeat { n, body: steps }];
        }
        Ok(steps)
    }

    fn atom(&mut self) -> Result<Vec<Step>> {
        match self.peek() {
            Some(b'(') => {
                self.pos += 1;
                let steps = self.seq()?;
                self.eat(b')')?;
                Ok(steps)
            }
            Some(b'e') if self.eat_keyword("edge") => {
                self.eat(b'(')?;
                let epochs = self.number()?;
                self.eat(b')')?;
                let channel = if self.peek() == Some(b'@') {
                    self.pos += 1;
                    if self.eat_keyword("cloud") {
                        UploadChannel::DeviceCloud
                    } else if self.eat_keyword("masked") {
                        UploadChannel::DeviceEdgeMasked
                    } else if self.eat_keyword("edge") {
                        UploadChannel::DeviceEdge
                    } else {
                        return Err(self.err("expected 'edge', 'cloud' or 'masked' after '@'"));
                    }
                } else {
                    UploadChannel::DeviceEdge
                };
                Ok(vec![Step::EdgePhase { epochs, channel }])
            }
            Some(b'g') if self.eat_keyword("gossip") => {
                self.eat(b'(')?;
                let pi = self.number()?;
                self.eat(b')')?;
                let pi = u32::try_from(pi).map_err(|_| self.err("gossip π out of range"))?;
                Ok(vec![Step::Gossip { pi }])
            }
            Some(b'c') if self.eat_keyword("cloud") => Ok(vec![Step::CloudAggregate]),
            _ => Err(self.err("expected edge(E), gossip(P), cloud, or '('")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edge(epochs: usize) -> Step {
        Step::EdgePhase { epochs, channel: UploadChannel::DeviceEdge }
    }

    #[test]
    fn parses_the_canned_shapes() {
        assert_eq!(
            parse("edge(2)*2; gossip(10)").unwrap(),
            Plan::from_steps(vec![
                Step::Repeat { n: 2, body: vec![edge(2)] },
                Step::Gossip { pi: 10 },
            ])
        );
        assert_eq!(
            parse("edge(4)@cloud; cloud").unwrap(),
            Plan::from_steps(vec![
                Step::EdgePhase { epochs: 4, channel: UploadChannel::DeviceCloud },
                Step::CloudAggregate,
            ])
        );
        assert_eq!(
            parse("edge(2)*7; edge(2)@cloud; cloud").unwrap(),
            Plan::from_steps(vec![
                Step::Repeat { n: 7, body: vec![edge(2)] },
                Step::EdgePhase { epochs: 2, channel: UploadChannel::DeviceCloud },
                Step::CloudAggregate,
            ])
        );
        assert_eq!(
            parse("edge(2)@masked*2; gossip(10)").unwrap(),
            Plan::from_steps(vec![
                Step::Repeat {
                    n: 2,
                    body: vec![Step::EdgePhase {
                        epochs: 2,
                        channel: UploadChannel::DeviceEdgeMasked,
                    }],
                },
                Step::Gossip { pi: 10 },
            ])
        );
    }

    #[test]
    fn whitespace_and_explicit_edge_channel_are_accepted() {
        assert_eq!(
            parse("  edge( 3 ) @edge ;\n gossip( 4 ) ").unwrap(),
            Plan::from_steps(vec![edge(3), Step::Gossip { pi: 4 }])
        );
    }

    #[test]
    fn groups_repeat_and_splice() {
        assert_eq!(
            parse("(edge(1); gossip(2))*3").unwrap(),
            Plan::from_steps(vec![Step::Repeat {
                n: 3,
                body: vec![edge(1), Step::Gossip { pi: 2 }],
            }])
        );
        // A bare group splices inline (no wrapper node).
        assert_eq!(
            parse("(edge(1); cloud)").unwrap(),
            Plan::from_steps(vec![edge(1), Step::CloudAggregate])
        );
        // Chained counts nest left-associatively.
        assert_eq!(
            parse("edge(2)*2*3").unwrap(),
            Plan::from_steps(vec![Step::Repeat {
                n: 3,
                body: vec![Step::Repeat { n: 2, body: vec![edge(2)] }],
            }])
        );
    }

    #[test]
    fn errors_quote_the_grammar() {
        for bad in [
            "",
            "edge(2",
            "edge()",
            "edge(2);;",
            "warp(9)",
            "edge(2)@warp",
            "edge(2) extra",
            "gossip(2)",      // valid syntax, but never trains
            "edge(0)",        // degenerate epoch count
            "(edge(2))*0",    // nothing ever executes
        ] {
            let err = parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("plan"),
                "error for {bad:?} should mention the plan: {err}"
            );
        }
        let err = parse("warp(9)").unwrap_err().to_string();
        assert!(err.contains("plan grammar"), "grammar not quoted: {err}");
    }

    #[test]
    fn roundtrips_canonical_specs() {
        for spec in [
            "edge(2)*2; gossip(10)",
            "edge(4)@cloud; cloud",
            "edge(2)*7; edge(2)@cloud; cloud",
            "edge(2)*2",
            "(edge(1); gossip(2))*3; cloud",
            "edge(2)*2*3",
            "edge(1)*0; edge(3)",
            "edge(2)@masked; gossip(10)",
            "edge(2)@masked*2; cloud",
        ] {
            let p = parse(spec).unwrap();
            assert_eq!(p.to_string(), spec);
            assert_eq!(parse(&p.to_string()).unwrap(), p);
        }
    }
}
