//! `cfel-edge` — one edge-server process of the multi-process runtime.
//!
//! Connects to a `cfel-cloud`, receives its cluster assignment and the
//! full experiment config over the wire, and serves training work orders
//! until the cloud shuts it down. Holds no configuration of its own: the
//! world is rebuilt deterministically from the config JSON the cloud
//! ships in `Init`.

use cfel::rpc::{run_edge, EdgeOpts};
use cfel::util::cli::Command;

fn command() -> Command {
    Command::new("cfel-edge", "edge worker for the multi-process runtime")
        .flag_default("connect", "127.0.0.1:4710", "cloud address (host:port or unix:/path)")
        .flag_default("retry", "10", "seconds to keep retrying the initial connect")
        .flag(
            "die-after-phases",
            "test hook: exit mid-round after serving this many phases",
        )
        .bool_flag("quiet", "suppress logging")
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = command();
    let args = match cmd.parse(&argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            std::process::exit(2);
        }
    };
    let opts = EdgeOpts {
        connect: args.get_or("connect", "127.0.0.1:4710"),
        connect_retry_s: args.get_f64("retry", 10.0),
        die_after_phases: args.get("die-after-phases").and_then(|v| v.parse().ok()),
        verbose: !args.get_bool("quiet"),
    };
    if let Err(e) = run_edge(&opts) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
