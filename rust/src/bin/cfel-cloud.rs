//! `cfel-cloud` — the cloud side of the multi-process runtime.
//!
//! Binds a listener, announces the resolved address on stdout
//! (`[cfel-cloud] listening on <addr>`), accepts `--edges` `cfel-edge`
//! processes, and drives the experiment's plan over them. The history it
//! produces is bit-identical to `cfel train` on the same config
//! (`rust/tests/distributed_equivalence.rs`); `--digest` prints the
//! wall-clock-free FNV digest so CI can diff the two.
//!
//! Example (two terminals + two edges):
//!   cfel-cloud --listen 127.0.0.1:4710 --edges 2 --plan "(edge(2); gossip(3))*2" --rounds 2
//!   cfel-edge --connect 127.0.0.1:4710   # twice

use std::path::Path;

use cfel::config::{AlgorithmKind, ControllerKind, ExperimentConfig, LatencyMode};
use cfel::metrics::{history_digest, CsvWriter, ROUND_HEADER};
use cfel::plan::Plan;
use cfel::rpc::{run_cloud, CloudOpts};
use cfel::util::cli::Command;
use cfel::util::json::Json;

fn command() -> Command {
    Command::new("cfel-cloud", "plan interpreter for the multi-process runtime")
        .flag("config", "load an ExperimentConfig JSON file (other flags override)")
        .flag("plan", "explicit federation plan, e.g. \"(edge(2); gossip(3))*2\"")
        .flag("algorithm", "ce-fedavg | fedavg | hier-favg | local-edge")
        .flag("devices", "total devices n")
        .flag("clusters", "edge servers m")
        .flag("rounds", "global rounds")
        .flag("seed", "experiment seed")
        .flag("latency", "closed-form | event")
        .flag("controller", "static | adaptive[:<window>] | floating[:<threshold>]")
        .flag("samples", "training samples per device")
        .flag("eval-every", "evaluate every k rounds")
        .flag_default("listen", "127.0.0.1:0", "bind address (host:port or unix:/path)")
        .flag_default("edges", "1", "edge processes to accept")
        .flag("csv", "write per-round history to this CSV file")
        .bool_flag("digest", "print `history_digest: <hex>` (wall-clock excluded)")
        .bool_flag("recover", "retry a failed round with a reconnecting edge")
        .flag_default("max-retries", "1", "transport failures tolerated with --recover")
        .flag_default("timeout", "60", "per-read and accept timeout in seconds (0 = none)")
        .bool_flag("quiet", "suppress per-round logging")
}

fn run(args: &cfel::util::cli::Args) -> cfel::Result<()> {
    let mut cfg = if let Some(path) = args.get("config") {
        let j = Json::parse_file(Path::new(path))?;
        ExperimentConfig::from_json(&j)?
    } else {
        ExperimentConfig::quickstart()
    };
    if let Some(spec) = args.get("plan") {
        cfg.plan = Some(Plan::parse(spec)?);
    }
    if let Some(alg) = args.get("algorithm") {
        cfg.algorithm = AlgorithmKind::parse(alg)?;
    }
    cfg.n_devices = args.get_usize("devices", cfg.n_devices);
    cfg.n_clusters = args.get_usize("clusters", cfg.n_clusters);
    cfg.rounds = args.get_usize("rounds", cfg.rounds);
    cfg.seed = args.get_usize("seed", cfg.seed as usize) as u64;
    if let Some(l) = args.get("latency") {
        cfg.latency = LatencyMode::parse(l)?;
    }
    if let Some(spec) = args.get("controller") {
        cfg.controller = ControllerKind::parse(spec)?;
    }
    cfg.samples_per_device = args.get_usize("samples", cfg.samples_per_device);
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every);
    cfg.validate()?;

    let timeout = args.get_f64("timeout", 60.0);
    let opts = CloudOpts {
        listen: args.get_or("listen", "127.0.0.1:0"),
        edges: args.get_usize("edges", 1),
        read_timeout_s: timeout,
        accept_timeout_s: timeout,
        recover: args.get_bool("recover"),
        max_retries: args.get_usize("max-retries", 1),
        verbose: !args.get_bool("quiet"),
    };
    let history = run_cloud(&cfg, &opts)?;

    if let Some(csv_path) = args.get("csv") {
        let mut w = CsvWriter::create(Path::new(csv_path), ROUND_HEADER)?;
        let series = cfg.run_label();
        for rec in &history {
            w.round_row(&series, rec)?;
        }
        eprintln!("[cfel-cloud] wrote {csv_path}");
    }
    if args.get_bool("digest") {
        println!("history_digest: {:016x}", history_digest(&history));
    }
    let last = history.last().expect("at least one round");
    println!("rounds:         {}", history.len());
    println!("final accuracy: {:.4}", last.test_accuracy);
    println!("sim time:       {:.1} s", last.sim_time_s);
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = command();
    let args = match cmd.parse(&argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
