//! The multi-process runtime: a length-prefixed binary protocol
//! ([`codec`], [`wire`]) over TCP or Unix sockets, the cloud-side driver
//! ([`cloud`]) and the edge-side serve loop ([`edge`]).
//!
//! The split follows the paper's deployment: `cfel-cloud` interprets the
//! plan on a full mirror world and ships `EdgePhase` work orders;
//! `cfel-edge` processes own disjoint cluster subsets and run
//! training/aggregation locally; gossip and cloud aggregation execute on
//! the mirror (the cloud is the rendezvous) and the results are pushed
//! back. Virtual clocks stay authoritative — wall-clock transport time
//! never enters the history, which is pinned bit-identical to the
//! in-process interpreter by `rust/tests/distributed_equivalence.rs`.
//!
//! Addresses: `host:port` for TCP, `unix:/path/to.sock` for Unix domain
//! sockets.

pub mod cloud;
pub mod codec;
pub mod edge;
pub mod wire;

pub use cloud::{run_cloud, CloudOpts, RemoteExecutor};
pub use edge::{run_edge, EdgeOpts};

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::{Duration, Instant};

use crate::error::{CfelError, Result};

/// Prefix selecting a Unix-domain socket address.
pub const UNIX_PREFIX: &str = "unix:";

/// One established cloud⇄edge connection.
pub enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    /// Connect to `addr`, retrying for up to `retry_s` seconds — the
    /// edge processes race the cloud's bind during startup.
    pub fn connect_retry(addr: &str, retry_s: f64) -> Result<Conn> {
        let deadline = Instant::now() + Duration::from_secs_f64(retry_s.max(0.0));
        loop {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(CfelError::Transport {
                            cluster: None,
                            message: format!("connect {addr}: {e}"),
                        });
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    fn connect(addr: &str) -> io::Result<Conn> {
        if let Some(path) = addr.strip_prefix(UNIX_PREFIX) {
            #[cfg(unix)]
            {
                return Ok(Conn::Unix(UnixStream::connect(path)?));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(io::Error::new(
                    io::ErrorKind::Unsupported,
                    "unix sockets are unavailable on this platform",
                ));
            }
        }
        let s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        Ok(Conn::Tcp(s))
    }

    /// Bound on how long a single read blocks; `None` blocks forever.
    pub fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// A listening socket accepting edge connections.
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, String),
}

impl Listener {
    /// Bind `addr` (`host:port`, port 0 for an ephemeral port, or
    /// `unix:/path`). A stale Unix socket file is removed first.
    pub fn bind(addr: &str) -> Result<Listener> {
        if let Some(path) = addr.strip_prefix(UNIX_PREFIX) {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                return Ok(Listener::Unix(UnixListener::bind(path)?, path.to_string()));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(CfelError::Config(
                    "unix sockets are unavailable on this platform".into(),
                ));
            }
        }
        Ok(Listener::Tcp(TcpListener::bind(addr)?))
    }

    /// The bound address in connectable form (resolves port 0).
    pub fn local_desc(&self) -> String {
        match self {
            Listener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "<unknown>".into()),
            #[cfg(unix)]
            Listener::Unix(_, path) => format!("{UNIX_PREFIX}{path}"),
        }
    }

    /// Accept one connection, waiting at most `timeout`.
    pub fn accept_deadline(&self, timeout: Duration) -> Result<Conn> {
        let deadline = Instant::now() + timeout;
        self.set_nonblocking(true)?;
        let out = loop {
            match self.try_accept() {
                Ok(c) => break Ok(c),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        break Err(CfelError::Transport {
                            cluster: None,
                            message: format!(
                                "no edge connected within {:.1}s",
                                timeout.as_secs_f64()
                            ),
                        });
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => break Err(CfelError::Io(e)),
            }
        };
        let _ = self.set_nonblocking(false);
        out
    }

    fn set_nonblocking(&self, v: bool) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(v),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(v),
        }
    }

    fn try_accept(&self) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l, _) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(Conn::Unix(s))
            }
        }
    }
}

#[cfg(unix)]
impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}
