//! Cloud side of the multi-process runtime: [`RemoteExecutor`] (a
//! [`ClusterExecutor`] speaking the wire protocol to one `cfel-edge`
//! process) and [`run_cloud`] (bind, handshake N edges, drive
//! [`DistRunner`]).

use std::time::Duration;

use crate::config::ExperimentConfig;
use crate::coordinator::executor::{partition_clusters, ClusterExecutor, DistRunner};
use crate::coordinator::ClusterPhase;
use crate::error::{CfelError, Result};
use crate::metrics::History;
use crate::netsim::UploadChannel;
use crate::rpc::codec::PROTO_VERSION;
use crate::rpc::wire::{self, Msg};
use crate::rpc::{Conn, Listener};

/// [`ClusterExecutor`] implemented as an RPC client: every trait call is
/// one request frame to the owning `cfel-edge`, every reply is awaited
/// under the read timeout. Connection failures (EOF, reset, timeout)
/// surface as [`CfelError::Transport`] naming the first owned cluster;
/// an [`Msg::Error`] reply — the edge ran but its *work* failed — stays
/// a runtime error and is not retried.
pub struct RemoteExecutor {
    conn: Conn,
    owned: Vec<usize>,
    config_json: String,
    /// `RunPhase` orders sent but not yet collected. The driver aborts
    /// its collect loop at the first failure, so a *healthy* connection
    /// can be left with a reply in flight — `reinit` drains it before
    /// retrying, lest `Init` be answered by a stale `phase-done`.
    inflight: usize,
}

impl RemoteExecutor {
    /// Consume a fresh inbound connection: verify the edge's `Hello`,
    /// and (unless this executor replaces a dead one — the driver
    /// reinitializes those itself) ship the initial `Init` so the edge
    /// builds its world.
    pub fn accept_handshake(
        conn: Conn,
        owned: Vec<usize>,
        config_json: String,
        read_timeout: Option<Duration>,
        init_now: bool,
    ) -> Result<RemoteExecutor> {
        conn.set_read_timeout(read_timeout)?;
        let mut ex = RemoteExecutor {
            conn,
            owned,
            config_json,
            inflight: 0,
        };
        match ex.recv()? {
            Msg::Hello { proto } if proto == PROTO_VERSION => {}
            Msg::Hello { proto } => {
                return Err(ex.transport(format!(
                    "edge speaks protocol {proto}, cloud speaks {PROTO_VERSION}"
                )));
            }
            m => return Err(ex.transport(format!("expected hello, got {}", m.name()))),
        }
        if init_now {
            ex.send_init(0, &[], &[], &[])?;
        }
        Ok(ex)
    }

    fn transport(&self, message: String) -> CfelError {
        CfelError::Transport {
            cluster: self.owned.first().copied(),
            message,
        }
    }

    /// Map connection-level failures to `Transport`; leave everything
    /// else (notably edge-reported execution errors) untouched.
    fn map_err(&self, e: CfelError) -> CfelError {
        match e {
            CfelError::Io(ioe) => self.transport(ioe.to_string()),
            CfelError::Codec(m) => self.transport(m),
            other => other,
        }
    }

    fn send(&mut self, msg: &Msg) -> Result<()> {
        wire::send(&mut self.conn, msg).map_err(|e| self.map_err(e))
    }

    fn recv(&mut self) -> Result<Msg> {
        wire::recv(&mut self.conn).map_err(|e| self.map_err(e))
    }

    /// Await a reply, unwrapping edge-reported errors.
    fn expect(&mut self, want: &'static str) -> Result<Msg> {
        match self.recv()? {
            Msg::Error { message } => Err(CfelError::Runtime(format!("edge: {message}"))),
            m if m.name() == want => Ok(m),
            m => Err(self.transport(format!("expected {want}, got {}", m.name()))),
        }
    }

    fn send_init(
        &mut self,
        rounds_applied: usize,
        models: &[(usize, &[f32])],
        clocks: &[(usize, f64)],
        policies: &[(usize, String)],
    ) -> Result<()> {
        let msg = Msg::Init {
            config_json: self.config_json.clone(),
            clusters: self.owned.clone(),
            rounds_applied,
            models: models.iter().map(|&(ci, m)| (ci, m.to_vec())).collect(),
            clocks: clocks.to_vec(),
            policies: policies.to_vec(),
        };
        self.send(&msg)?;
        self.expect("init-ok").map(|_| ())
    }
}

impl ClusterExecutor for RemoteExecutor {
    fn clusters(&self) -> &[usize] {
        &self.owned
    }

    fn begin_round(&mut self, round: usize, policies: &[(usize, String)]) -> Result<()> {
        self.send(&Msg::BeginRound {
            round,
            policies: policies.to_vec(),
        })?;
        self.expect("round-begun").map(|_| ())
    }

    fn start_phase(&mut self, phase: u64, epochs: usize, channel: UploadChannel) -> Result<()> {
        // Fire the work order without awaiting: the driver issues every
        // edge's order first, so the edges train concurrently.
        self.send(&Msg::RunPhase {
            phase,
            epochs,
            channel,
        })?;
        self.inflight += 1;
        Ok(())
    }

    fn finish_phase(&mut self) -> Result<Vec<ClusterPhase>> {
        // Decrement up front: on success (or an edge-reported error) a
        // frame was consumed; on a transport error this executor is dead
        // and gets replaced by one with a fresh count.
        self.inflight = self.inflight.saturating_sub(1);
        match self.recv()? {
            Msg::Error { message } => Err(CfelError::Runtime(format!("edge: {message}"))),
            // Plain and masked phase results are the same call outcome;
            // the driver branches on `ClusterPhase::masked` itself.
            Msg::PhaseDone { phases } | Msg::MaskedPhaseDone { phases } => Ok(phases),
            m => Err(self.transport(format!("expected phase-done, got {}", m.name()))),
        }
    }

    fn set_state(&mut self, models: &[(usize, &[f32])], clocks: &[(usize, f64)]) -> Result<()> {
        let msg = Msg::SetState {
            models: models.iter().map(|&(ci, m)| (ci, m.to_vec())).collect(),
            clocks: clocks.to_vec(),
        };
        self.send(&msg)?;
        self.expect("state-set").map(|_| ())
    }

    fn reinit(
        &mut self,
        rounds_applied: usize,
        models: &[(usize, &[f32])],
        clocks: &[(usize, f64)],
        policies: &[(usize, String)],
    ) -> Result<()> {
        while self.inflight > 0 {
            let _ = self.recv()?;
            self.inflight -= 1;
        }
        self.send_init(rounds_applied, models, clocks, policies)
    }

    fn shutdown(&mut self) -> Result<()> {
        // Best effort: the run is over either way.
        if self.send(&Msg::Shutdown).is_ok() {
            let _ = self.recv();
        }
        Ok(())
    }
}

/// Knobs for [`run_cloud`].
pub struct CloudOpts {
    /// Bind address (`host:port`, port 0 for ephemeral, or `unix:/path`).
    pub listen: String,
    /// Number of edge processes to accept; clusters are partitioned over
    /// them contiguously ([`partition_clusters`]).
    pub edges: usize,
    /// Per-read timeout on every edge connection; an edge that goes
    /// silent longer than this surfaces `CfelError::Transport` instead
    /// of hanging the round. `0` disables the timeout.
    pub read_timeout_s: f64,
    /// Seconds to wait for each initial (and each replacement) edge.
    pub accept_timeout_s: f64,
    /// Allow a failed round to be retried with a reconnecting edge.
    pub recover: bool,
    /// Transport failures tolerated when `recover` is set.
    pub max_retries: usize,
    pub verbose: bool,
}

impl Default for CloudOpts {
    fn default() -> CloudOpts {
        CloudOpts {
            listen: "127.0.0.1:0".into(),
            edges: 1,
            read_timeout_s: 60.0,
            accept_timeout_s: 60.0,
            recover: false,
            max_retries: 1,
            verbose: false,
        }
    }
}

fn opt_timeout(s: f64) -> Option<Duration> {
    (s > 0.0).then(|| Duration::from_secs_f64(s))
}

/// Run the full experiment as the cloud process: bind, announce the
/// resolved address on stdout (`[cfel-cloud] listening on <addr>` — the
/// line test harnesses parse for ephemeral ports), accept and handshake
/// `opts.edges` edges (accept order = cluster-range order), then drive
/// the distributed interpreter to completion.
pub fn run_cloud(cfg: &ExperimentConfig, opts: &CloudOpts) -> Result<History> {
    cfg.validate()?;
    let config_json = cfg.to_json().to_string();
    let listener = Listener::bind(&opts.listen)?;
    println!("[cfel-cloud] listening on {}", listener.local_desc());
    let parts = partition_clusters(cfg.n_clusters, opts.edges);
    let read_timeout = opt_timeout(opts.read_timeout_s);
    let accept_timeout = opt_timeout(opts.accept_timeout_s).unwrap_or(Duration::from_secs(3600));

    let mut executors: Vec<Box<dyn ClusterExecutor>> = Vec::with_capacity(opts.edges);
    for (slot, part) in parts.iter().enumerate() {
        let conn = listener.accept_deadline(accept_timeout)?;
        if opts.verbose {
            eprintln!("[cfel-cloud] edge {slot} connected, owns clusters {part:?}");
        }
        let ex = RemoteExecutor::accept_handshake(
            conn,
            part.clone(),
            config_json.clone(),
            read_timeout,
            true,
        )?;
        executors.push(Box::new(ex));
    }

    let mut runner = DistRunner::new(cfg, executors)?;
    if opts.recover {
        let parts = parts.clone();
        runner = runner.with_recovery(
            Box::new(move |slot| {
                let conn = listener.accept_deadline(accept_timeout)?;
                let ex = RemoteExecutor::accept_handshake(
                    conn,
                    parts[slot].clone(),
                    config_json.clone(),
                    read_timeout,
                    // The driver reinitializes every executor after
                    // recovery; don't build the edge's world twice.
                    false,
                )?;
                Ok(Box::new(ex) as Box<dyn ClusterExecutor>)
            }),
            opts.max_retries,
        );
    }
    runner.verbose = opts.verbose;
    runner.run()
}
