//! Length-prefixed, versioned binary framing plus the primitive
//! encoders/decoders the wire messages are built from.
//!
//! A frame is `b"CFRP" | version:u16 | kind:u16 | len:u32 | payload`
//! (all integers little-endian). Floats travel as raw IEEE-754 bit
//! patterns (`to_bits`/`from_bits`), so NaN payloads, negative zero and
//! subnormals survive the wire exactly — the equivalence suite compares
//! histories bit for bit, so the codec must never canonicalize.
//! Malformed input (bad magic, wrong version, truncated or oversized
//! frames, trailing payload bytes, lengths that exceed the buffer)
//! returns [`CfelError::Codec`]; nothing in this module panics on
//! untrusted bytes.

use std::io::{ErrorKind, Read, Write};

use crate::error::{CfelError, Result};

/// Frame preamble, first bytes on every frame.
pub const MAGIC: [u8; 4] = *b"CFRP";
/// Protocol version; bumped on any wire-format change (v3: masked
/// secure-aggregation phase payloads + per-phase secagg overhead).
pub const PROTO_VERSION: u16 = 3;
/// Upper bound on a frame payload: 256 MiB holds a 64M-parameter f32
/// model, far above anything the MLP zoo here ships per cluster.
pub const MAX_FRAME: usize = 256 << 20;

/// Frame header size on the wire: magic + version + kind + len.
const HEADER_LEN: usize = 12;

/// Write one frame.
pub fn write_frame<W: Write>(w: &mut W, kind: u16, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(CfelError::Codec(format!(
            "refusing to send {}-byte frame (cap {MAX_FRAME})",
            payload.len()
        )));
    }
    let mut head = [0u8; HEADER_LEN];
    head[..4].copy_from_slice(&MAGIC);
    head[4..6].copy_from_slice(&PROTO_VERSION.to_le_bytes());
    head[6..8].copy_from_slice(&kind.to_le_bytes());
    head[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on a clean EOF *at a frame boundary*
/// (the peer closed the connection between messages). EOF inside a
/// frame is a [`CfelError::Codec`] truncation error.
pub fn read_frame_opt<R: Read>(r: &mut R) -> Result<Option<(u16, Vec<u8>)>> {
    let mut head = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut head[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(CfelError::Codec(format!(
                    "truncated frame header: got {got} of {HEADER_LEN} bytes"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(CfelError::Io(e)),
        }
    }
    if head[..4] != MAGIC {
        return Err(CfelError::Codec(format!(
            "bad frame magic {:02x?} (expected {:02x?})",
            &head[..4],
            MAGIC
        )));
    }
    let version = u16::from_le_bytes([head[4], head[5]]);
    if version != PROTO_VERSION {
        return Err(CfelError::Codec(format!(
            "protocol version {version} (this build speaks {PROTO_VERSION})"
        )));
    }
    let kind = u16::from_le_bytes([head[6], head[7]]);
    let len = u32::from_le_bytes([head[8], head[9], head[10], head[11]]) as usize;
    if len > MAX_FRAME {
        return Err(CfelError::Codec(format!(
            "frame length {len} exceeds cap {MAX_FRAME}"
        )));
    }
    let mut payload = vec![0u8; len];
    if let Err(e) = r.read_exact(&mut payload) {
        if e.kind() == ErrorKind::UnexpectedEof {
            return Err(CfelError::Codec(format!(
                "truncated frame payload: wanted {len} bytes"
            )));
        }
        return Err(CfelError::Io(e));
    }
    Ok(Some((kind, payload)))
}

/// Read one frame, treating EOF at a frame boundary as an error too
/// (the caller expected an answer).
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u16, Vec<u8>)> {
    read_frame_opt(r)?
        .ok_or_else(|| CfelError::Codec("connection closed while awaiting a frame".into()))
}

/// Append-only payload builder. All integers little-endian; `usize`
/// widens to `u64`; floats are raw bit patterns.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> WireWriter {
        WireWriter::default()
    }

    pub fn into_payload(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(u8::from(v));
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_usize(vs.len());
        for &v in vs {
            self.put_f32(v);
        }
    }

    pub fn put_f64s(&mut self, vs: &[f64]) {
        self.put_usize(vs.len());
        for &v in vs {
            self.put_f64(v);
        }
    }

    pub fn put_usizes(&mut self, vs: &[usize]) {
        self.put_usize(vs.len());
        for &v in vs {
            self.put_usize(v);
        }
    }

    pub fn put_u64s(&mut self, vs: &[u64]) {
        self.put_usize(vs.len());
        for &v in vs {
            self.put_u64(v);
        }
    }
}

/// Checked cursor over a frame payload. Every read validates the
/// remaining length first; length prefixes are checked against the
/// bytes actually present *before* any allocation, so an adversarial
/// length cannot trigger an OOM.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(CfelError::Codec(format!(
                "payload underrun: wanted {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(CfelError::Codec(format!("bool byte {b} is neither 0 nor 1"))),
        }
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        usize::try_from(self.get_u64()?)
            .map_err(|_| CfelError::Codec("usize field overflows this platform".into()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    /// Length prefix for a sequence of `elem_size`-byte elements,
    /// validated against the bytes actually remaining.
    pub fn get_len(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.get_usize()?;
        let need = n
            .checked_mul(elem_size.max(1))
            .ok_or_else(|| CfelError::Codec(format!("length {n} overflows")))?;
        if need > self.remaining() {
            return Err(CfelError::Codec(format!(
                "length prefix {n} needs {need} bytes, {} left",
                self.remaining()
            )));
        }
        Ok(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| CfelError::Codec(format!("invalid UTF-8 string: {e}")))
    }

    pub fn get_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.get_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f32()?);
        }
        Ok(out)
    }

    pub fn get_f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.get_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f64()?);
        }
        Ok(out)
    }

    pub fn get_usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.get_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_usize()?);
        }
        Ok(out)
    }

    pub fn get_u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.get_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u64()?);
        }
        Ok(out)
    }

    /// A decode must consume the payload exactly; trailing bytes mean
    /// the two sides disagree about the message layout.
    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(CfelError::Codec(format!(
                "{} trailing bytes after message",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 7, b"hello").unwrap();
        let mut r = &buf[..];
        let (kind, payload) = read_frame(&mut r).unwrap();
        assert_eq!(kind, 7);
        assert_eq!(payload, b"hello");
        assert!(read_frame_opt(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncation_is_typed_not_a_panic() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, &[9u8; 32]).unwrap();
        for cut in 1..buf.len() {
            let mut r = &buf[..cut];
            let err = read_frame(&mut r).unwrap_err();
            assert!(matches!(err, CfelError::Codec(_)), "cut at {cut}: {err}");
        }
    }

    #[test]
    fn bad_magic_version_and_length_are_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, b"x").unwrap();
        let mut bad = buf.clone();
        bad[0] = b'Z';
        assert!(matches!(read_frame(&mut &bad[..]).unwrap_err(), CfelError::Codec(_)));
        let mut bad = buf.clone();
        bad[4] = 0xFF;
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("version"));
        let mut bad = buf;
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("exceeds cap"));
    }

    #[test]
    fn reader_validates_lengths_before_allocating() {
        let mut w = WireWriter::new();
        w.put_usize(usize::MAX); // length prefix far beyond the buffer
        let payload = w.into_payload();
        let mut r = WireReader::new(&payload);
        assert!(r.get_f32s().is_err());
    }

    #[test]
    fn exotic_floats_roundtrip_bitwise() {
        let vals = [
            f64::NAN,
            f64::from_bits(0x7FF8_DEAD_BEEF_0001),
            -0.0,
            f64::from_bits(1), // smallest subnormal
            f64::INFINITY,
            f64::NEG_INFINITY,
        ];
        let mut w = WireWriter::new();
        w.put_f64s(&vals);
        let payload = w.into_payload();
        let mut r = WireReader::new(&payload);
        let back = r.get_f64s().unwrap();
        r.finish().unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
