//! Edge side of the multi-process runtime: connect to the cloud, build
//! the world from the shipped config, and serve work orders until
//! shutdown.
//!
//! The edge holds a full [`Coordinator`] rebuilt from the config JSON
//! (every part of the world is a deterministic function of the config,
//! which round-trips f64-exactly), but only ever executes edge phases
//! for the clusters the cloud assigned it. Round boundaries (faults,
//! timeline events) are replayed locally on `BeginRound` — worlds never
//! drift because both sides compute them from the same data. Semi-sync
//! pending reports live here, inside the coordinator, across phases.

use crate::config::ExperimentConfig;
use crate::coordinator::executor::{install_state, rebuild_world};
use crate::coordinator::Coordinator;
use crate::error::{CfelError, Result};
use crate::rpc::codec::PROTO_VERSION;
use crate::rpc::wire::{self, Msg};
use crate::rpc::Conn;
use crate::util::json::Json;

/// Knobs for [`run_edge`].
pub struct EdgeOpts {
    /// Cloud address (`host:port` or `unix:/path`).
    pub connect: String,
    /// Seconds to keep retrying the connect — edges usually race the
    /// cloud's bind at startup.
    pub connect_retry_s: f64,
    /// Test hook: serve this many `RunPhase` orders, then exit the
    /// process without replying — a deterministic mid-round death for
    /// the fault-injection suite.
    pub die_after_phases: Option<usize>,
    pub verbose: bool,
}

impl Default for EdgeOpts {
    fn default() -> EdgeOpts {
        EdgeOpts {
            connect: "127.0.0.1:0".into(),
            connect_retry_s: 10.0,
            die_after_phases: None,
            verbose: false,
        }
    }
}

/// The edge's world: the coordinator plus the clusters the cloud
/// assigned to this process.
struct EdgeWorld {
    coord: Coordinator,
    owned: Vec<usize>,
}

fn build_world(
    config_json: &str,
    rounds_applied: usize,
    models: &[(usize, Vec<f32>)],
    clocks: &[(usize, f64)],
) -> Result<Coordinator> {
    let j = Json::parse(config_json)?;
    let cfg = ExperimentConfig::from_json(&j)?;
    let mut coord = rebuild_world(&cfg, rounds_applied)?;
    let model_refs: Vec<(usize, &[f32])> =
        models.iter().map(|(ci, m)| (*ci, m.as_slice())).collect();
    install_state(&mut coord, &model_refs, clocks)?;
    Ok(coord)
}

fn handle(msg: Msg, world: &mut Option<EdgeWorld>, verbose: bool) -> Result<Msg> {
    match msg {
        Msg::Init {
            config_json,
            clusters,
            rounds_applied,
            models,
            clocks,
            policies,
        } => {
            if verbose {
                eprintln!(
                    "[cfel-edge] init: clusters {clusters:?}, {rounds_applied} boundaries applied"
                );
            }
            let mut coord = build_world(&config_json, rounds_applied, &models, &clocks)?;
            coord.set_cluster_policies(&policies)?;
            *world = Some(EdgeWorld {
                coord,
                owned: clusters,
            });
            Ok(Msg::InitOk)
        }
        Msg::BeginRound { round, policies } => {
            let w = need_world(world)?;
            w.coord.apply_fault(round)?;
            w.coord.apply_timeline(round)?;
            w.coord.set_cluster_policies(&policies)?;
            Ok(Msg::RoundBegun)
        }
        Msg::RunPhase {
            phase,
            epochs,
            channel,
        } => {
            let w = need_world(world)?;
            let owned = w.owned.clone();
            let phases = w.coord.edge_phase_on(&owned, epochs, phase, channel, true)?;
            // Masked aggregates ride their own frame kind so the payload
            // layout is unambiguous on both sides of the wire.
            if phases.iter().any(|p| p.masked.is_some()) {
                Ok(Msg::MaskedPhaseDone { phases })
            } else {
                Ok(Msg::PhaseDone { phases })
            }
        }
        Msg::SetState { models, clocks } => {
            let w = need_world(world)?;
            let model_refs: Vec<(usize, &[f32])> =
                models.iter().map(|(ci, m)| (*ci, m.as_slice())).collect();
            install_state(&mut w.coord, &model_refs, &clocks)?;
            Ok(Msg::StateSet)
        }
        m => Err(CfelError::Runtime(format!(
            "edge received unexpected message {}",
            m.name()
        ))),
    }
}

fn need_world(world: &mut Option<EdgeWorld>) -> Result<&mut EdgeWorld> {
    world
        .as_mut()
        .ok_or_else(|| CfelError::Runtime("work order before init".into()))
}

/// Serve one cloud connection to completion. Returns `Ok(())` on an
/// orderly shutdown (or the cloud closing the connection between
/// messages); execution errors are reported to the cloud as
/// [`Msg::Error`] and then returned.
pub fn run_edge(opts: &EdgeOpts) -> Result<()> {
    let mut conn = Conn::connect_retry(&opts.connect, opts.connect_retry_s)?;
    wire::send(
        &mut conn,
        &Msg::Hello {
            proto: PROTO_VERSION,
        },
    )?;
    let mut world: Option<EdgeWorld> = None;
    let mut phases_served = 0usize;
    loop {
        let Some(msg) = wire::recv_opt(&mut conn)? else {
            // Cloud hung up between messages: our work is done.
            return Ok(());
        };
        match msg {
            Msg::Shutdown => {
                let _ = wire::send(&mut conn, &Msg::Bye);
                return Ok(());
            }
            Msg::RunPhase { .. } if opts.die_after_phases == Some(phases_served) => {
                // Deterministic mid-round crash: the work order is in,
                // the reply never comes.
                if opts.verbose {
                    eprintln!("[cfel-edge] dying after {phases_served} phases (test hook)");
                }
                std::process::exit(17);
            }
            msg => {
                if matches!(msg, Msg::RunPhase { .. }) {
                    phases_served += 1;
                }
                match handle(msg, &mut world, opts.verbose) {
                    Ok(reply) => wire::send(&mut conn, &reply)?,
                    Err(e) => {
                        let _ = wire::send(
                            &mut conn,
                            &Msg::Error {
                                message: e.to_string(),
                            },
                        );
                        return Err(e);
                    }
                }
            }
        }
    }
}
