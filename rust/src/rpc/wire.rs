//! The cloud ⇄ edge message vocabulary and its binary layout.
//!
//! One frame kind per message. Payload layouts are hand-rolled over
//! [`WireWriter`]/[`WireReader`]: little-endian integers, `usize` as
//! `u64`, floats as raw bit patterns. Decoding validates every length
//! prefix against the bytes present, checks SoA columns agree, and
//! requires the payload be consumed exactly — malformed input returns
//! [`CfelError::Codec`], never a panic.

use std::io::{Read, Write};

use crate::aggregation::policy::{CloseReason, ReportVerdict};
use crate::coordinator::ClusterPhase;
use crate::error::{CfelError, Result};
use crate::netsim::{DeviceTimings, PhaseTiming, UploadChannel};
use crate::rpc::codec::{read_frame, read_frame_opt, write_frame, WireReader, WireWriter};
use crate::secagg::MaskedSum;

/// Everything that can travel between `cfel-cloud` and `cfel-edge`.
#[derive(Debug, Clone)]
pub enum Msg {
    /// Edge → cloud, first message on a fresh connection.
    Hello { proto: u16 },
    /// Cloud → edge: build your world. The config JSON round-trips every
    /// finite f64 exactly, so the edge reconstructs the *identical*
    /// world from it; `rounds_applied` boundaries are replayed before
    /// `models`/`clocks` (empty on a first init) are installed.
    Init {
        config_json: String,
        clusters: Vec<usize>,
        rounds_applied: usize,
        models: Vec<(usize, Vec<f32>)>,
        clocks: Vec<(usize, f64)>,
        /// Controller-installed per-cluster close-policy overrides as
        /// `(cluster, spec)` pairs (the [`AggPolicyKind`] grammar).
        /// Recovery replays an `Init` *without* a fresh `BeginRound`, so
        /// the round-in-flight's overrides must ride here too.
        ///
        /// [`AggPolicyKind`]: crate::config::AggPolicyKind
        policies: Vec<(usize, String)>,
    },
    InitOk,
    /// Cloud → edge: apply the round boundary (fault + timeline), then
    /// install the driver's policy overrides for the round. The wire
    /// stays decision-agnostic: the edge sees opaque policy specs, never
    /// telemetry or the controller itself.
    BeginRound {
        round: usize,
        policies: Vec<(usize, String)>,
    },
    RoundBegun,
    /// Cloud → edge: run edge phase `phase` on your owned clusters.
    RunPhase {
        phase: u64,
        epochs: usize,
        channel: UploadChannel,
    },
    /// Edge → cloud: the phase results, owned clusters ascending.
    /// Every phase's `masked` is `None` — plain models only (the encoder
    /// debug-asserts it); masked phases travel as [`Msg::MaskedPhaseDone`].
    PhaseDone { phases: Vec<ClusterPhase> },
    /// Edge → cloud: phase results where at least one cluster aggregated
    /// under secure aggregation — the wire carries the still-encoded
    /// masked sum (`ClusterPhase::masked`) instead of a plain f32 model,
    /// and the cloud decodes it itself. A separate frame kind (rather
    /// than a flag inside `PhaseDone`) so a pre-secagg peer fails loudly
    /// on the kind tag instead of misparsing the payload.
    MaskedPhaseDone { phases: Vec<ClusterPhase> },
    /// Cloud → edge: install models/clocks rewritten cloud-side
    /// (gossip, cloud aggregation).
    SetState {
        models: Vec<(usize, Vec<f32>)>,
        clocks: Vec<(usize, f64)>,
    },
    StateSet,
    Shutdown,
    Bye,
    /// Edge → cloud: the edge hit an execution error (the connection
    /// stays up; transport is fine, the *work* failed).
    Error { message: String },
}

const K_HELLO: u16 = 1;
const K_INIT: u16 = 2;
const K_INIT_OK: u16 = 3;
const K_BEGIN_ROUND: u16 = 4;
const K_ROUND_BEGUN: u16 = 5;
const K_RUN_PHASE: u16 = 6;
const K_PHASE_DONE: u16 = 7;
const K_SET_STATE: u16 = 8;
const K_STATE_SET: u16 = 9;
const K_SHUTDOWN: u16 = 10;
const K_BYE: u16 = 11;
const K_ERROR: u16 = 12;
const K_MASKED_PHASE_DONE: u16 = 13;

fn put_channel(w: &mut WireWriter, c: UploadChannel) {
    w.put_u8(match c {
        UploadChannel::DeviceEdge => 0,
        UploadChannel::DeviceCloud => 1,
        UploadChannel::DeviceEdgeMasked => 2,
    });
}

fn get_channel(r: &mut WireReader) -> Result<UploadChannel> {
    match r.get_u8()? {
        0 => Ok(UploadChannel::DeviceEdge),
        1 => Ok(UploadChannel::DeviceCloud),
        2 => Ok(UploadChannel::DeviceEdgeMasked),
        t => Err(CfelError::Codec(format!("unknown upload channel tag {t}"))),
    }
}

fn put_verdict(w: &mut WireWriter, v: ReportVerdict) {
    w.put_u8(match v {
        ReportVerdict::OnTime => 0,
        ReportVerdict::Late => 1,
        ReportVerdict::Dropped => 2,
    });
}

fn get_verdict(r: &mut WireReader) -> Result<ReportVerdict> {
    match r.get_u8()? {
        0 => Ok(ReportVerdict::OnTime),
        1 => Ok(ReportVerdict::Late),
        2 => Ok(ReportVerdict::Dropped),
        t => Err(CfelError::Codec(format!("unknown report verdict tag {t}"))),
    }
}

fn put_timing(w: &mut WireWriter, pt: &PhaseTiming) {
    w.put_f64(pt.duration_s);
    w.put_f64(pt.compute_s);
    w.put_f64(pt.upload_s);
    w.put_usizes(&pt.devices.device);
    w.put_f64s(&pt.devices.compute_s);
    w.put_f64s(&pt.devices.upload_s);
    w.put_f64s(&pt.devices.finish_s);
    w.put_usize(pt.devices.verdict.len());
    for &v in &pt.devices.verdict {
        put_verdict(w, v);
    }
    w.put_usize(pt.events);
    w.put_u8(pt.close_reason.index() as u8);
}

fn get_timing(r: &mut WireReader) -> Result<PhaseTiming> {
    let duration_s = r.get_f64()?;
    let compute_s = r.get_f64()?;
    let upload_s = r.get_f64()?;
    let device = r.get_usizes()?;
    let dev_compute = r.get_f64s()?;
    let dev_upload = r.get_f64s()?;
    let finish = r.get_f64s()?;
    let nv = r.get_len(1)?;
    let mut verdict = Vec::with_capacity(nv);
    for _ in 0..nv {
        verdict.push(get_verdict(r)?);
    }
    if [dev_compute.len(), dev_upload.len(), finish.len(), verdict.len()]
        .iter()
        .any(|&l| l != device.len())
    {
        return Err(CfelError::Codec(
            "device-timing columns disagree on length".into(),
        ));
    }
    let events = r.get_usize()?;
    let reason = r.get_u8()? as usize;
    let close_reason = *CloseReason::ALL
        .get(reason)
        .ok_or_else(|| CfelError::Codec(format!("unknown close reason index {reason}")))?;
    Ok(PhaseTiming {
        duration_s,
        compute_s,
        upload_s,
        devices: DeviceTimings {
            device,
            compute_s: dev_compute,
            upload_s: dev_upload,
            finish_s: finish,
            verdict,
        },
        events,
        close_reason,
    })
}

fn put_phase(w: &mut WireWriter, p: &ClusterPhase) {
    w.put_usize(p.cluster);
    w.put_usize(p.reports.len());
    for &(dev, steps, loss) in &p.reports {
        w.put_usize(dev);
        w.put_usize(steps);
        w.put_f64(loss);
    }
    w.put_f32s(&p.model);
    w.put_f64(p.clock_s);
    w.put_bool(p.timing.is_some());
    if let Some(pt) = &p.timing {
        put_timing(w, pt);
    }
    w.put_usize(p.stale_merged);
    w.put_usize(p.pending_after);
    w.put_f64(p.secagg_mask_s);
    w.put_f64(p.secagg_extra_bits);
}

fn get_phase(r: &mut WireReader) -> Result<ClusterPhase> {
    let cluster = r.get_usize()?;
    let nr = r.get_len(24)?;
    let mut reports = Vec::with_capacity(nr);
    for _ in 0..nr {
        let dev = r.get_usize()?;
        let steps = r.get_usize()?;
        let loss = r.get_f64()?;
        reports.push((dev, steps, loss));
    }
    let model = r.get_f32s()?;
    let clock_s = r.get_f64()?;
    let timing = if r.get_bool()? {
        Some(get_timing(r)?)
    } else {
        None
    };
    let stale_merged = r.get_usize()?;
    let pending_after = r.get_usize()?;
    let secagg_mask_s = r.get_f64()?;
    let secagg_extra_bits = r.get_f64()?;
    Ok(ClusterPhase {
        cluster,
        reports,
        model,
        clock_s,
        timing,
        stale_merged,
        pending_after,
        masked: None,
        secagg_mask_s,
        secagg_extra_bits,
    })
}

/// The optional masked-sum suffix a [`Msg::MaskedPhaseDone`] phase
/// carries after the common [`put_phase`] layout.
fn put_masked(w: &mut WireWriter, masked: &Option<MaskedSum>) {
    w.put_bool(masked.is_some());
    if let Some(sum) = masked {
        w.put_u64s(&sum.words);
        w.put_u64(sum.total_weight);
    }
}

fn get_masked(r: &mut WireReader) -> Result<Option<MaskedSum>> {
    if !r.get_bool()? {
        return Ok(None);
    }
    let words = r.get_u64s()?;
    let total_weight = r.get_u64()?;
    Ok(Some(MaskedSum { words, total_weight }))
}

fn put_policies(w: &mut WireWriter, policies: &[(usize, String)]) {
    w.put_usize(policies.len());
    for (ci, spec) in policies {
        w.put_usize(*ci);
        w.put_str(spec);
    }
}

fn get_policies(r: &mut WireReader) -> Result<Vec<(usize, String)>> {
    let n = r.get_len(16)?;
    let mut policies = Vec::with_capacity(n);
    for _ in 0..n {
        let ci = r.get_usize()?;
        let spec = r.get_str()?;
        policies.push((ci, spec));
    }
    Ok(policies)
}

#[allow(clippy::type_complexity)]
fn put_state(w: &mut WireWriter, models: &[(usize, Vec<f32>)], clocks: &[(usize, f64)]) {
    w.put_usize(models.len());
    for (ci, m) in models {
        w.put_usize(*ci);
        w.put_f32s(m);
    }
    w.put_usize(clocks.len());
    for &(ci, t) in clocks {
        w.put_usize(ci);
        w.put_f64(t);
    }
}

#[allow(clippy::type_complexity)]
fn get_state(r: &mut WireReader) -> Result<(Vec<(usize, Vec<f32>)>, Vec<(usize, f64)>)> {
    let nm = r.get_len(12)?;
    let mut models = Vec::with_capacity(nm);
    for _ in 0..nm {
        let ci = r.get_usize()?;
        let m = r.get_f32s()?;
        models.push((ci, m));
    }
    let nc = r.get_len(16)?;
    let mut clocks = Vec::with_capacity(nc);
    for _ in 0..nc {
        let ci = r.get_usize()?;
        let t = r.get_f64()?;
        clocks.push((ci, t));
    }
    Ok((models, clocks))
}

impl Msg {
    /// Short name for log and error messages.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Init { .. } => "init",
            Msg::InitOk => "init-ok",
            Msg::BeginRound { .. } => "begin-round",
            Msg::RoundBegun => "round-begun",
            Msg::RunPhase { .. } => "run-phase",
            Msg::PhaseDone { .. } => "phase-done",
            Msg::MaskedPhaseDone { .. } => "masked-phase-done",
            Msg::SetState { .. } => "set-state",
            Msg::StateSet => "state-set",
            Msg::Shutdown => "shutdown",
            Msg::Bye => "bye",
            Msg::Error { .. } => "error",
        }
    }

    /// Frame kind + payload.
    pub fn encode(&self) -> (u16, Vec<u8>) {
        let mut w = WireWriter::new();
        let kind = match self {
            Msg::Hello { proto } => {
                w.put_u16(*proto);
                K_HELLO
            }
            Msg::Init {
                config_json,
                clusters,
                rounds_applied,
                models,
                clocks,
                policies,
            } => {
                w.put_str(config_json);
                w.put_usizes(clusters);
                w.put_usize(*rounds_applied);
                put_state(&mut w, models, clocks);
                put_policies(&mut w, policies);
                K_INIT
            }
            Msg::InitOk => K_INIT_OK,
            Msg::BeginRound { round, policies } => {
                w.put_usize(*round);
                put_policies(&mut w, policies);
                K_BEGIN_ROUND
            }
            Msg::RoundBegun => K_ROUND_BEGUN,
            Msg::RunPhase {
                phase,
                epochs,
                channel,
            } => {
                w.put_u64(*phase);
                w.put_usize(*epochs);
                put_channel(&mut w, *channel);
                K_RUN_PHASE
            }
            Msg::PhaseDone { phases } => {
                w.put_usize(phases.len());
                for p in phases {
                    debug_assert!(
                        p.masked.is_none(),
                        "masked phases must travel as MaskedPhaseDone"
                    );
                    put_phase(&mut w, p);
                }
                K_PHASE_DONE
            }
            Msg::MaskedPhaseDone { phases } => {
                w.put_usize(phases.len());
                for p in phases {
                    put_phase(&mut w, p);
                    put_masked(&mut w, &p.masked);
                }
                K_MASKED_PHASE_DONE
            }
            Msg::SetState { models, clocks } => {
                put_state(&mut w, models, clocks);
                K_SET_STATE
            }
            Msg::StateSet => K_STATE_SET,
            Msg::Shutdown => K_SHUTDOWN,
            Msg::Bye => K_BYE,
            Msg::Error { message } => {
                w.put_str(message);
                K_ERROR
            }
        };
        (kind, w.into_payload())
    }

    /// Decode one frame; the payload must be consumed exactly.
    pub fn decode(kind: u16, payload: &[u8]) -> Result<Msg> {
        let mut r = WireReader::new(payload);
        let msg = match kind {
            K_HELLO => Msg::Hello {
                proto: r.get_u16()?,
            },
            K_INIT => {
                let config_json = r.get_str()?;
                let clusters = r.get_usizes()?;
                let rounds_applied = r.get_usize()?;
                let (models, clocks) = get_state(&mut r)?;
                let policies = get_policies(&mut r)?;
                Msg::Init {
                    config_json,
                    clusters,
                    rounds_applied,
                    models,
                    clocks,
                    policies,
                }
            }
            K_INIT_OK => Msg::InitOk,
            K_BEGIN_ROUND => Msg::BeginRound {
                round: r.get_usize()?,
                policies: get_policies(&mut r)?,
            },
            K_ROUND_BEGUN => Msg::RoundBegun,
            K_RUN_PHASE => Msg::RunPhase {
                phase: r.get_u64()?,
                epochs: r.get_usize()?,
                channel: get_channel(&mut r)?,
            },
            K_PHASE_DONE => {
                let n = r.get_len(1)?;
                let mut phases = Vec::with_capacity(n);
                for _ in 0..n {
                    phases.push(get_phase(&mut r)?);
                }
                Msg::PhaseDone { phases }
            }
            K_MASKED_PHASE_DONE => {
                let n = r.get_len(1)?;
                let mut phases = Vec::with_capacity(n);
                for _ in 0..n {
                    let mut p = get_phase(&mut r)?;
                    p.masked = get_masked(&mut r)?;
                    phases.push(p);
                }
                Msg::MaskedPhaseDone { phases }
            }
            K_SET_STATE => {
                let (models, clocks) = get_state(&mut r)?;
                Msg::SetState { models, clocks }
            }
            K_STATE_SET => Msg::StateSet,
            K_SHUTDOWN => Msg::Shutdown,
            K_BYE => Msg::Bye,
            K_ERROR => Msg::Error {
                message: r.get_str()?,
            },
            k => return Err(CfelError::Codec(format!("unknown frame kind {k}"))),
        };
        r.finish()?;
        Ok(msg)
    }
}

/// Encode and send one message as a frame.
pub fn send<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let (kind, payload) = msg.encode();
    write_frame(w, kind, &payload)
}

/// Receive and decode one message; errors if the peer closed cleanly.
pub fn recv<R: Read>(r: &mut R) -> Result<Msg> {
    let (kind, payload) = read_frame(r)?;
    Msg::decode(kind, &payload)
}

/// Receive one message; `Ok(None)` when the peer closed the connection
/// cleanly between messages.
pub fn recv_opt<R: Read>(r: &mut R) -> Result<Option<Msg>> {
    match read_frame_opt(r)? {
        Some((kind, payload)) => Ok(Some(Msg::decode(kind, &payload)?)),
        None => Ok(None),
    }
}
