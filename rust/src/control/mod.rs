//! Online adaptive control plane — telemetry-driven per-round rewriting.
//!
//! CE-FedAvg as shipped fixes its schedule before training starts: one
//! [`Plan`], one [`AggPolicyKind`](crate::config::AggPolicyKind) for every
//! cluster, for every round. But CFEL's whole premise is a mobile edge
//! whose churn and link quality drift round to round. Now that plans,
//! worlds and close policies are all *data*, a [`Controller`] can rewrite
//! them at each round boundary from observed telemetry:
//!
//! * [`Static`] — never adapts. Pinned bit-identical to the plain
//!   interpreter (history digest + CSV) across `CFEL_THREADS` and across
//!   the `ClusterExecutor` seam by `rust/tests/control_equivalence.rs`.
//! * [`AdaptiveSemiSync`] — refits per-cluster semi-sync `K`/timeout each
//!   round from the empirical report-time quantiles of a sliding window,
//!   clamped to `[1, n]` via [`SemiSync::from_fit`].
//! * [`FloatingAggregation`] — the floating aggregation point of
//!   arXiv:2203.13950: swaps `cloud` ↔ `gossip(π)` steps (and migrates
//!   the aggregator-anchor cluster) when cloud backhaul bandwidth or
//!   roster churn crosses hysteresis thresholds.
//!
//! # Determinism contract
//!
//! Every decision is a **pure function of prior telemetry**, and the
//! telemetry itself ([`RoundTelemetry`]) is derived exclusively from
//! simulated quantities — virtual report times, verdict counts, roster
//! sizes, configured bandwidths — never wall clocks. The coordinator
//! invokes the controller exactly once per round boundary (before
//! `plan_round`, after timeline events), logs the resulting note into the
//! round's CSV row, and in the distributed runtime makes the decision
//! *cloud-side only*, shipping the resulting policy overrides through the
//! existing `BeginRound`/`Init` flow. Edges never decide; the wire stays
//! decision-agnostic. See `docs/DETERMINISM.md` §"Adaptive control".

use crate::config::{AggPolicyKind, ControllerKind};
use crate::plan::Plan;

/// One cluster's view of the round that just finished.
#[derive(Debug, Clone, Default)]
pub struct ClusterTelemetry {
    /// Cluster index (stable across rounds).
    pub cluster: usize,
    /// Whether the cluster still has members after timeline events.
    pub alive: bool,
    /// Roster size after this round's churn/timeline events.
    pub roster: usize,
    /// Per-device report times (virtual seconds from phase start), pooled
    /// across the round's edge phases. Unordered; consumers sort.
    pub report_s: Vec<f64>,
    /// Reports that made their phase close.
    pub on_time: usize,
    /// Reports kept but merged stale (semi-sync).
    pub late: usize,
    /// Reports discarded outright (deadline-drop).
    pub dropped: usize,
}

/// Everything a controller may condition on: the completed round's
/// per-cluster report distributions plus the world state the next round
/// will run under (bandwidths and rosters *after* timeline events).
#[derive(Debug, Clone)]
pub struct RoundTelemetry {
    /// The round this telemetry describes (0-based).
    pub round: usize,
    /// One entry per cluster, ascending cluster index.
    pub clusters: Vec<ClusterTelemetry>,
    /// Phase-close counts indexed by `CloseReason::index()`.
    pub close_reasons: [usize; 4],
    /// Simulated backhaul seconds accumulated this round (gossip + cloud).
    pub backhaul_s: f64,
    /// Device→cloud bandwidth in effect for the *next* round (bit/s).
    pub b_d2c: f64,
    /// Edge↔edge backhaul bandwidth for the next round (bit/s).
    pub b_e2e: f64,
    /// Whether the current aggregator-anchor cluster is still alive.
    pub aggregator_alive: bool,
}

impl RoundTelemetry {
    /// Total roster across alive clusters.
    pub fn total_roster(&self) -> usize {
        self.clusters.iter().map(|c| c.roster).sum()
    }
}

/// A controller's verdict for the next round. `None` fields mean "keep".
#[derive(Debug, Clone)]
pub struct Decision {
    /// Replacement plan for the next round, already validated by the
    /// coordinator before installation.
    pub plan: Option<Plan>,
    /// Full replacement set of per-cluster close-policy overrides as
    /// `(cluster, spec)` pairs; the spec grammar is
    /// [`AggPolicyKind::parse`]. `Some(vec![])` clears all overrides.
    pub policies: Option<Vec<(usize, String)>>,
    /// New aggregator-anchor cluster (provenance only — cloud aggregation
    /// is host-symmetric in the simulator, so this changes no arithmetic).
    pub aggregator: Option<usize>,
    /// Human-readable, comma-free provenance line for the CSV `decision`
    /// column; `"-"` means "no change".
    pub note: String,
}

impl Decision {
    /// The no-op decision.
    pub fn keep() -> Decision {
        Decision { plan: None, policies: None, aggregator: None, note: "-".into() }
    }

    /// Whether this decision changes anything.
    pub fn is_keep(&self) -> bool {
        self.plan.is_none() && self.policies.is_none() && self.aggregator.is_none()
    }
}

/// Round-boundary controller: consulted once per round with the previous
/// round's telemetry (`None` before round 0) and the plan currently in
/// force; returns a [`Decision`]. Implementations must be pure functions
/// of their constructor parameters and the telemetry stream — no clocks,
/// no RNG — so replaying the same run reproduces every decision bit for
/// bit regardless of `CFEL_THREADS` or the executor seam.
pub trait Controller: Send {
    /// Stable name used in `run_label()` and logs.
    fn name(&self) -> String;

    /// `true` only for [`Static`]: lets the coordinator skip telemetry
    /// capture entirely, guaranteeing zero behavioural delta.
    fn is_static(&self) -> bool {
        false
    }

    /// Decide what round `round` should run. `telemetry` is the completed
    /// previous round's view (`None` for the first round); `plan` is the
    /// plan currently in force.
    fn decide(&mut self, round: usize, telemetry: Option<&RoundTelemetry>, plan: &Plan)
        -> Decision;
}

/// Instantiate the configured controller. `pi` is the config's gossip
/// step count, used when [`FloatingAggregation`] synthesizes `gossip(π)`
/// steps.
pub fn build(kind: ControllerKind, pi: u32) -> Box<dyn Controller> {
    match kind {
        ControllerKind::Static => Box::new(Static),
        ControllerKind::AdaptiveSemiSync { window } => {
            Box::new(AdaptiveSemiSync::new(window))
        }
        ControllerKind::FloatingAggregation { threshold } => {
            Box::new(FloatingAggregation::new(threshold, pi))
        }
    }
}

/// Never adapts. The `is_static` fast path means the coordinator does not
/// even extract telemetry, so a static-controlled run executes the exact
/// instruction stream of a controller-free run.
#[derive(Debug, Clone, Copy, Default)]
pub struct Static;

impl Controller for Static {
    fn name(&self) -> String {
        "static".into()
    }

    fn is_static(&self) -> bool {
        true
    }

    fn decide(&mut self, _round: usize, _t: Option<&RoundTelemetry>, _plan: &Plan) -> Decision {
        Decision::keep()
    }
}

/// Nearest-rank quantile of an ascending-sorted slice (`q` in `[0, 1]`).
fn quantile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1).min(sorted.len()) - 1]
}

/// Fit semi-sync `(k, timeout_s)` to an empirical report-time sample for
/// a cluster of `n` devices. Pure and total: any input — empty, NaN-laden,
/// negative — yields `1 <= k <= max(n, 1)` and a timeout that is either
/// finite-positive or `f64::INFINITY` (the invariant proptested by
/// `rust/tests/control_equivalence.rs`).
///
/// The fit is the straggler heuristic from the semi-sync literature
/// (arXiv:1909.11875): take the median report time, call everything within
/// `2×` median "the pack", close once the pack has reported
/// (`k = ⌈pack-fraction · n⌉`), and arm a timeout at the observed p99 so a
/// regime shift (links degrading mid-run) cannot stall the close.
pub fn fit(samples: &[f64], n: usize) -> (usize, f64) {
    let mut clean: Vec<f64> =
        samples.iter().copied().filter(|s| s.is_finite() && *s >= 0.0).collect();
    let n_eff = n.max(1);
    if clean.is_empty() {
        return (n_eff, f64::INFINITY);
    }
    clean.sort_by(f64::total_cmp);
    let cutoff = 2.0 * quantile(&clean, 0.5);
    let in_pack = clean.iter().filter(|&&s| s <= cutoff).count();
    let frac = in_pack as f64 / clean.len() as f64;
    let k = (frac * n_eff as f64).ceil() as usize;
    let k = k.clamp(1, n_eff);
    let timeout = quantile(&clean, 0.99).max(cutoff);
    let timeout =
        if timeout.is_finite() && timeout > 0.0 { timeout } else { f64::INFINITY };
    (k, timeout)
}

/// Refits each cluster's semi-sync close condition every round from a
/// sliding window of report-time telemetry. Emits policy overrides only —
/// the plan is never touched.
#[derive(Debug, Clone)]
pub struct AdaptiveSemiSync {
    window: usize,
    /// Sliding window: one entry per completed round, each holding the
    /// per-cluster report-time samples of that round.
    history: Vec<Vec<Vec<f64>>>,
}

impl AdaptiveSemiSync {
    pub fn new(window: usize) -> AdaptiveSemiSync {
        AdaptiveSemiSync { window: window.max(1), history: Vec::new() }
    }
}

impl Controller for AdaptiveSemiSync {
    fn name(&self) -> String {
        format!("adaptive:{}", self.window)
    }

    fn decide(&mut self, _round: usize, telemetry: Option<&RoundTelemetry>, _plan: &Plan)
        -> Decision {
        let Some(t) = telemetry else {
            return Decision::keep();
        };
        let round_samples: Vec<Vec<f64>> =
            t.clusters.iter().map(|c| c.report_s.clone()).collect();
        self.history.push(round_samples);
        if self.history.len() > self.window {
            let excess = self.history.len() - self.window;
            self.history.drain(..excess);
        }
        let mut policies = Vec::new();
        let (mut k_lo, mut k_hi) = (usize::MAX, 0usize);
        let (mut t_lo, mut t_hi) = (f64::INFINITY, 0.0f64);
        for ct in &t.clusters {
            if !ct.alive || ct.roster == 0 {
                continue;
            }
            let pooled: Vec<f64> = self
                .history
                .iter()
                .filter_map(|round| round.get(ct.cluster))
                .flatten()
                .copied()
                .collect();
            if pooled.is_empty() {
                continue;
            }
            let (k, timeout_s) = fit(&pooled, ct.roster);
            k_lo = k_lo.min(k);
            k_hi = k_hi.max(k);
            t_lo = t_lo.min(timeout_s);
            t_hi = t_hi.max(timeout_s);
            policies.push((ct.cluster, AggPolicyKind::SemiSync { k, timeout_s }.name()));
        }
        if policies.is_empty() {
            return Decision::keep();
        }
        let note = format!(
            "refit {} clusters k[{k_lo}-{k_hi}] t[{t_lo:.3}-{t_hi:.3}]",
            policies.len()
        );
        Decision { plan: None, policies: Some(policies), aggregator: None, note }
    }
}

/// Floating aggregation point (arXiv:2203.13950). Tracks the cloud
/// backhaul bandwidth against its first-round baseline and the per-round
/// roster churn; when either crosses the entry threshold (or the anchor
/// cluster dies) the plan's `cloud` steps are rewritten to `gossip(π)`
/// via [`Plan::decentralize`], and restored from the saved base plan once
/// conditions recover past the (stricter) exit threshold — classic
/// hysteresis, so a link flapping around the threshold cannot thrash the
/// plan every round. Independently, the aggregator anchor migrates to the
/// largest alive cluster (ties → lowest index) for provenance.
#[derive(Debug, Clone)]
pub struct FloatingAggregation {
    threshold: f64,
    pi: u32,
    base_plan: Option<Plan>,
    baseline_d2c: Option<f64>,
    decentralized: bool,
    anchor: Option<usize>,
    prev_rosters: Vec<usize>,
}

/// Roster-churn fraction above which the controller decentralizes.
const CHURN_ENTER: f64 = 0.25;
/// Churn must fall back below this before recentralizing.
const CHURN_EXIT: f64 = 0.10;

impl FloatingAggregation {
    pub fn new(threshold: f64, pi: u32) -> FloatingAggregation {
        FloatingAggregation {
            threshold,
            pi: pi.max(1),
            base_plan: None,
            baseline_d2c: None,
            decentralized: false,
            anchor: None,
            prev_rosters: Vec::new(),
        }
    }

    /// Fraction of devices that moved since the previous round:
    /// `Σ|rosterᵢ(t) − rosterᵢ(t−1)| / Σrosterᵢ(t−1)`.
    fn churn(&self, t: &RoundTelemetry) -> f64 {
        if self.prev_rosters.is_empty() {
            return 0.0;
        }
        let prev_total: usize = self.prev_rosters.iter().sum();
        if prev_total == 0 {
            return 0.0;
        }
        let moved: usize = t
            .clusters
            .iter()
            .map(|c| {
                let prev = self.prev_rosters.get(c.cluster).copied().unwrap_or(0);
                c.roster.abs_diff(prev)
            })
            .sum();
        moved as f64 / prev_total as f64
    }

    /// Largest alive cluster; ties break to the lowest index.
    fn pick_anchor(t: &RoundTelemetry) -> Option<usize> {
        t.clusters
            .iter()
            .filter(|c| c.alive && c.roster > 0)
            .max_by(|a, b| a.roster.cmp(&b.roster).then(b.cluster.cmp(&a.cluster)))
            .map(|c| c.cluster)
    }
}

impl Controller for FloatingAggregation {
    fn name(&self) -> String {
        format!("floating:{}", self.threshold)
    }

    fn decide(&mut self, _round: usize, telemetry: Option<&RoundTelemetry>, plan: &Plan)
        -> Decision {
        if self.base_plan.is_none() {
            self.base_plan = Some(plan.clone());
        }
        let Some(t) = telemetry else {
            return Decision::keep();
        };
        let baseline = *self.baseline_d2c.get_or_insert(t.b_d2c);
        let churn = self.churn(t);
        self.prev_rosters = {
            let max_idx =
                t.clusters.iter().map(|c| c.cluster).max().map_or(0, |m| m + 1);
            let mut rosters = vec![0usize; max_idx];
            for c in &t.clusters {
                rosters[c.cluster] = c.roster;
            }
            rosters
        };

        let mut decision = Decision::keep();
        let mut notes: Vec<String> = Vec::new();

        // Anchor migration (provenance only; arithmetic is host-symmetric).
        let anchor = Self::pick_anchor(t);
        if anchor.is_some() && anchor != self.anchor {
            let c = anchor.unwrap();
            if self.anchor.is_some() {
                notes.push(format!("aggregator->c{c}"));
            }
            self.anchor = anchor;
            decision.aggregator = anchor;
        }

        // Plan rewriting only makes sense if the base plan aggregates in
        // the cloud at all.
        let base = self.base_plan.as_ref().expect("base plan captured above");
        if base.has_cloud_aggregate() {
            let degraded = t.b_d2c < self.threshold * baseline;
            let churny = churn > CHURN_ENTER;
            let anchor_dead = !t.aggregator_alive;
            if !self.decentralized && (degraded || churny || anchor_dead) {
                self.decentralized = true;
                decision.plan = Some(base.decentralize(self.pi));
                let why = if degraded {
                    format!("d2c {:.0} < {:.0}", t.b_d2c, self.threshold * baseline)
                } else if churny {
                    format!("churn {churn:.2}")
                } else {
                    "aggregator lost".into()
                };
                notes.push(format!("cloud->gossip ({why})"));
            } else if self.decentralized {
                // Exit hysteresis: halfway between threshold and 1.0.
                let exit_at = baseline * (self.threshold + 1.0) / 2.0;
                if t.b_d2c >= exit_at && churn <= CHURN_EXIT && t.aggregator_alive {
                    self.decentralized = false;
                    decision.plan = Some(base.clone());
                    notes.push("gossip->cloud (links recovered)".into());
                }
            }
        }

        if !notes.is_empty() {
            decision.note = notes.join("; ");
        }
        decision
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn telemetry(rosters: &[usize], b_d2c: f64) -> RoundTelemetry {
        RoundTelemetry {
            round: 0,
            clusters: rosters
                .iter()
                .enumerate()
                .map(|(i, &r)| ClusterTelemetry {
                    cluster: i,
                    alive: r > 0,
                    roster: r,
                    report_s: vec![0.5, 1.0, 1.5, 9.0],
                    on_time: r,
                    late: 0,
                    dropped: 0,
                })
                .collect(),
            close_reasons: [0; 4],
            backhaul_s: 0.0,
            b_d2c,
            b_e2e: 5e7,
            aggregator_alive: true,
        }
    }

    #[test]
    fn static_controller_always_keeps() {
        let mut c = Static;
        assert!(c.is_static());
        let plan = Plan::parse("edge(2)@cloud; cloud").unwrap();
        let t = telemetry(&[4, 4], 1e6);
        for round in 0..5 {
            let d = c.decide(round, Some(&t), &plan);
            assert!(d.is_keep());
            assert_eq!(d.note, "-");
        }
    }

    #[test]
    fn fit_is_total_and_clamped() {
        // Empty / garbage samples degrade to the full barrier.
        assert_eq!(fit(&[], 8), (8, f64::INFINITY));
        assert_eq!(fit(&[f64::NAN, -1.0, f64::INFINITY], 8), (8, f64::INFINITY));
        assert_eq!(fit(&[], 0).0, 1, "empty cluster still yields k >= 1");
        // A tight pack plus one straggler: k excludes the straggler.
        let (k, t) = fit(&[1.0, 1.1, 1.2, 1.3, 50.0], 5);
        assert_eq!(k, 4);
        assert!(t >= 50.0, "timeout covers the observed p99: {t}");
        // Homogeneous reports keep the barrier (everyone is in the pack).
        let (k, _) = fit(&[2.0, 2.0, 2.0, 2.0], 4);
        assert_eq!(k, 4);
        // All-zero samples: cutoff 0, pack = everyone, timeout sanitized.
        let (k, t) = fit(&[0.0, 0.0], 4);
        assert_eq!(k, 4);
        assert!(t.is_infinite());
    }

    #[test]
    fn adaptive_emits_valid_specs_and_windows() {
        let mut c = AdaptiveSemiSync::new(2);
        let plan = Plan::parse("edge(2)@cloud; cloud").unwrap();
        assert!(c.decide(0, None, &plan).is_keep(), "no telemetry -> keep");
        let t = telemetry(&[4, 0, 6], 1e6);
        for round in 1..5 {
            let d = c.decide(round, Some(&t), &plan);
            let pols = d.policies.expect("telemetry present -> refit");
            // Dead cluster 1 is skipped; the rest parse and clamp.
            assert_eq!(pols.len(), 2);
            for (ci, spec) in &pols {
                assert_ne!(*ci, 1);
                let kind = crate::config::AggPolicyKind::parse(spec).unwrap();
                let crate::config::AggPolicyKind::SemiSync { k, timeout_s } = kind else {
                    panic!("adaptive must emit kofn specs, got {spec}");
                };
                let n = t.clusters[*ci].roster;
                assert!(k >= 1 && k <= n, "k={k} out of [1,{n}]");
                assert!(timeout_s.is_infinite() || timeout_s > 0.0);
            }
            assert!(!d.note.contains(','), "CSV notes must be comma-free");
        }
        assert_eq!(c.history.len(), 2, "window truncates history");
    }

    #[test]
    fn floating_enters_and_exits_with_hysteresis() {
        let mut c = FloatingAggregation::new(0.5, 3);
        let plan = Plan::parse("edge(2)@cloud; cloud").unwrap();
        assert!(c.decide(0, None, &plan).is_keep());
        // Healthy baseline round: anchor settles, plan untouched.
        let d = c.decide(1, Some(&telemetry(&[4, 6], 1e6)), &plan);
        assert!(d.plan.is_none());
        assert_eq!(d.aggregator, Some(1), "largest cluster anchors");
        // Mild degradation (60% of baseline) stays centralized.
        let d = c.decide(2, Some(&telemetry(&[4, 6], 6e5)), &plan);
        assert!(d.plan.is_none());
        // Below threshold: decentralize, cloud becomes gossip(pi).
        let d = c.decide(3, Some(&telemetry(&[4, 6], 4e5)), &plan);
        let rewritten = d.plan.expect("threshold crossing rewrites the plan");
        assert!(rewritten.has_gossip() && !rewritten.has_cloud_aggregate());
        assert!(d.note.starts_with("cloud->gossip"));
        // Recovery to 60% is inside the hysteresis band: stay gossip.
        let gossip_plan = rewritten.clone();
        let d = c.decide(4, Some(&telemetry(&[4, 6], 6e5)), &gossip_plan);
        assert!(d.plan.is_none(), "hysteresis holds at 60%");
        // Full recovery past (threshold+1)/2 = 75%: restore the base plan.
        let d = c.decide(5, Some(&telemetry(&[4, 6], 9e5)), &gossip_plan);
        let restored = d.plan.expect("recovery restores the base plan");
        assert_eq!(format!("{restored}"), format!("{plan}"));
        assert!(d.note.contains("gossip->cloud"));
    }

    #[test]
    fn floating_reacts_to_churn_and_anchor_death() {
        let mut c = FloatingAggregation::new(0.5, 2);
        let plan = Plan::parse("edge(1); cloud").unwrap();
        c.decide(0, None, &plan);
        c.decide(1, Some(&telemetry(&[10, 10], 1e6)), &plan);
        // 6 of 20 devices moved: churn 0.3 > 0.25 enters gossip.
        let d = c.decide(2, Some(&telemetry(&[7, 13], 1e6)), &plan);
        assert!(d.plan.is_some(), "churn crossing decentralizes");
        assert!(d.note.contains("churn"));

        // Anchor death also triggers entry.
        let mut c = FloatingAggregation::new(0.5, 2);
        c.decide(0, None, &plan);
        c.decide(1, Some(&telemetry(&[10, 10], 1e6)), &plan);
        let mut t = telemetry(&[10, 10], 1e6);
        t.aggregator_alive = false;
        let d = c.decide(2, Some(&t), &plan);
        assert!(d.plan.is_some());
        assert!(d.note.contains("aggregator lost"));
    }

    #[test]
    fn build_matches_kind_names() {
        let pairs = [
            (ControllerKind::Static, "static"),
            (ControllerKind::AdaptiveSemiSync { window: 3 }, "adaptive:3"),
            (ControllerKind::FloatingAggregation { threshold: 0.5 }, "floating:0.5"),
        ];
        for (kind, name) in pairs {
            assert_eq!(build(kind, 4).name(), name);
            assert_eq!(kind.name(), name);
        }
        assert!(build(ControllerKind::Static, 4).is_static());
        assert!(!build(ControllerKind::AdaptiveSemiSync { window: 3 }, 4).is_static());
    }
}
