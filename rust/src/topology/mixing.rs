//! Gossip mixing matrix **H** (paper Assumption 4) and its spectral
//! quantities.
//!
//! * Metropolis–Hastings weights make H symmetric doubly stochastic for any
//!   connected graph: `H[i][j] = 1 / (1 + max(d_i, d_j))` for edges,
//!   diagonal absorbs the remainder.
//! * ζ = max{|λ₂|, |λ_m|} — the second largest eigenvalue magnitude —
//!   computed by power iteration on H deflated by the all-ones eigenvector
//!   (H is symmetric, so power iteration converges to the dominant
//!   remaining eigenvalue magnitude).
//! * Ω₁, Ω₂ of Eq. 15 — the constants in Theorem 1's bound; exposed so the
//!   figure harnesses can report the theory-side quantities next to the
//!   measured convergence curves.

use crate::error::{CfelError, Result};
use crate::topology::Graph;

/// A dense m×m doubly-stochastic mixing matrix.
#[derive(Debug, Clone)]
pub struct MixingMatrix {
    m: usize,
    /// Row-major storage; `h[i*m + j]` = weight server i assigns to j.
    h: Vec<f64>,
}

impl MixingMatrix {
    /// Metropolis–Hastings weights on `graph` (symmetric doubly stochastic).
    pub fn metropolis(graph: &Graph) -> MixingMatrix {
        let m = graph.len();
        let mut h = vec![0.0; m * m];
        for i in 0..m {
            let mut diag = 1.0;
            for &j in graph.neighbors(i) {
                let w = 1.0 / (1.0 + graph.degree(i).max(graph.degree(j)) as f64);
                h[i * m + j] = w;
                diag -= w;
            }
            h[i * m + i] = diag;
        }
        MixingMatrix { m, h }
    }

    /// Uniform averaging matrix H = (1/m) 11ᵀ — the Hier-FAvg / cloud limit.
    pub fn uniform(m: usize) -> MixingMatrix {
        MixingMatrix { m, h: vec![1.0 / m as f64; m * m] }
    }

    /// Identity (no cooperation — the Local-Edge limit).
    pub fn identity(m: usize) -> MixingMatrix {
        let mut h = vec![0.0; m * m];
        for i in 0..m {
            h[i * m + i] = 1.0;
        }
        MixingMatrix { m, h }
    }

    /// Build from explicit row-major entries (tests / custom weights).
    pub fn from_rows(m: usize, h: Vec<f64>) -> Result<MixingMatrix> {
        if h.len() != m * m {
            return Err(CfelError::Topology(format!(
                "mixing matrix needs {}x{} entries, got {}",
                m,
                m,
                h.len()
            )));
        }
        let mm = MixingMatrix { m, h };
        mm.validate()?;
        Ok(mm)
    }

    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.h[i * self.m + j]
    }

    /// Row-major raw entries (for the PJRT aggregate fast path).
    pub fn entries(&self) -> &[f64] {
        &self.h
    }

    /// Check Assumption 4: non-negative, symmetric, doubly stochastic.
    pub fn validate(&self) -> Result<()> {
        let m = self.m;
        for i in 0..m {
            let mut row = 0.0;
            let mut col = 0.0;
            for j in 0..m {
                let v = self.get(i, j);
                if v < -1e-12 {
                    return Err(CfelError::Topology(format!(
                        "negative weight H[{i}][{j}] = {v}"
                    )));
                }
                if (v - self.get(j, i)).abs() > 1e-9 {
                    return Err(CfelError::Topology(format!(
                        "asymmetric H at ({i},{j})"
                    )));
                }
                row += v;
                col += self.get(j, i);
            }
            if (row - 1.0).abs() > 1e-9 || (col - 1.0).abs() > 1e-9 {
                return Err(CfelError::Topology(format!(
                    "row/col {i} sums {row}/{col} != 1"
                )));
            }
        }
        Ok(())
    }

    /// Matrix power H^π (π = gossip steps per global round, paper Eq. 7).
    pub fn power(&self, pi: u32) -> MixingMatrix {
        let mut result = MixingMatrix::identity(self.m);
        let mut base = self.clone();
        let mut e = pi;
        while e > 0 {
            if e & 1 == 1 {
                result = result.matmul(&base);
            }
            base = base.matmul(&base);
            e >>= 1;
        }
        result
    }

    fn matmul(&self, other: &MixingMatrix) -> MixingMatrix {
        assert_eq!(self.m, other.m);
        let m = self.m;
        let mut out = vec![0.0; m * m];
        for i in 0..m {
            for k in 0..m {
                let a = self.h[i * m + k];
                if a == 0.0 {
                    continue;
                }
                for j in 0..m {
                    out[i * m + j] += a * other.h[k * m + j];
                }
            }
        }
        MixingMatrix { m, h: out }
    }

    /// ζ = max{|λ₂(H)|, |λ_m(H)|} (Assumption 4.3). Power iteration on the
    /// deflated matrix H − (1/m)·11ᵀ; H symmetric ⇒ the dominant eigenvalue
    /// of the deflation is exactly ζ.
    pub fn zeta(&self) -> f64 {
        let m = self.m;
        if m == 1 {
            return 0.0;
        }
        // Deterministic pseudo-random start orthogonal to 1.
        let mut v: Vec<f64> = (0..m)
            .map(|i| (i as f64 * 0.754_877_666 + 0.1).sin())
            .collect();
        let mean: f64 = v.iter().sum::<f64>() / m as f64;
        for x in &mut v {
            *x -= mean;
        }
        let mut lambda = 0.0;
        for _ in 0..5_000 {
            // w = (H - A) v  =  H v - mean(v) (v already centered each iter)
            let mut w = vec![0.0; m];
            for i in 0..m {
                let mut s = 0.0;
                for j in 0..m {
                    s += self.h[i * m + j] * v[j];
                }
                w[i] = s;
            }
            let wmean: f64 = w.iter().sum::<f64>() / m as f64;
            for x in &mut w {
                *x -= wmean;
            }
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return 0.0; // deflated matrix is (numerically) zero: ζ = 0
            }
            let new_lambda = norm
                / v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
            for (x, wi) in v.iter_mut().zip(&w) {
                *x = wi / norm;
            }
            if (new_lambda - lambda).abs() < 1e-13 {
                return new_lambda;
            }
            lambda = new_lambda;
        }
        lambda
    }

    /// Ω₁ = ζ^{2π} / (1 − ζ^{2π})  (Eq. 15). Infinite when ζ^π → 1.
    pub fn omega1(&self, pi: u32) -> f64 {
        let z = self.zeta().powi(2 * pi as i32);
        if z >= 1.0 {
            f64::INFINITY
        } else {
            z / (1.0 - z)
        }
    }

    /// Ω₂ = 1/(1−ζ^{2π}) + 2/(1−ζ^π) + ζ^π/(1−ζ^π)²  (Eq. 15).
    pub fn omega2(&self, pi: u32) -> f64 {
        let zp = self.zeta().powi(pi as i32);
        let z2p = zp * zp;
        if zp >= 1.0 {
            return f64::INFINITY;
        }
        1.0 / (1.0 - z2p) + 2.0 / (1.0 - zp) + zp / ((1.0 - zp) * (1.0 - zp))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn metropolis_is_doubly_stochastic_on_every_builder() {
        let rng = crate::util::rng::Rng::new(3);
        for g in [
            Graph::ring(8).unwrap(),
            Graph::complete(6).unwrap(),
            Graph::star(7).unwrap(),
            Graph::line(5).unwrap(),
            Graph::erdos_renyi(10, 0.4, &rng).unwrap(),
        ] {
            MixingMatrix::metropolis(&g).validate().unwrap();
        }
    }

    #[test]
    fn metropolis_respects_sparsity() {
        let g = Graph::ring(6).unwrap();
        let h = MixingMatrix::metropolis(&g);
        // H[i][j] > 0 iff (i,j) in E or i == j (Assumption 4.1).
        for i in 0..6 {
            for j in 0..6 {
                let connected = g.neighbors(i).contains(&j) || i == j;
                assert_eq!(h.get(i, j) > 0.0, connected, "({i},{j})");
            }
        }
    }

    #[test]
    fn uniform_zeta_is_zero_complete_near_zero() {
        assert_close(MixingMatrix::uniform(8).zeta(), 0.0, 1e-9);
        // Metropolis on complete graph: H = (1/m)(11ᵀ) exactly, so ζ=0.
        let g = Graph::complete(8).unwrap();
        let z = MixingMatrix::metropolis(&g).zeta();
        assert_close(z, 0.0, 1e-9);
    }

    #[test]
    fn identity_zeta_is_one() {
        assert_close(MixingMatrix::identity(5).zeta(), 1.0, 1e-9);
    }

    #[test]
    fn ring_zeta_closed_form() {
        // Metropolis ring (m>=3): every degree is 2 so off-diagonal weights
        // are 1/3 and diagonal 1/3: H = I/3 + C/3 + Cᵀ/3 with C the cyclic
        // shift. Eigenvalues: (1 + 2cos(2πk/m))/3 ⇒
        // ζ = max_k |(1+2cos(2πk/m))/3| for k != 0.
        for m in [4usize, 5, 8, 12] {
            let g = Graph::ring(m).unwrap();
            let z = MixingMatrix::metropolis(&g).zeta();
            let expect = (1..m)
                .map(|k| {
                    ((1.0 + 2.0 * (2.0 * std::f64::consts::PI * k as f64 / m as f64).cos())
                        / 3.0)
                        .abs()
                })
                .fold(0.0f64, f64::max);
            assert_close(z, expect, 1e-6);
        }
    }

    #[test]
    fn better_connectivity_smaller_zeta() {
        // Theorem 1's topology ordering (Fig. 6): complete < ER(0.6) <
        // ER(0.2)-ish < ring < line for large-ish m.
        let rng = crate::util::rng::Rng::new(1);
        let z_complete = MixingMatrix::metropolis(&Graph::complete(16).unwrap()).zeta();
        let z_er6 =
            MixingMatrix::metropolis(&Graph::erdos_renyi(16, 0.6, &rng).unwrap()).zeta();
        let z_ring = MixingMatrix::metropolis(&Graph::ring(16).unwrap()).zeta();
        let z_line = MixingMatrix::metropolis(&Graph::line(16).unwrap()).zeta();
        assert!(z_complete < z_er6, "{z_complete} {z_er6}");
        assert!(z_er6 < z_ring, "{z_er6} {z_ring}");
        assert!(z_ring < z_line, "{z_ring} {z_line}");
        assert!(z_line < 1.0);
    }

    #[test]
    fn power_matches_repeated_matmul() {
        let g = Graph::ring(5).unwrap();
        let h = MixingMatrix::metropolis(&g);
        let h3 = h.power(3);
        let manual = h.matmul(&h).matmul(&h);
        for i in 0..5 {
            for j in 0..5 {
                assert_close(h3.get(i, j), manual.get(i, j), 1e-12);
            }
        }
        // H^0 = I
        let h0 = h.power(0);
        for i in 0..5 {
            for j in 0..5 {
                assert_close(h0.get(i, j), if i == j { 1.0 } else { 0.0 }, 1e-15);
            }
        }
    }

    #[test]
    fn power_stays_doubly_stochastic_and_contracts() {
        let g = Graph::ring(8).unwrap();
        let h = MixingMatrix::metropolis(&g);
        let h10 = h.power(10);
        h10.validate().unwrap();
        // H^π → (1/m)11ᵀ: entries approach 1/8.
        let max_dev = (0..64)
            .map(|k| (h10.entries()[k] - 1.0 / 8.0).abs())
            .fold(0.0f64, f64::max);
        assert!(max_dev < h.zeta().powi(10) + 1e-9, "dev {max_dev}");
    }

    #[test]
    fn omegas_match_formula_and_ordering() {
        let g = Graph::ring(8).unwrap();
        let h = MixingMatrix::metropolis(&g);
        let z = h.zeta();
        let pi = 10u32;
        let zp = z.powi(pi as i32);
        let z2p = zp * zp;
        assert_close(h.omega1(pi), z2p / (1.0 - z2p), 1e-9);
        assert_close(
            h.omega2(pi),
            1.0 / (1.0 - z2p) + 2.0 / (1.0 - zp) + zp / (1.0 - zp).powi(2),
            1e-9,
        );
        // More gossip steps ⇒ smaller Ω₁ (faster consensus).
        assert!(h.omega1(20) < h.omega1(5));
        // Identity (no mixing): Ω infinite.
        assert!(MixingMatrix::identity(4).omega1(1).is_infinite());
    }

    #[test]
    fn from_rows_validates() {
        assert!(MixingMatrix::from_rows(2, vec![0.5, 0.5, 0.5, 0.5]).is_ok());
        assert!(MixingMatrix::from_rows(2, vec![0.9, 0.1, 0.5, 0.5]).is_err()); // asym
        assert!(MixingMatrix::from_rows(2, vec![1.5, -0.5, -0.5, 1.5]).is_err()); // neg
        assert!(MixingMatrix::from_rows(2, vec![1.0]).is_err()); // size
    }
}
