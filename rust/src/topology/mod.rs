//! Edge-backhaul topology: the undirected connected graph G = (V, E) over
//! which edge servers cooperate (paper §3).
//!
//! Builders cover every topology the paper evaluates: the default ring
//! (§6.1), the complete graph (the Hier-FAvg limit, §4.3), Erdős–Rényi
//! random graphs with edge probability p (Fig. 6), plus star and line used
//! in tests. [`mixing`] derives the doubly-stochastic gossip matrix **H**
//! and its spectral quantities (ζ, Ω₁, Ω₂).

pub mod mixing;

pub use mixing::MixingMatrix;

use crate::error::{CfelError, Result};
use crate::util::rng::Rng;

/// An undirected graph over `m` edge servers, stored as an adjacency list.
#[derive(Debug, Clone)]
pub struct Graph {
    m: usize,
    adj: Vec<Vec<usize>>,
    name: String,
}

impl Graph {
    /// Build from an explicit (deduplicated) undirected edge list.
    pub fn from_edges(m: usize, edges: &[(usize, usize)], name: &str) -> Result<Graph> {
        if m == 0 {
            return Err(CfelError::Topology("graph needs at least one node".into()));
        }
        let mut adj = vec![Vec::new(); m];
        for &(a, b) in edges {
            if a >= m || b >= m {
                return Err(CfelError::Topology(format!(
                    "edge ({a},{b}) out of range for m={m}"
                )));
            }
            if a == b {
                continue; // self-loops are implicit in the mixing matrix
            }
            if !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        }
        for list in &mut adj {
            list.sort_unstable();
        }
        Ok(Graph { m, adj, name: name.to_string() })
    }

    /// Ring topology (the paper's default backhaul, §6.1).
    pub fn ring(m: usize) -> Result<Graph> {
        if m == 1 {
            return Graph::from_edges(1, &[], "ring");
        }
        if m == 2 {
            return Graph::from_edges(2, &[(0, 1)], "ring");
        }
        let edges: Vec<_> = (0..m).map(|i| (i, (i + 1) % m)).collect();
        Graph::from_edges(m, &edges, "ring")
    }

    /// Complete graph (ζ = 0 with uniform weights; the Hier-FAvg limit).
    pub fn complete(m: usize) -> Result<Graph> {
        let mut edges = Vec::new();
        for i in 0..m {
            for j in (i + 1)..m {
                edges.push((i, j));
            }
        }
        Graph::from_edges(m, &edges, "complete")
    }

    /// Star topology: node 0 is the hub (models a central coordinator).
    pub fn star(m: usize) -> Result<Graph> {
        let edges: Vec<_> = (1..m).map(|i| (0, i)).collect();
        Graph::from_edges(m, &edges, "star")
    }

    /// Line (path) topology — worst connectivity among connected graphs.
    pub fn line(m: usize) -> Result<Graph> {
        let edges: Vec<_> = (1..m).map(|i| (i - 1, i)).collect();
        Graph::from_edges(m, &edges, "line")
    }

    /// Erdős–Rényi G(m, p) conditioned on connectivity (Fig. 6): edges are
    /// re-drawn (new seed stream) until the sample is connected, matching
    /// the paper's "generate random topologies" procedure.
    pub fn erdos_renyi(m: usize, p: f64, rng: &Rng) -> Result<Graph> {
        if !(0.0..=1.0).contains(&p) {
            return Err(CfelError::Topology(format!("p={p} outside [0,1]")));
        }
        for attempt in 0..10_000u64 {
            let mut r = rng.split(attempt);
            let mut edges = Vec::new();
            for i in 0..m {
                for j in (i + 1)..m {
                    if (r.f64()) < p {
                        edges.push((i, j));
                    }
                }
            }
            let g = Graph::from_edges(m, &edges, &format!("erdos_renyi(p={p})"))?;
            if g.is_connected() {
                return Ok(g);
            }
        }
        Err(CfelError::Topology(format!(
            "could not sample a connected G({m},{p}) in 10k attempts"
        )))
    }

    /// Build by name — used by configs/CLI: "ring" | "complete" | "star" |
    /// "line" | "er:<p>".
    pub fn by_name(kind: &str, m: usize, rng: &Rng) -> Result<Graph> {
        match kind {
            "ring" => Graph::ring(m),
            "complete" => Graph::complete(m),
            "star" => Graph::star(m),
            "line" => Graph::line(m),
            _ => {
                if let Some(p) = kind.strip_prefix("er:") {
                    let p: f64 = p.parse().map_err(|_| {
                        CfelError::Topology(format!("bad ER probability {p:?}"))
                    })?;
                    Graph::erdos_renyi(m, p, rng)
                } else {
                    Err(CfelError::Topology(format!("unknown topology {kind:?}")))
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Neighbors N_i of server i.
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// BFS connectivity check (Assumption 4 requires a connected graph).
    pub fn is_connected(&self) -> bool {
        if self.m == 0 {
            return false;
        }
        let mut seen = vec![false; self.m];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.m
    }

    /// Remove a node (fault injection for Table 1): returns the induced
    /// subgraph on the surviving nodes with indices remapped to 0..m-1,
    /// plus the old->new index map.
    pub fn remove_node(&self, victim: usize) -> Result<(Graph, Vec<Option<usize>>)> {
        if victim >= self.m {
            return Err(CfelError::Topology(format!("no node {victim}")));
        }
        if self.m == 1 {
            return Err(CfelError::Topology("cannot remove the only node".into()));
        }
        let mut map = vec![None; self.m];
        let mut next = 0;
        for i in 0..self.m {
            if i != victim {
                map[i] = Some(next);
                next += 1;
            }
        }
        let mut edges = Vec::new();
        for i in 0..self.m {
            if i == victim {
                continue;
            }
            for &j in &self.adj[i] {
                if j != victim && i < j {
                    edges.push((map[i].unwrap(), map[j].unwrap()));
                }
            }
        }
        let g = Graph::from_edges(self.m - 1, &edges, &format!("{}-minus{victim}", self.name))?;
        Ok((g, map))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let g = Graph::ring(8).unwrap();
        assert_eq!(g.len(), 8);
        assert_eq!(g.edge_count(), 8);
        for i in 0..8 {
            assert_eq!(g.degree(i), 2);
        }
        assert_eq!(g.neighbors(0), &[1, 7]);
        assert!(g.is_connected());
    }

    #[test]
    fn tiny_rings() {
        assert_eq!(Graph::ring(1).unwrap().edge_count(), 0);
        assert_eq!(Graph::ring(2).unwrap().edge_count(), 1);
        assert_eq!(Graph::ring(3).unwrap().edge_count(), 3);
    }

    #[test]
    fn complete_structure() {
        let g = Graph::complete(5).unwrap();
        assert_eq!(g.edge_count(), 10);
        for i in 0..5 {
            assert_eq!(g.degree(i), 4);
        }
    }

    #[test]
    fn star_and_line() {
        let s = Graph::star(6).unwrap();
        assert_eq!(s.degree(0), 5);
        assert!(s.is_connected());
        let l = Graph::line(4).unwrap();
        assert_eq!(l.degree(0), 1);
        assert_eq!(l.degree(1), 2);
        assert!(l.is_connected());
    }

    #[test]
    fn er_respects_p_and_connectivity() {
        let rng = Rng::new(1);
        let g = Graph::erdos_renyi(16, 0.4, &rng).unwrap();
        assert!(g.is_connected());
        // Higher p ⇒ denser (statistical, but overwhelming at these sizes).
        let dense = Graph::erdos_renyi(16, 0.9, &rng).unwrap();
        assert!(dense.edge_count() > g.edge_count());
        // p=1 is complete.
        let full = Graph::erdos_renyi(8, 1.0, &rng).unwrap();
        assert_eq!(full.edge_count(), 28);
    }

    #[test]
    fn er_deterministic_for_seed() {
        let a = Graph::erdos_renyi(12, 0.3, &Rng::new(9)).unwrap();
        let b = Graph::erdos_renyi(12, 0.3, &Rng::new(9)).unwrap();
        for i in 0..12 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
    }

    #[test]
    fn by_name_dispatch() {
        let rng = Rng::new(0);
        assert_eq!(Graph::by_name("ring", 4, &rng).unwrap().name(), "ring");
        assert!(Graph::by_name("er:0.5", 8, &rng).unwrap().is_connected());
        assert!(Graph::by_name("nope", 4, &rng).is_err());
        assert!(Graph::by_name("er:2.0", 4, &rng).is_err());
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)], "two-pairs").unwrap();
        assert!(!g.is_connected());
    }

    #[test]
    fn edge_validation() {
        assert!(Graph::from_edges(3, &[(0, 5)], "bad").is_err());
        assert!(Graph::from_edges(0, &[], "empty").is_err());
        // duplicate + self-loop tolerated
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (2, 2)], "dups").unwrap();
        assert_eq!(g.edge_count(), 1);
    }

    #[test]
    fn remove_node_remaps() {
        let g = Graph::ring(4).unwrap(); // 0-1-2-3-0
        let (h, map) = g.remove_node(2).unwrap();
        assert_eq!(h.len(), 3);
        assert_eq!(map[0], Some(0));
        assert_eq!(map[2], None);
        assert_eq!(map[3], Some(2));
        // Ring minus a node = path: still connected.
        assert!(h.is_connected());
        assert_eq!(h.edge_count(), 2);
    }

    #[test]
    fn remove_hub_disconnects_star() {
        let s = Graph::star(5).unwrap();
        let (h, _) = s.remove_node(0).unwrap();
        assert!(!h.is_connected()); // the Table 1 fault-tolerance scenario
    }
}
