//! Deterministic Bonawitz-style pairwise-masking secure aggregation.
//!
//! Devices upload fixed-point-encoded, additively masked updates; the edge
//! sums them under wrapping `u64` arithmetic and never sees an individual
//! model. Every unordered device pair `(lo, hi)` of a phase's participant
//! set shares a PRG stream derived from the run's root seed
//! (`root.split(0x5ECA_6600).split(phase).split(lo).split(hi)`): `lo` adds
//! the stream to its upload, `hi` subtracts it, so the pair contributes
//! exactly zero to the sum. Devices dropped by the close policy leave
//! dangling shares in the survivors' uploads; the aggregator reconstructs
//! those shares from the same seeds ([`recover_dropouts`]) so the unmasked
//! sum equals the plain weighted sum over the survivors, bit for bit.
//!
//! The simulator trusts itself with the seeds (both "ends" live in one
//! address space), so there is no key agreement or Shamir recovery phase —
//! what is modelled faithfully is the *arithmetic* (masks cancel exactly,
//! dropout recovery is exact) and the *cost* (mask generation compute and
//! message inflation, charged by `netsim`). Determinism: masks are pure
//! functions of `(seed, phase, device pair)`, and wrapping addition is
//! associative and commutative, so the aggregate is independent of thread
//! count and summation order (docs/DETERMINISM.md).
//!
//! ## Fixed-point encoding
//!
//! With `mask:<bits>`, parameter `x` is clamped to ±[`CLIP`] and encoded as
//! `q = round(x · 2^bits)`; a device of sample weight `n` uploads
//! `n · q mod 2^64` per parameter (plus masks). Decoding divides the summed
//! words by `2^bits · Σn`, so the result differs from the exact clamped
//! weighted mean by at most `2^-(bits+1)` per parameter (each device's
//! rounding error is ≤ n/2 words) plus one f32 rounding step. Overflow
//! headroom requires `bits + 6 + ceil(log2 Σn) ≤ 62` (|q| ≤ 2^(bits+6)),
//! validated at coordinator construction.
//!
//! In `lossless` mode the raw f32 bit patterns ride the masked channel and
//! are unmasked back verbatim ([`lossless_roundtrip`]) — a degenerate mode
//! pinning that masking alone cannot perturb a single bit of history.

use crate::util::rng::Rng;

/// RNG stream label for pairwise mask seeds (docs/DETERMINISM.md §3).
pub const SECAGG_STREAM: u64 = 0x5ECA_6600;

/// Fixed-point clip range: parameters are clamped to ±CLIP before
/// quantization. Model weights in this codebase live well inside ±64.
pub const CLIP: f64 = 64.0;

/// Largest supported `mask:<bits>` precision. 46 + 6 clip bits + 10 bits
/// of weight headroom stays within the 62-bit overflow budget for any
/// cluster of ≤ 1024 total samples; larger fleets need fewer bits, which
/// the coordinator's headroom check enforces per run.
pub const MAX_BITS: u32 = 46;

/// `2^bits` as f64 — the fixed-point scale factor.
pub fn scale(bits: u32) -> f64 {
    (1u64 << bits) as f64
}

/// A cluster phase's aggregated-but-encoded upload: the wrapping sum of
/// the survivors' masked words (dangling dropout shares already removed)
/// and the survivors' total sample weight. [`decode_sum`] turns it back
/// into a plain model.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskedSum {
    /// One wrapping-u64 accumulator per model parameter.
    pub words: Vec<u64>,
    /// Σ n_i over the surviving (on-time) devices.
    pub total_weight: u64,
}

/// The shared PRG for the unordered pair `{a, b}` in `phase`, derived from
/// the run's root RNG. Symmetric in its device arguments.
fn pair_stream(root: &Rng, phase: u64, a: usize, b: usize) -> Rng {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    root.split(SECAGG_STREAM)
        .split(phase)
        .split(lo as u64)
        .split(hi as u64)
}

/// Apply (or, with `apply = false`, remove) device `i`'s mask share toward
/// its pair with `j`: the lower-numbered device adds the pair's PRG words,
/// the higher-numbered one subtracts them, so `i`'s and `j`'s shares cancel
/// in any wrapping sum containing both.
pub fn mask_share(words: &mut [u64], root: &Rng, phase: u64, i: usize, j: usize, apply: bool) {
    debug_assert_ne!(i, j, "a device has no mask pair with itself");
    let mut prg = pair_stream(root, phase, i, j);
    let positive = (i < j) == apply;
    for w in words.iter_mut() {
        let m = prg.next_u64();
        *w = if positive { w.wrapping_add(m) } else { w.wrapping_sub(m) };
    }
}

/// Fixed-point encode a model with sample weight `weight`:
/// `word[k] = (round(clamp(x_k) · 2^bits) as i64 as u64) · weight mod 2^64`.
pub fn encode_weighted(params: &[f32], bits: u32, weight: u64) -> Vec<u64> {
    let s = scale(bits);
    params
        .iter()
        .map(|&x| {
            let q = ((x as f64).clamp(-CLIP, CLIP) * s).round() as i64;
            (q as u64).wrapping_mul(weight)
        })
        .collect()
}

/// One device's complete upload: its weighted fixed-point encoding plus its
/// mask shares toward every other participant of the phase.
pub fn masked_upload(
    params: &[f32],
    bits: u32,
    weight: u64,
    root: &Rng,
    phase: u64,
    device: usize,
    participants: &[usize],
) -> Vec<u64> {
    let mut words = encode_weighted(params, bits, weight);
    for &j in participants {
        if j != device {
            mask_share(&mut words, root, phase, device, j, true);
        }
    }
    words
}

/// Wrapping elementwise accumulation of one upload into the running sum.
/// The accumulator adopts the upload's length on first use.
pub fn accumulate(acc: &mut Vec<u64>, upload: &[u64]) {
    if acc.is_empty() {
        acc.resize(upload.len(), 0);
    }
    debug_assert_eq!(acc.len(), upload.len(), "uploads must agree on model size");
    for (a, u) in acc.iter_mut().zip(upload) {
        *a = a.wrapping_add(*u);
    }
}

/// Deterministic dropout recovery: every survivor `i` carries a dangling
/// share toward each dropped device `j` (whose own upload never arrived).
/// Re-derive those shares from the seeds and remove them, leaving the sum
/// equal to the plain weighted encoded sum over the survivors alone.
pub fn recover_dropouts(
    words: &mut [u64],
    root: &Rng,
    phase: u64,
    survivors: &[usize],
    dropped: &[usize],
) {
    for &i in survivors {
        for &j in dropped {
            mask_share(words, root, phase, i, j, false);
        }
    }
}

/// Decode an unmasked sum back to a plain model: reinterpret each word as
/// two's-complement and divide by `2^bits · total_weight`. With a zero
/// total weight there is nothing to average; callers keep the previous
/// model instead (mirroring the plain path's empty-cluster skip).
pub fn decode_sum(sum: &MaskedSum, bits: u32) -> Vec<f32> {
    debug_assert!(sum.total_weight > 0, "decode_sum needs survivors");
    let denom = scale(bits) * sum.total_weight as f64;
    sum.words
        .iter()
        .map(|&w| ((w as i64) as f64 / denom) as f32)
        .collect()
}

/// The `lossless` degenerate mode: the raw f32 bit patterns ride the masked
/// channel — mask with the device's shares over the participant set, then
/// immediately unmask with the identically re-derived shares. Exercises the
/// full mask machinery while returning every parameter bit-identically,
/// including NaN payloads, −0.0 and subnormals.
pub fn lossless_roundtrip(
    params: &mut [f32],
    root: &Rng,
    phase: u64,
    device: usize,
    participants: &[usize],
) {
    let mut words: Vec<u64> = params.iter().map(|&x| x.to_bits() as u64).collect();
    for &j in participants {
        if j != device {
            mask_share(&mut words, root, phase, device, j, true);
        }
    }
    for &j in participants {
        if j != device {
            mask_share(&mut words, root, phase, device, j, false);
        }
    }
    for (p, w) in params.iter_mut().zip(&words) {
        *p = f32::from_bits(*w as u32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest;

    fn root() -> Rng {
        Rng::new(0xC0FFEE)
    }

    /// Plain (mask-free) weighted encoded sum — the oracle the masked
    /// pipeline must match bit for bit.
    fn plain_sum(models: &[(usize, u64, Vec<f32>)], bits: u32) -> Vec<u64> {
        let mut acc = Vec::new();
        for (_, w, m) in models {
            accumulate(&mut acc, &encode_weighted(m, bits, *w));
        }
        acc
    }

    fn gen_models(rng: &mut Rng, n: usize, len: usize) -> Vec<(usize, u64, Vec<f32>)> {
        (0..n)
            .map(|d| {
                let w = 1 + rng.below(50) as u64;
                (d, w, proptest::vec_f32(rng, len))
            })
            .collect()
    }

    #[test]
    fn pair_shares_cancel_exactly() {
        let root = root();
        let mut a = vec![0u64; 16];
        let mut b = vec![0u64; 16];
        mask_share(&mut a, &root, 7, 3, 9, true);
        mask_share(&mut b, &root, 7, 9, 3, true);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.wrapping_add(*y), 0, "pair shares must sum to zero");
        }
    }

    #[test]
    fn masks_cancel_over_the_full_participant_set() {
        let root = root();
        let mut rng = Rng::new(11);
        let models = gen_models(&mut rng, 7, 33);
        let participants: Vec<usize> = models.iter().map(|(d, _, _)| *d).collect();
        let bits = 16;
        let mut masked = Vec::new();
        for (d, w, m) in &models {
            accumulate(
                &mut masked,
                &masked_upload(m, bits, *w, &root, 42, *d, &participants),
            );
        }
        assert_eq!(masked, plain_sum(&models, bits), "masks must cancel bitwise");
    }

    #[test]
    fn dropout_recovery_matches_the_survivor_only_sum() {
        let root = root();
        let mut rng = Rng::new(13);
        let models = gen_models(&mut rng, 9, 21);
        let participants: Vec<usize> = models.iter().map(|(d, _, _)| *d).collect();
        let bits = 20;
        let dropped = [2usize, 5, 8];
        let survivors: Vec<usize> = participants
            .iter()
            .copied()
            .filter(|d| !dropped.contains(d))
            .collect();
        // Every participant computed its upload over the FULL set, but the
        // dropped devices' uploads never arrive.
        let mut sum = Vec::new();
        for (d, w, m) in &models {
            if survivors.contains(d) {
                accumulate(
                    &mut sum,
                    &masked_upload(m, bits, *w, &root, 3, *d, &participants),
                );
            }
        }
        recover_dropouts(&mut sum, &root, 3, &survivors, &dropped);
        let expected = plain_sum(
            &models
                .iter()
                .filter(|(d, _, _)| survivors.contains(d))
                .cloned()
                .collect::<Vec<_>>(),
            bits,
        );
        assert_eq!(sum, expected, "dropout recovery must be exact");
    }

    #[test]
    fn decode_is_within_the_documented_quantization_bound() {
        let root = root();
        let mut rng = Rng::new(17);
        let models = gen_models(&mut rng, 5, 40);
        let participants: Vec<usize> = models.iter().map(|(d, _, _)| *d).collect();
        let bits = 24;
        let mut sum = MaskedSum { words: Vec::new(), total_weight: 0 };
        for (d, w, m) in &models {
            accumulate(
                &mut sum.words,
                &masked_upload(m, bits, *w, &root, 1, *d, &participants),
            );
            sum.total_weight += w;
        }
        let decoded = decode_sum(&sum, bits);
        let total = sum.total_weight as f64;
        for k in 0..decoded.len() {
            let exact: f64 = models
                .iter()
                .map(|(_, w, m)| *w as f64 * (m[k] as f64).clamp(-CLIP, CLIP))
                .sum::<f64>()
                / total;
            let bound = 0.5 / scale(bits) + (exact.abs() + 1.0) * f32::EPSILON as f64;
            assert!(
                (decoded[k] as f64 - exact).abs() <= bound,
                "param {k}: decoded {} vs exact {exact}",
                decoded[k]
            );
        }
    }

    #[test]
    fn lossless_roundtrip_preserves_exotic_bit_patterns() {
        let root = root();
        let original = vec![
            0.0f32,
            -0.0,
            1.0,
            -1.5,
            f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 64.0, // subnormal
            -f32::MIN_POSITIVE / 2.0, // negative subnormal
            f32::MAX,
            f32::MIN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(0x7FC0_1234), // NaN payload
        ];
        let mut params = original.clone();
        lossless_roundtrip(&mut params, &root, 5, 2, &[0, 2, 4, 7]);
        for (a, b) in original.iter().zip(&params) {
            assert_eq!(a.to_bits(), b.to_bits(), "lossless mode must be bit-exact");
        }
    }

    #[test]
    fn prop_encode_mask_unmask_decode_roundtrips() {
        // ISSUE satellite: fixed-point encode → mask → unmask → decode
        // round-trips models (incl. −0.0, subnormals, extreme magnitudes)
        // within the documented quantization bound.
        proptest::check("secagg-roundtrip", 0x5ECA66, proptest::default_cases(), |rng| {
            let root = Rng::new(rng.next_u64());
            let phase = rng.next_u64();
            let n = 2 + rng.below(6);
            let len = 1 + rng.below(48);
            let bits = 8 + rng.below((MAX_BITS - 8) as usize + 1) as u32;
            let exotics = [
                -0.0f32,
                f32::MIN_POSITIVE / 8.0,
                -f32::MIN_POSITIVE,
                1e30,
                -1e30,
                1e-30,
            ];
            let models: Vec<(usize, u64, Vec<f32>)> = (0..n)
                .map(|d| {
                    let w = 1 + rng.below(20) as u64;
                    let m: Vec<f32> = (0..len)
                        .map(|_| {
                            if rng.below(4) == 0 {
                                exotics[rng.below(exotics.len())]
                            } else {
                                rng.normal()
                            }
                        })
                        .collect();
                    (d, w, m)
                })
                .collect();
            let participants: Vec<usize> = models.iter().map(|(d, _, _)| *d).collect();
            let mut sum = MaskedSum { words: Vec::new(), total_weight: 0 };
            for (d, w, m) in &models {
                accumulate(
                    &mut sum.words,
                    &masked_upload(m, bits, *w, &root, phase, *d, &participants),
                );
                sum.total_weight += w;
            }
            let decoded = decode_sum(&sum, bits);
            let total = sum.total_weight as f64;
            for k in 0..len {
                let exact: f64 = models
                    .iter()
                    .map(|(_, w, m)| *w as f64 * (m[k] as f64).clamp(-CLIP, CLIP))
                    .sum::<f64>()
                    / total;
                let bound = 0.5 / scale(bits) + (exact.abs() + 1.0) * f32::EPSILON as f64;
                prop_assert!(
                    (decoded[k] as f64 - exact).abs() <= bound,
                    "param {k}: decoded {} vs exact {exact} (bits {bits})",
                    decoded[k]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_lossless_mode_is_exact() {
        proptest::check("secagg-lossless", 0x10551E55, proptest::default_cases(), |rng| {
            let root = Rng::new(rng.next_u64());
            let phase = rng.next_u64();
            let len = 1 + rng.below(64);
            let device = rng.below(10);
            let participants: Vec<usize> = (0..10).collect();
            let original: Vec<f32> = (0..len)
                .map(|_| match rng.below(8) {
                    0 => -0.0,
                    1 => f32::from_bits(rng.next_u64() as u32), // any pattern
                    2 => f32::MIN_POSITIVE / 16.0,
                    _ => rng.normal() * 1e3,
                })
                .collect();
            let mut params = original.clone();
            lossless_roundtrip(&mut params, &root, phase, device, &participants);
            for (a, b) in original.iter().zip(&params) {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "bit pattern changed: {:#x} -> {:#x}",
                    a.to_bits(),
                    b.to_bits()
                );
            }
            Ok(())
        });
    }
}
