//! Flat-vector aggregation primitives — the L3 hot path.
//!
//! Model/momentum state travels as flat `Vec<f32>`; the aggregation
//! operators here implement the paper's Eq. 6 (intra-cluster weighted
//! average) and Eq. 7 (gossip application of H^π) plus the consensus
//! diagnostics used by tests and EXPERIMENTS.md. All operators are
//! allocation-free on the hot path (callers pass output buffers or use the
//! in-place variants); `components` bench tracks their throughput.

pub mod policy;

use crate::error::{CfelError, Result};
use crate::topology::MixingMatrix;

/// out = Σ_r weights[r] · rows[r]; `weights` need not be normalised —
/// pass normalised sample fractions for Eq. 6.
///
/// An empty participant set is a runtime condition, not a programming
/// error — fault injection or a tight reporting deadline can drop every
/// device of a cluster — so it returns [`CfelError::Aggregation`] rather
/// than panicking (callers either propagate or skip the cluster).
pub fn weighted_average_into(rows: &[&[f32]], weights: &[f64], out: &mut [f32]) -> Result<()> {
    assert_eq!(rows.len(), weights.len());
    if rows.is_empty() {
        return Err(CfelError::Aggregation(
            "weighted average over an empty participant set".into(),
        ));
    }
    let d = out.len();
    for r in rows {
        assert_eq!(r.len(), d, "row length mismatch");
    }
    out.fill(0.0);
    for (row, &w) in rows.iter().zip(weights) {
        let w = w as f32;
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += w * v;
        }
    }
    Ok(())
}

/// Allocating convenience wrapper for tests and cold paths.
pub fn weighted_average(rows: &[&[f32]], weights: &[f64]) -> Result<Vec<f32>> {
    let mut out = vec![0.0; rows.first().map_or(0, |r| r.len())];
    weighted_average_into(rows, weights, &mut out)?;
    Ok(out)
}

/// Uniform average.
pub fn mean(rows: &[&[f32]]) -> Result<Vec<f32>> {
    let w = vec![1.0 / rows.len().max(1) as f64; rows.len()];
    weighted_average(rows, &w)
}

/// y += a * x (the SGD apply / momentum update primitive).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Gossip application (Eq. 7): given the stacked edge models `models[i]`
/// and the (already powered) mixing matrix W = H^π, compute
/// `out[i] = Σ_j W[j][i] · models[j]` for every cluster i.
///
/// `scratch` must be `m * d` long; the result is written back into
/// `models` so callers keep a single buffer per cluster.
pub fn gossip_mix(models: &mut [Vec<f32>], h_pi: &MixingMatrix, scratch: &mut Vec<f32>) {
    let m = models.len();
    assert_eq!(h_pi.len(), m);
    if m == 0 {
        return;
    }
    let d = models[0].len();
    for mo in models.iter() {
        assert_eq!(mo.len(), d);
    }
    scratch.clear();
    scratch.resize(m * d, 0.0);
    for j in 0..m {
        let src = &models[j];
        for i in 0..m {
            let w = h_pi.get(j, i) as f32;
            if w == 0.0 {
                continue;
            }
            let dst = &mut scratch[i * d..(i + 1) * d];
            for (o, &v) in dst.iter_mut().zip(src.iter()) {
                *o += w * v;
            }
        }
    }
    for (i, mo) in models.iter_mut().enumerate() {
        mo.copy_from_slice(&scratch[i * d..(i + 1) * d]);
    }
}

/// Mean squared consensus distance: (1/m) Σ_i ‖x_i − x̄‖² — the residual
/// error tracked by Lemmas 2–3 and reported by the figure harnesses.
/// Borrow-based: callers pass row views, never cloned models.
pub fn consensus_distance_refs(models: &[&[f32]]) -> f64 {
    let m = models.len();
    if m <= 1 {
        return 0.0;
    }
    let d = models[0].len();
    let mut meanv = vec![0.0f64; d];
    for mo in models {
        for (acc, &v) in meanv.iter_mut().zip(mo.iter()) {
            *acc += v as f64;
        }
    }
    for v in &mut meanv {
        *v /= m as f64;
    }
    let mut total = 0.0;
    for mo in models {
        for (&mu, &v) in meanv.iter().zip(mo.iter()) {
            let dlt = v as f64 - mu;
            total += dlt * dlt;
        }
    }
    total / m as f64
}

/// Owned-vector convenience wrapper around [`consensus_distance_refs`].
pub fn consensus_distance(models: &[Vec<f32>]) -> f64 {
    let rows: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    consensus_distance_refs(&rows)
}

/// Normalized merge weights for a staleness-discounted Eq. 6 aggregate:
/// `w_i = n_i · d_i / Σ_j n_j · d_j` over sample counts `n` and positive
/// staleness discounts `d` (on-time reports pass `d = 1`).
///
/// With all discounts exactly `1.0` this reproduces the plain Eq. 6
/// weights bit for bit: `n as f64 * 1.0` is exact, and the f64 sum of
/// integer-valued terms equals the integer total exactly — the property
/// the semi-sync oracle-equivalence suite pins. The weights of any merged
/// aggregate always sum to 1 (up to one final rounding), which
/// `rust/tests/proptest_invariants.rs` checks over random inputs.
pub fn report_weights(n_samples: &[usize], discounts: &[f64]) -> Result<Vec<f64>> {
    assert_eq!(n_samples.len(), discounts.len());
    let total: f64 = n_samples
        .iter()
        .zip(discounts)
        .map(|(&n, &d)| n as f64 * d)
        .sum();
    if !(total > 0.0 && total.is_finite()) {
        return Err(CfelError::Aggregation(
            "staleness-weighted aggregation over an empty participant set".into(),
        ));
    }
    Ok(n_samples
        .iter()
        .zip(discounts)
        .map(|(&n, &d)| n as f64 * d / total)
        .collect())
}

/// Size-weighted global average of cluster models into a caller-provided
/// buffer — the delta-free cloud-aggregation hot path (borrowed rows in,
/// scratch out; a round never clones the per-cluster weights it only
/// reads). Same weight arithmetic and accumulation order as
/// [`global_average`], bit for bit.
pub fn global_average_into(
    models: &[&[f32]],
    cluster_sizes: &[usize],
    out: &mut [f32],
) -> Result<()> {
    let n: usize = cluster_sizes.iter().sum();
    if n == 0 {
        return Err(CfelError::Aggregation(
            "global average over zero total samples".into(),
        ));
    }
    let weights: Vec<f64> = cluster_sizes.iter().map(|&s| s as f64 / n as f64).collect();
    weighted_average_into(models, &weights, out)
}

/// Size-weighted global average of cluster models — the quantity u_t whose
/// invariance under gossip (Eq. 12) the property tests pin down.
/// Allocating wrapper around [`global_average_into`].
pub fn global_average(models: &[Vec<f32>], cluster_sizes: &[usize]) -> Result<Vec<f32>> {
    let rows: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let mut out = vec![0.0; rows.first().map_or(0, |r| r.len())];
    global_average_into(&rows, cluster_sizes, &mut out)?;
    Ok(out)
}

/// L2 distance between two flat vectors (test/diagnostic helper).
pub fn l2_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Graph;

    #[test]
    fn weighted_average_basic() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let out = weighted_average(&[&a, &b], &[0.25, 0.75]).unwrap();
        assert_eq!(out, vec![2.5, 5.0]);
    }

    #[test]
    fn mean_of_identical_is_identity() {
        let a = [1.5f32, -2.0, 0.0];
        let out = mean(&[&a, &a, &a]).unwrap();
        assert_eq!(out, a.to_vec());
    }

    #[test]
    fn empty_participant_set_is_an_error_not_a_panic() {
        // Regression: reachable when fault injection or a tight reporting
        // deadline drops every device in a cluster.
        assert!(matches!(
            weighted_average(&[], &[]),
            Err(crate::error::CfelError::Aggregation(_))
        ));
        let mut out = vec![1.0f32; 3];
        assert!(weighted_average_into(&[], &[], &mut out).is_err());
        assert_eq!(out, vec![1.0; 3], "output untouched on error");
        assert!(mean(&[]).is_err());
        assert!(global_average(&[], &[]).is_err());
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0f32, 2.0];
        axpy(&mut y, -0.5, &[2.0, 4.0]);
        assert_eq!(y, vec![0.0, 0.0]);
    }

    #[test]
    fn gossip_identity_is_noop() {
        let mut models = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let orig = models.clone();
        let mut scratch = Vec::new();
        gossip_mix(&mut models, &MixingMatrix::identity(2), &mut scratch);
        assert_eq!(models, orig);
    }

    #[test]
    fn gossip_uniform_averages() {
        let mut models = vec![vec![0.0f32, 4.0], vec![2.0, 0.0]];
        let mut scratch = Vec::new();
        gossip_mix(&mut models, &MixingMatrix::uniform(2), &mut scratch);
        assert_eq!(models[0], vec![1.0, 2.0]);
        assert_eq!(models[1], vec![1.0, 2.0]);
    }

    #[test]
    fn gossip_preserves_uniform_global_average() {
        // Eq. 12: doubly-stochastic mixing keeps the (equal-size) average.
        let g = Graph::ring(5).unwrap();
        let h = MixingMatrix::metropolis(&g).power(3);
        let mut models: Vec<Vec<f32>> = (0..5)
            .map(|i| (0..7).map(|j| (i * 7 + j) as f32).collect())
            .collect();
        let before = global_average(&models, &[1; 5]).unwrap();
        let mut scratch = Vec::new();
        gossip_mix(&mut models, &h, &mut scratch);
        let after = global_average(&models, &[1; 5]).unwrap();
        assert!(l2_distance(&before, &after) < 1e-4);
    }

    #[test]
    fn gossip_contracts_consensus_distance() {
        let g = Graph::ring(8).unwrap();
        let h = MixingMatrix::metropolis(&g);
        let mut models: Vec<Vec<f32>> = (0..8)
            .map(|i| vec![i as f32; 16])
            .collect();
        let mut scratch = Vec::new();
        let initial = consensus_distance(&models);
        let mut prev = initial;
        for _ in 0..5 {
            gossip_mix(&mut models, &h, &mut scratch);
            let cur = consensus_distance(&models);
            assert!(cur < prev + 1e-12, "{cur} !< {prev}");
            prev = cur;
        }
        // Contraction rate is governed by ζ²(ring_8) ≈ 0.771 per step.
        assert!(prev < initial * 0.5, "prev {prev} initial {initial}");
    }

    #[test]
    fn consensus_distance_zero_iff_equal() {
        let models = vec![vec![1.0f32, 2.0], vec![1.0, 2.0]];
        assert_eq!(consensus_distance(&models), 0.0);
        let models2 = vec![vec![1.0f32], vec![3.0]];
        assert!((consensus_distance(&models2) - 1.0).abs() < 1e-12); // var around mean 2
    }

    #[test]
    fn global_average_respects_sizes() {
        let models = vec![vec![0.0f32], vec![10.0]];
        let avg = global_average(&models, &[9, 1]).unwrap();
        assert!((avg[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn l2_distance_basic() {
        assert!((l2_distance(&[0.0, 3.0], &[4.0, 0.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn report_weights_match_plain_eq6_with_unit_discounts() {
        let w = report_weights(&[30, 10], &[1.0, 1.0]).unwrap();
        // Bit-identical to n_i as f64 / total as f64 — the oracle property.
        assert_eq!(w[0].to_bits(), (30.0f64 / 40.0).to_bits());
        assert_eq!(w[1].to_bits(), (10.0f64 / 40.0).to_bits());
    }

    #[test]
    fn report_weights_discount_stale_reports_and_sum_to_one() {
        // A report two phases stale at exponent 1 counts 1/3 as much.
        let w = report_weights(&[10, 10], &[1.0, 1.0 / 3.0]).unwrap();
        assert!((w[0] - 0.75).abs() < 1e-12);
        assert!((w[1] - 0.25).abs() < 1e-12);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(report_weights(&[], &[]).is_err());
    }
}
