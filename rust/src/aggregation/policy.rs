//! Edge-round close policies — when an edge server stops waiting.
//!
//! CE-FedAvg as written closes every edge round with a full barrier: the
//! Eq. 6 average waits for the slowest surviving device, so one straggler
//! stalls the whole cluster even though the event engine knows every
//! device's report time. [`AggregationPolicy`] abstracts the *close
//! condition* of an edge phase so the coordinator can trade that barrier
//! for latency:
//!
//! * [`FullBarrier`] — wait for every report (the paper's semantics and
//!   the equivalence oracle for the other two policies).
//! * [`DeadlineDrop`] — close at `min(deadline, latest report)` and drop
//!   late devices from Eq. 6 entirely (the `--deadline` policy; survivor
//!   weights renormalize).
//! * [`SemiSync`] — close at the K-th report (or a timeout), merge the
//!   on-time reports via Eq. 6, and fold late-but-arriving reports into a
//!   *later* phase's aggregate with a FedBuff-style polynomial staleness
//!   discount `1/(1+s)^a`, where `s` counts edge phases elapsed since the
//!   report's origin phase. Nothing is discarded; stragglers just count
//!   for less the longer they lag.
//!
//! The policy is consulted by the discrete-event simulator
//! (`netsim::event`): it may schedule one `RoundClose` timeout event and
//! decides, per `UploadDone`, whether the phase closes. Reports that miss
//! the close are classified by [`AggregationPolicy::late_verdict`] as
//! either [`ReportVerdict::Dropped`] (deadline-drop) or
//! [`ReportVerdict::Late`] (semi-sync: kept, merged stale). All policy
//! decisions are pure functions of simulated report times, which are
//! derived from the experiment seed alone — so every policy is
//! bit-identical for any `CFEL_THREADS` (pinned by
//! `rust/tests/determinism.rs`, and the degenerate `SemiSync{k=N,
//! timeout=∞, a=0}` case is pinned to `FullBarrier` at bit-identical
//! precision by `rust/tests/agg_policy.rs`).
//!
//! Cohort batching: the sharded engine delivers reports in batches (one
//! per capability cohort), consulting
//! [`AggregationPolicy::closes_within_batch`]. Its contract — return the
//! first report count within the batch at which [`closes_at_report`]
//! would fire, or `None` — must match the per-report scan exactly; the
//! provided default *is* that scan, and the O(1) overrides here are
//! pinned to it over an exhaustive grid by this module's tests. See
//! `docs/DETERMINISM.md` §2.
//!
//! [`closes_at_report`]: AggregationPolicy::closes_at_report

/// Why an edge phase stopped accepting reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloseReason {
    /// Every participating device reported before any cutoff fired.
    AllReported,
    /// The K-th report arrived (semi-sync) before the timeout.
    KthReport,
    /// The semi-sync timeout fired with fewer than K reports in.
    Timeout,
    /// The reporting deadline fired with reports still outstanding.
    Deadline,
}

impl CloseReason {
    pub fn name(self) -> &'static str {
        match self {
            CloseReason::AllReported => "all-reported",
            CloseReason::KthReport => "kth-report",
            CloseReason::Timeout => "timeout",
            CloseReason::Deadline => "deadline",
        }
    }

    /// Stable index for count accumulators (`RoundTiming::close_reasons`).
    pub fn index(self) -> usize {
        match self {
            CloseReason::AllReported => 0,
            CloseReason::KthReport => 1,
            CloseReason::Timeout => 2,
            CloseReason::Deadline => 3,
        }
    }

    /// All variants, in `index` order.
    pub const ALL: [CloseReason; 4] = [
        CloseReason::AllReported,
        CloseReason::KthReport,
        CloseReason::Timeout,
        CloseReason::Deadline,
    ];
}

/// How one device's report fared against the phase close.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReportVerdict {
    /// Arrived at or before the close — merged into this phase's Eq. 6.
    OnTime,
    /// Missed the close but is kept; merges into a later phase close with
    /// a staleness discount (semi-sync).
    Late,
    /// Missed the close and is discarded outright (deadline-drop).
    Dropped,
}

/// The edge-round close condition, consulted by the event simulator.
///
/// One phase of one cluster is simulated as `ComputeDone`/`UploadDone`
/// events; the policy optionally arms a single `RoundClose` timeout event
/// ([`timeout`](AggregationPolicy::timeout)) and is asked after every
/// report whether the phase closes now
/// ([`closes_at_report`](AggregationPolicy::closes_at_report)). Reports
/// landing after the close get [`late_verdict`](AggregationPolicy::late_verdict);
/// late reports that are kept merge into a later close weighted by
/// `n_samples ·` [`staleness_discount`](AggregationPolicy::staleness_discount).
pub trait AggregationPolicy: Send + Sync {
    /// Absolute phase-relative time of the `RoundClose` timeout event to
    /// arm, if any, and the [`CloseReason`] to record when it fires first.
    fn timeout(&self) -> Option<(f64, CloseReason)>;

    /// Whether the phase closes once `reports_done` of `total` devices
    /// have reported. Called after each `UploadDone` in virtual-time
    /// order; the first `true` fixes the close instant.
    fn closes_at_report(&self, reports_done: usize, total: usize) -> bool;

    /// Batched close check for the cohort engine: a cohort of `batch`
    /// simultaneous reports lands with `done_before` already in. Returns
    /// the first absolute count `k` in `done_before+1 ..= done_before+batch`
    /// at which [`closes_at_report`](AggregationPolicy::closes_at_report)
    /// fires, or `None`. Because the reports of one cohort share an exact
    /// timestamp, only *whether* and at *which count* the close fires is
    /// observable — the close time is the batch's — so this provided
    /// default (a per-report scan) is always correct; the built-in
    /// policies override it with O(1) closed forms for million-device
    /// batches.
    fn closes_within_batch(
        &self,
        done_before: usize,
        batch: usize,
        total: usize,
    ) -> Option<usize> {
        (done_before + 1..=done_before + batch).find(|&k| self.closes_at_report(k, total))
    }

    /// Fate of a report that misses the close: [`ReportVerdict::Dropped`]
    /// or [`ReportVerdict::Late`]. Never [`ReportVerdict::OnTime`].
    fn late_verdict(&self) -> ReportVerdict;

    /// Weight multiplier for a kept report merged `staleness` edge phases
    /// after its origin phase (on-time reports use `staleness = 0`). Must
    /// be positive; the merge renormalizes, so only ratios matter.
    fn staleness_discount(&self, staleness: u64) -> f64;
}

/// Wait for every report — the paper's barrier and the equivalence oracle.
#[derive(Debug, Clone, Copy, Default)]
pub struct FullBarrier;

impl AggregationPolicy for FullBarrier {
    fn timeout(&self) -> Option<(f64, CloseReason)> {
        None
    }

    fn closes_at_report(&self, reports_done: usize, total: usize) -> bool {
        reports_done == total
    }

    fn closes_within_batch(
        &self,
        done_before: usize,
        batch: usize,
        total: usize,
    ) -> Option<usize> {
        // Only the final report closes; the count never overshoots total.
        (done_before + batch == total).then_some(total)
    }

    fn late_verdict(&self) -> ReportVerdict {
        // Unreachable in practice: the barrier close is the last report.
        ReportVerdict::Dropped
    }

    fn staleness_discount(&self, _staleness: u64) -> f64 {
        1.0
    }
}

/// Close at `min(deadline, latest report)`; late devices are dropped from
/// Eq. 6 and the survivor weights renormalize (PR 2's `--deadline` path).
#[derive(Debug, Clone, Copy)]
pub struct DeadlineDrop {
    /// Per-edge-phase reporting deadline T_dl, seconds from phase start.
    pub deadline_s: f64,
}

impl AggregationPolicy for DeadlineDrop {
    fn timeout(&self) -> Option<(f64, CloseReason)> {
        Some((self.deadline_s, CloseReason::Deadline))
    }

    fn closes_at_report(&self, reports_done: usize, total: usize) -> bool {
        reports_done == total
    }

    fn closes_within_batch(
        &self,
        done_before: usize,
        batch: usize,
        total: usize,
    ) -> Option<usize> {
        (done_before + batch == total).then_some(total)
    }

    fn late_verdict(&self) -> ReportVerdict {
        ReportVerdict::Dropped
    }

    fn staleness_discount(&self, _staleness: u64) -> f64 {
        1.0
    }
}

/// FedBuff-style K-of-N: close at the K-th report (or `timeout_s`), keep
/// late reports and merge them stale with weight `1/(1+s)^staleness_exp`.
#[derive(Debug, Clone, Copy)]
pub struct SemiSync {
    /// Reports needed to close the phase (clamped to the phase's
    /// participant count, so `k >= n` degenerates to the full barrier).
    pub k: usize,
    /// Hard cutoff, seconds from phase start; `f64::INFINITY` disables it.
    pub timeout_s: f64,
    /// Polynomial staleness exponent `a` in `1/(1+s)^a`; `0` weights late
    /// reports like fresh ones.
    pub staleness_exp: f64,
}

impl SemiSync {
    /// Build a semi-sync policy from controller-fitted parameters,
    /// enforcing the invariants the event engine assumes: `k` is clamped
    /// to `[1, max(n, 1)]`, and a non-finite or non-positive timeout
    /// degrades to "no timeout" (`f64::INFINITY`) rather than arming a
    /// `RoundClose` event at a nonsense instant. Controllers
    /// (`control::AdaptiveSemiSync`) must funnel through here so
    /// arbitrary telemetry can never produce an invalid close condition.
    pub fn from_fit(k: usize, timeout_s: f64, n: usize, staleness_exp: f64) -> SemiSync {
        let timeout_s = if timeout_s.is_finite() && timeout_s > 0.0 {
            timeout_s
        } else {
            f64::INFINITY
        };
        SemiSync { k: k.clamp(1, n.max(1)), timeout_s, staleness_exp }
    }
}

impl AggregationPolicy for SemiSync {
    fn timeout(&self) -> Option<(f64, CloseReason)> {
        if self.timeout_s.is_finite() {
            Some((self.timeout_s, CloseReason::Timeout))
        } else {
            None
        }
    }

    fn closes_at_report(&self, reports_done: usize, total: usize) -> bool {
        reports_done >= self.k.min(total)
    }

    fn closes_within_batch(
        &self,
        done_before: usize,
        batch: usize,
        total: usize,
    ) -> Option<usize> {
        // First count >= k.min(total) (>= 1 — counts start at one) inside
        // the batch window; identical to the per-report scan.
        let k_star = self.k.min(total).max(1).max(done_before + 1);
        (k_star <= done_before + batch).then_some(k_star)
    }

    fn late_verdict(&self) -> ReportVerdict {
        ReportVerdict::Late
    }

    fn staleness_discount(&self, staleness: u64) -> f64 {
        // (1+s)^0 == 1.0 exactly (IEEE pow), so a == 0 reproduces the
        // undiscounted Eq. 6 weights bit for bit.
        (1.0 + staleness as f64).powf(-self.staleness_exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_barrier_closes_only_on_last_report() {
        let p = FullBarrier;
        assert!(p.timeout().is_none());
        assert!(!p.closes_at_report(3, 4));
        assert!(p.closes_at_report(4, 4));
        assert_eq!(p.staleness_discount(7), 1.0);
    }

    #[test]
    fn deadline_drop_arms_timeout_and_drops_late() {
        let p = DeadlineDrop { deadline_s: 0.25 };
        assert_eq!(p.timeout(), Some((0.25, CloseReason::Deadline)));
        assert!(!p.closes_at_report(1, 2));
        assert!(p.closes_at_report(2, 2));
        assert_eq!(p.late_verdict(), ReportVerdict::Dropped);
    }

    #[test]
    fn semi_sync_closes_at_kth_and_clamps_k() {
        let p = SemiSync { k: 3, timeout_s: f64::INFINITY, staleness_exp: 1.0 };
        assert!(p.timeout().is_none(), "infinite timeout arms no event");
        assert!(!p.closes_at_report(2, 8));
        assert!(p.closes_at_report(3, 8));
        // k larger than the phase degenerates to the barrier.
        assert!(!p.closes_at_report(1, 2));
        assert!(p.closes_at_report(2, 2));
        assert_eq!(p.late_verdict(), ReportVerdict::Late);
    }

    #[test]
    fn staleness_discount_is_polynomial_and_exact_at_zero_exp() {
        let p = SemiSync { k: 1, timeout_s: 0.5, staleness_exp: 2.0 };
        assert!((p.staleness_discount(0) - 1.0).abs() < 1e-15);
        assert!((p.staleness_discount(1) - 0.25).abs() < 1e-15);
        assert!((p.staleness_discount(3) - 1.0 / 16.0).abs() < 1e-15);
        let flat = SemiSync { k: 1, timeout_s: 0.5, staleness_exp: 0.0 };
        for s in 0..10 {
            // Bit-exact 1.0: the oracle-equivalence tests rely on it.
            assert_eq!(flat.staleness_discount(s).to_bits(), 1.0f64.to_bits());
        }
    }

    #[test]
    fn from_fit_clamps_k_and_sanitizes_timeout() {
        let p = SemiSync::from_fit(0, 2.5, 8, 1.0);
        assert_eq!(p.k, 1);
        assert_eq!(p.timeout_s, 2.5);
        let p = SemiSync::from_fit(99, f64::NAN, 8, 1.0);
        assert_eq!(p.k, 8);
        assert!(p.timeout_s.is_infinite());
        let p = SemiSync::from_fit(3, -1.0, 8, 1.0);
        assert!(p.timeout_s.is_infinite(), "non-positive timeout disarms");
        let p = SemiSync::from_fit(3, 0.0, 0, 1.0);
        assert_eq!(p.k, 1, "empty cluster still yields a valid policy");
        assert!(p.timeout_s.is_infinite());
    }

    #[test]
    fn batch_close_overrides_match_the_per_report_scan() {
        // The O(1) closes_within_batch overrides must agree with the
        // provided default (a closes_at_report scan) on every reachable
        // (done_before, batch, total) cell — this is what licenses the
        // cohort engine to consult the policy once per batch.
        fn scan(p: &dyn AggregationPolicy, done: usize, batch: usize, total: usize) -> Option<usize> {
            (done + 1..=done + batch).find(|&k| p.closes_at_report(k, total))
        }
        let policies: Vec<Box<dyn AggregationPolicy>> = vec![
            Box::new(FullBarrier),
            Box::new(DeadlineDrop { deadline_s: 1.0 }),
            Box::new(SemiSync { k: 0, timeout_s: 1.0, staleness_exp: 1.0 }),
            Box::new(SemiSync { k: 3, timeout_s: f64::INFINITY, staleness_exp: 1.0 }),
            Box::new(SemiSync { k: 99, timeout_s: 1.0, staleness_exp: 0.0 }),
        ];
        for p in &policies {
            for total in 1..=8usize {
                for done in 0..total {
                    for batch in 1..=(total - done) {
                        assert_eq!(
                            p.closes_within_batch(done, batch, total),
                            scan(&**p, done, batch, total),
                            "done={done} batch={batch} total={total}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn close_reason_names_and_indices_are_stable() {
        for (i, r) in CloseReason::ALL.iter().enumerate() {
            assert_eq!(r.index(), i);
        }
        assert_eq!(CloseReason::Deadline.name(), "deadline");
        assert_eq!(CloseReason::KthReport.name(), "kth-report");
    }
}
