//! # cfel — Cooperative Federated Edge Learning
//!
//! A production-grade reproduction of *Scalable and Low-Latency Federated
//! Learning with Cooperative Mobile Edge Networking* (Zhang et al., 2022):
//! the CFEL two-tier edge architecture and the CE-FedAvg federated
//! optimization algorithm, plus the three baseline FL frameworks the paper
//! compares against (cloud FedAvg, hierarchical Hier-FAvg, Local-Edge).
//!
//! Architecture (see DESIGN.md):
//! * **Layer 3 (this crate)** — the coordinator: cluster/device topology,
//!   gossip over the edge backhaul, partitioning, the paper's runtime model
//!   (Eq. 8), metrics and experiment harnesses.
//! * **Layer 2/1 (python/, build time only)** — JAX model fwd/bwd on Pallas
//!   kernels, AOT-lowered to HLO text and executed here through the PJRT C
//!   API ([`runtime::PjrtBackend`]). Python never runs on the request path.
//!
//! Quick start:
//! ```no_run
//! use cfel::config::ExperimentConfig;
//! use cfel::coordinator::Coordinator;
//!
//! let cfg = ExperimentConfig::quickstart();
//! let mut coord = Coordinator::from_config(&cfg).unwrap();
//! let history = coord.run().unwrap();
//! println!("final accuracy: {:.3}", history.last().unwrap().test_accuracy);
//! ```

pub mod aggregation;
pub mod compression;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod plan;
pub mod rpc;
pub mod runtime;
pub mod scenario;
pub mod secagg;
pub mod topology;
pub mod util;

pub use error::{CfelError, Result};
