//! Round-level metrics, history, CSV/markdown emission, time-to-accuracy.

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// One global round's record (the unit of Figs. 2–6).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    /// Global round index l (1-based in reports).
    pub round: usize,
    /// Simulated wall-clock per Eq. 8, cumulative seconds.
    pub sim_time_s: f64,
    /// Real wall-clock spent training, cumulative seconds.
    pub wall_time_s: f64,
    /// This round's simulated compute (straggler barrier) seconds.
    pub compute_s: f64,
    /// This round's simulated device-uplink seconds.
    pub upload_s: f64,
    /// This round's simulated backhaul (gossip) seconds.
    pub backhaul_s: f64,
    /// Devices dropped outright by the close policy this round (the
    /// deadline; event-driven latency mode; always 0 in closed-form mode).
    pub dropped_devices: usize,
    /// Reports that made their phase close this round (event mode).
    pub on_time_devices: usize,
    /// Reports that missed their close but were kept for a stale merge
    /// (semi-sync; event mode).
    pub late_devices: usize,
    /// Kept-late reports from earlier phases folded into one of this
    /// round's aggregates with a staleness discount (semi-sync).
    pub stale_merged: usize,
    /// Why this round's phases closed: a `CloseReason` name when
    /// unanimous, "mixed" otherwise, "-" in closed-form mode.
    pub close_reason: String,
    /// Mean training loss over the round's SGD steps.
    pub train_loss: f64,
    /// Common-test-set accuracy (NaN when eval was skipped this round).
    pub test_accuracy: f64,
    pub test_loss: f64,
    /// Mean squared distance of cluster models from their average.
    pub consensus: f64,
    /// Total SGD steps executed this round (all devices).
    pub steps: usize,
    /// Median device report time across the round's simulated phase
    /// reports, seconds from phase start (NaN in closed-form mode — the
    /// control plane's primary input, and useful telemetry on its own).
    pub report_p50_s: f64,
    /// 90th-percentile report time (nearest rank).
    pub report_p90_s: f64,
    /// 99th-percentile report time (nearest rank).
    pub report_p99_s: f64,
    /// Mask-generation + fixed-point-encode compute the secure-
    /// aggregation tier charged this round, seconds summed over all
    /// masked-phase participants (0 when secagg is off or lossless).
    pub secagg_mask_s: f64,
    /// Upload inflation the masked encoding added over the plain model
    /// payload this round, bits summed over all masked uploads.
    pub secagg_extra_bits: f64,
    /// The controller decision applied at this round's boundary
    /// (comma-free provenance note; `"-"` when nothing was rewritten).
    pub decision: String,
}

/// Full run history.
pub type History = Vec<RoundRecord>;

/// First round/sim-time at which `target` accuracy is reached (Fig. 2's
/// time-to-accuracy metric). Returns (round, sim_time_s).
pub fn time_to_accuracy(history: &History, target: f64) -> Option<(usize, f64)> {
    history
        .iter()
        .find(|r| !r.test_accuracy.is_nan() && r.test_accuracy >= target)
        .map(|r| (r.round, r.sim_time_s))
}

/// FNV-1a 64 digest over every deterministic field of the history, in
/// declaration order. `wall_time_s` is real wall clock — the one
/// nondeterministic field — and is skipped, so the digest of a
/// distributed run can be diffed against the in-process interpreter's
/// (`cfel-cloud --digest` vs `cfel train --digest` in CI's
/// distributed-smoke job). f64s hash by bit pattern: NaN evals and
/// negative zeros are pinned too.
pub fn history_digest(history: &History) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x1000_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    for r in history {
        eat(&(r.round as u64).to_le_bytes());
        eat(&r.sim_time_s.to_bits().to_le_bytes());
        // wall_time_s deliberately skipped.
        eat(&r.compute_s.to_bits().to_le_bytes());
        eat(&r.upload_s.to_bits().to_le_bytes());
        eat(&r.backhaul_s.to_bits().to_le_bytes());
        eat(&(r.dropped_devices as u64).to_le_bytes());
        eat(&(r.on_time_devices as u64).to_le_bytes());
        eat(&(r.late_devices as u64).to_le_bytes());
        eat(&(r.stale_merged as u64).to_le_bytes());
        eat(r.close_reason.as_bytes());
        eat(&r.train_loss.to_bits().to_le_bytes());
        eat(&r.test_accuracy.to_bits().to_le_bytes());
        eat(&r.test_loss.to_bits().to_le_bytes());
        eat(&r.consensus.to_bits().to_le_bytes());
        eat(&(r.steps as u64).to_le_bytes());
        // report_p50/p90/p99_s, decision, and the secagg overhead
        // columns deliberately skipped: the digest is fed by the
        // original columns only, so pins recorded before the control
        // plane / secure-aggregation tier landed stay valid. (Masking's
        // *latency* effects flow through compute_s/upload_s/sim_time_s,
        // which the digest does cover.)
    }
    h
}

/// Nearest-rank p50/p90/p99 of a report-time sample (seconds from phase
/// start, any order). Empty input — closed-form mode simulates no
/// per-device reports — yields NaNs, which the CSV writer renders as
/// empty fields exactly like a skipped eval.
pub fn report_quantiles(finish_s: &[f64]) -> (f64, f64, f64) {
    if finish_s.is_empty() {
        return (f64::NAN, f64::NAN, f64::NAN);
    }
    let mut sorted = finish_s.to_vec();
    sorted.sort_by(f64::total_cmp);
    let q = |p: f64| -> f64 {
        let rank = (p * sorted.len() as f64).ceil() as usize;
        sorted[rank.max(1).min(sorted.len()) - 1]
    };
    (q(0.5), q(0.9), q(0.99))
}

/// Best accuracy seen in the run.
pub fn best_accuracy(history: &History) -> f64 {
    history
        .iter()
        .map(|r| r.test_accuracy)
        .filter(|a| !a.is_nan())
        .fold(0.0, f64::max)
}

/// CSV writer: one file accumulating rows across experiment series.
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &str) -> Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{header}")?;
        Ok(CsvWriter { file })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }

    /// Standard per-round row for a named series.
    pub fn round_row(&mut self, series: &str, r: &RoundRecord) -> Result<()> {
        self.row(&[
            series.to_string(),
            r.round.to_string(),
            format!("{:.3}", r.sim_time_s),
            format!("{:.3}", r.wall_time_s),
            format!("{:.5}", r.train_loss),
            if r.test_accuracy.is_nan() {
                String::new()
            } else {
                format!("{:.5}", r.test_accuracy)
            },
            if r.test_loss.is_nan() {
                String::new()
            } else {
                format!("{:.5}", r.test_loss)
            },
            format!("{:.6e}", r.consensus),
            r.steps.to_string(),
            format!("{:.3}", r.compute_s),
            format!("{:.3}", r.upload_s),
            format!("{:.3}", r.backhaul_s),
            r.dropped_devices.to_string(),
            r.on_time_devices.to_string(),
            r.late_devices.to_string(),
            r.stale_merged.to_string(),
            r.close_reason.clone(),
            quantile_field(r.report_p50_s),
            quantile_field(r.report_p90_s),
            quantile_field(r.report_p99_s),
            r.decision.clone(),
            format!("{:.6e}", r.secagg_mask_s),
            format!("{:.6e}", r.secagg_extra_bits),
        ])
    }
}

/// Report-quantile CSV field: fixed precision, empty for NaN (closed-form
/// mode), mirroring how skipped evals render.
fn quantile_field(v: f64) -> String {
    if v.is_nan() {
        String::new()
    } else {
        format!("{v:.4}")
    }
}

/// Header matching [`CsvWriter::round_row`]. Columns added after the
/// original set (controller, then secagg overhead) sit at the end so
/// field indices of the earlier columns are stable.
pub const ROUND_HEADER: &str = "series,round,sim_time_s,wall_time_s,train_loss,\
     test_accuracy,test_loss,consensus,steps,compute_s,upload_s,backhaul_s,dropped,\
     on_time,late,stale,close_reason,report_p50_s,report_p90_s,report_p99_s,decision,\
     secagg_mask_s,secagg_extra_bits";

/// Render a small aligned markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str(&format!("| {} |\n", headers.join(" | ")));
    s.push_str(&format!(
        "|{}\n",
        headers.iter().map(|_| "---|").collect::<String>()
    ));
    for row in rows {
        s.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64, t: f64) -> RoundRecord {
        RoundRecord {
            round,
            sim_time_s: t,
            wall_time_s: 0.0,
            compute_s: 0.1,
            upload_s: 0.2,
            backhaul_s: 0.3,
            dropped_devices: 0,
            on_time_devices: 0,
            late_devices: 0,
            stale_merged: 0,
            close_reason: "-".into(),
            train_loss: 1.0,
            test_accuracy: acc,
            test_loss: 1.0,
            consensus: 0.0,
            steps: 10,
            report_p50_s: f64::NAN,
            report_p90_s: f64::NAN,
            report_p99_s: f64::NAN,
            secagg_mask_s: 0.0,
            secagg_extra_bits: 0.0,
            decision: "-".into(),
        }
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let h = vec![rec(1, 0.3, 10.0), rec(2, 0.55, 20.0), rec(3, 0.6, 30.0)];
        assert_eq!(time_to_accuracy(&h, 0.5), Some((2, 20.0)));
        assert_eq!(time_to_accuracy(&h, 0.9), None);
    }

    #[test]
    fn nan_rounds_skipped() {
        let h = vec![rec(1, f64::NAN, 5.0), rec(2, 0.7, 9.0)];
        assert_eq!(time_to_accuracy(&h, 0.5), Some((2, 9.0)));
        assert!((best_accuracy(&h) - 0.7).abs() < 1e-12);
    }

    #[test]
    fn csv_writer_produces_rows() {
        let tmp = std::env::temp_dir().join(format!("cfel_csv_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&tmp, ROUND_HEADER).unwrap();
            w.round_row("ce-fedavg", &rec(1, 0.5, 2.0)).unwrap();
            w.round_row("fedavg", &rec(2, f64::NAN, 3.0)).unwrap();
        }
        let text = std::fs::read_to_string(&tmp).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("series,round"));
        assert!(lines[1].contains("ce-fedavg,1,"));
        assert!(lines[2].contains(",,")); // NaN accuracy → empty field
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn report_quantiles_nearest_rank() {
        let (p50, p90, p99) = report_quantiles(&[]);
        assert!(p50.is_nan() && p90.is_nan() && p99.is_nan());
        let samples: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let (p50, p90, p99) = report_quantiles(&samples);
        assert_eq!((p50, p90, p99), (5.0, 9.0, 10.0));
        // Unsorted input, single element, NaN-free ordering via total_cmp.
        assert_eq!(report_quantiles(&[3.0]), (3.0, 3.0, 3.0));
        assert_eq!(report_quantiles(&[2.0, 1.0]).0, 1.0);
    }

    #[test]
    fn round_row_appends_controller_columns() {
        let tmp = std::env::temp_dir()
            .join(format!("cfel_csv_ctrl_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&tmp, ROUND_HEADER).unwrap();
            let mut r = rec(1, 0.5, 2.0);
            r.report_p50_s = 0.25;
            r.report_p90_s = 0.5;
            r.report_p99_s = 1.0;
            r.decision = "refit 4 clusters k[2-5] t[0.8-1.2]".into();
            w.round_row("adaptive", &r).unwrap();
        }
        let text = std::fs::read_to_string(&tmp).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].ends_with(
            "report_p50_s,report_p90_s,report_p99_s,decision,\
             secagg_mask_s,secagg_extra_bits"
        ));
        assert!(lines[1].contains(",0.2500,0.5000,1.0000,refit 4 clusters k[2-5] t[0.8-1.2],"));
        assert_eq!(
            lines[1].split(',').count(),
            lines[0].split(',').count(),
            "decision notes must stay comma-free"
        );
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn round_row_appends_secagg_columns() {
        let tmp = std::env::temp_dir()
            .join(format!("cfel_csv_secagg_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&tmp, ROUND_HEADER).unwrap();
            let mut r = rec(1, 0.5, 2.0);
            r.secagg_mask_s = 0.125;
            r.secagg_extra_bits = 4096.0;
            w.round_row("masked", &r).unwrap();
        }
        let text = std::fs::read_to_string(&tmp).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].ends_with("secagg_mask_s,secagg_extra_bits"), "{}", lines[0]);
        assert!(lines[1].ends_with(",1.250000e-1,4.096000e3"), "{}", lines[1]);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn digest_ignores_controller_columns() {
        let base = vec![rec(1, 0.5, 2.0)];
        let mut adorned = base.clone();
        adorned[0].report_p50_s = 0.25;
        adorned[0].report_p90_s = 0.5;
        adorned[0].report_p99_s = 1.0;
        adorned[0].decision = "cloud->gossip (d2c 100000 < 500000)".into();
        adorned[0].secagg_mask_s = 0.5;
        adorned[0].secagg_extra_bits = 1024.0;
        assert_eq!(
            history_digest(&base),
            history_digest(&adorned),
            "old digest pins must stay valid"
        );
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
        assert_eq!(t.lines().count(), 3);
    }
}
