//! `cfel` — CLI for the CFEL / CE-FedAvg reproduction.
//!
//! Subcommands:
//!   train      run one experiment (algorithm, system shape, backend flags)
//!   figures    regenerate the paper's figures/tables into results/
//!   topology   print spectral diagnostics (ζ, Ω₁, Ω₂) for a backhaul graph
//!   artifacts  inspect the AOT artifact manifest
//!
//! Examples:
//!   cfel train --algorithm ce-fedavg --rounds 20
//!   cfel train --plan "(edge(2); gossip(3))*2; cloud" --rounds 20
//!   cfel train --plan "edge(2)*8; gossip(10)" --dry-run
//!   cfel train --backend pjrt --model femnist_cnn --devices 16 --clusters 4
//!   cfel figures --fig fig2 --rounds 30 --out results
//!   cfel topology --kind er:0.4 --m 8 --pi 10

use std::path::PathBuf;

use cfel::config::{
    conflicting_options, AggPolicyKind, AlgorithmKind, BackendKind, ControllerKind,
    DataScheme, ExperimentConfig, LatencyMode,
};
use cfel::plan::Plan;
use cfel::coordinator::Coordinator;
use cfel::experiments::{run_figure, FigureOpts};
use cfel::metrics::{best_accuracy, time_to_accuracy, CsvWriter, ROUND_HEADER};
use cfel::runtime::Manifest;
use cfel::topology::{Graph, MixingMatrix};
use cfel::util::cli::Command;
use cfel::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(String::as_str) {
        Some("train") => cmd_train(&argv[1..]),
        Some("figures") => cmd_figures(&argv[1..]),
        Some("topology") => cmd_topology(&argv[1..]),
        Some("artifacts") => cmd_artifacts(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "cfel — Cooperative Federated Edge Learning (CE-FedAvg reproduction)\n\n\
         Subcommands:\n\
         \x20 train      run one experiment\n\
         \x20 figures    regenerate paper figures/tables (fig2..fig6, table1, runtime, all)\n\
         \x20 topology   spectral diagnostics for a backhaul graph\n\
         \x20 artifacts  inspect the AOT artifact manifest\n\n\
         Run `cfel <subcommand> --help` for flags."
    );
}

fn train_command() -> Command {
    Command::new("cfel train", "run one CFEL experiment")
        .flag(
            "algorithm",
            "ce-fedavg | fedavg | hier-favg | local-edge [default: ce-fedavg]",
        )
        .flag(
            "plan",
            "explicit federation plan, e.g. \"edge(2)*2; gossip(10)\" \
             (replaces --algorithm; run with --dry-run to inspect)",
        )
        .flag(
            "scenario",
            "load a Scenario JSON (world description: rosters, capability \
             profiles, churn/handover timeline; fixes devices/clusters/topology \
             — see examples/scenarios/)",
        )
        .bool_flag(
            "dry-run",
            "print the resolved plan, config summary, cluster layout and \
             scenario timeline, then exit",
        )
        .bool_flag("print-plan", "alias for --dry-run")
        .flag_default("devices", "16", "total devices n")
        .flag_default("clusters", "4", "edge servers m (uneven splits allowed; remainder goes to the first clusters)")
        .flag_default("tau", "2", "local epochs per edge round (τ)")
        .flag_default("q", "2", "edge rounds per global round")
        .flag_default("pi", "10", "gossip steps per global aggregation (π)")
        .flag_default("rounds", "15", "global rounds")
        .flag_default("lr", "0.1", "local learning rate")
        .flag_default("topology", "ring", "ring | complete | star | line | er:<p>")
        .flag_default("data", "writers:0.3", "writers:<a> | dirichlet:<a> | iid | cluster-iid | cluster-noniid:<C>")
        .flag_default("samples", "60", "training samples per device")
        .flag_default("seed", "42", "experiment seed")
        .flag_default("backend", "mock", "mock | pjrt")
        .flag_default("model", "mlp_synth", "artifact model name (pjrt backend)")
        .flag("artifacts-dir", "artifacts directory (default: <repo>/artifacts)")
        .flag("heterogeneity", "device speed floor in (0,1], e.g. 0.5")
        .flag_default("latency", "closed-form", "closed-form | event (per-round latency estimator)")
        .flag("deadline", "per-edge-round reporting deadline in seconds (event mode)")
        .flag(
            "agg-policy",
            "edge-round close policy: full | deadline:<T> | kofn:<K>:<timeout|inf> (event mode)",
        )
        .flag(
            "staleness-exp",
            "semi-sync staleness discount exponent a in 1/(1+s)^a [default: 1.0]",
        )
        .flag(
            "controller",
            "round-boundary control plane: static | adaptive[:<window>] | \
             floating[:<threshold>] (adaptive/floating need --latency event)",
        )
        .flag("stragglers", "heavy-tail stragglers as <fraction>:<slowdown>, e.g. 0.1:50")
        .flag("csv", "write per-round history to this CSV file")
        .flag_default("eval-every", "1", "evaluate every k rounds")
        .flag_default("compression", "none", "none | topk:<frac> | quantize:<bits> (upload codec)")
        .flag_default(
            "secagg",
            "off",
            "off | lossless | mask:<bits> (pairwise-masked secure aggregation \
             on device→edge uploads; rewrites edge phases to edge(E)@masked)",
        )
        .flag_default("participation", "1.0", "fraction of devices sampled per edge round")
        .flag("save", "write the final global model to this checkpoint file")
        .bool_flag("quiet", "suppress per-round logging")
        .flag("config", "load an ExperimentConfig JSON file (other flags override)")
        .flag_default(
            "mode",
            "local",
            "local | cloud | edge (multi-process runtime; see cfel-cloud/cfel-edge)",
        )
        .flag_default("listen", "127.0.0.1:0", "cloud mode: bind address (or unix:/path)")
        .flag("connect", "edge mode: cloud address to connect to")
        .flag_default("edges", "1", "cloud mode: number of edge processes to accept")
        .bool_flag("digest", "print `history_digest: <hex>` (wall-clock excluded) after the run")
}

fn cmd_train(argv: &[String]) -> i32 {
    let cmd = train_command();
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    match run_train(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn run_train(args: &cfel::util::cli::Args) -> cfel::Result<()> {
    let mode = args.get_or("mode", "local");
    if !matches!(mode.as_str(), "local" | "cloud" | "edge") {
        return Err(cfel::CfelError::Config(format!(
            "unknown --mode {mode:?} (local | cloud | edge)"
        )));
    }
    if mode == "edge" {
        // The edge is config-free: the cloud ships the world over the wire.
        let connect = args
            .get("connect")
            .ok_or_else(|| cfel::CfelError::Config("--mode edge requires --connect".into()))?;
        let opts = cfel::rpc::EdgeOpts {
            connect: connect.to_string(),
            verbose: !args.get_bool("quiet"),
            ..Default::default()
        };
        return cfel::rpc::run_edge(&opts);
    }
    let mut cfg = if let Some(path) = args.get("config") {
        let j = cfel::util::json::Json::parse_file(std::path::Path::new(path))?;
        ExperimentConfig::from_json(&j)?
    } else {
        ExperimentConfig::quickstart()
    };
    // `--plan` replaces the canned schedule `--algorithm` names; naming
    // both is contradictory even when the algorithm spelled out is the
    // default (config-level validation can't see that case, since an
    // explicit `ce-fedavg` is indistinguishable from the default there —
    // the same split the `--deadline` / `--agg-policy` pair uses below).
    if args.get("plan").is_some() && args.get("algorithm").is_some() {
        return Err(conflicting_options(
            "--plan",
            "--algorithm",
            "an explicit plan replaces the canned algorithm schedule",
        ));
    }
    if let Some(spec) = args.get("plan") {
        // Plan::parse rejects unknown specs with the full grammar quoted.
        cfg.plan = Some(Plan::parse(spec)?);
    }
    if let Some(alg) = args.get("algorithm") {
        cfg.algorithm = AlgorithmKind::parse(alg)?;
    }
    cfg.n_devices = args.get_usize("devices", cfg.n_devices);
    cfg.n_clusters = args.get_usize("clusters", cfg.n_clusters);
    cfg.tau = args.get_usize("tau", cfg.tau);
    cfg.q = args.get_usize("q", cfg.q);
    cfg.pi = args.get_usize("pi", cfg.pi as usize) as u32;
    cfg.rounds = args.get_usize("rounds", cfg.rounds);
    cfg.lr = args.get_f64("lr", cfg.lr as f64) as f32;
    cfg.topology = args.get_or("topology", &cfg.topology);
    cfg.data = DataScheme::parse(&args.get_or("data", &cfg.data.name()))?;
    cfg.samples_per_device = args.get_usize("samples", cfg.samples_per_device);
    cfg.seed = args.get_usize("seed", cfg.seed as usize) as u64;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every);
    if args.get("heterogeneity").is_some() {
        cfg.heterogeneity = Some(args.get_f64("heterogeneity", 0.5));
    }
    cfg.latency = LatencyMode::parse(&args.get_or("latency", cfg.latency.name()))?;
    if let Some(dl) = args.get("deadline") {
        // Strict parse: a malformed deadline must not silently fall back
        // to some default — it changes which devices get dropped.
        cfg.deadline_s = Some(dl.parse().map_err(|_| {
            cfel::CfelError::Config(format!("invalid --deadline value {dl:?} (seconds)"))
        })?);
    }
    if let Some(spec) = args.get("stragglers") {
        cfg.stragglers = Some(cfel::netsim::StragglerSpec::parse(spec)?);
    }
    if let Some(p) = args.get("agg-policy") {
        // `--deadline` is sugar for `--agg-policy deadline:<T>`; naming
        // both is contradictory even when the policy spelled out is
        // `full` (config-level validation can't see that case, since an
        // explicit `full` is indistinguishable from the default there).
        if args.get("deadline").is_some() {
            return Err(conflicting_options(
                "--agg-policy",
                "--deadline",
                "--deadline is sugar for the deadline-drop policy",
            ));
        }
        cfg.agg_policy = AggPolicyKind::parse(p)?;
    }
    if let Some(a) = args.get("staleness-exp") {
        // Strict parse: the exponent reshapes every stale merge weight.
        cfg.staleness_exp = a.parse().map_err(|_| {
            cfel::CfelError::Config(format!("invalid --staleness-exp value {a:?}"))
        })?;
    }
    if let Some(spec) = args.get("controller") {
        // A controller rewrites the plan round by round, so naming the
        // canned schedule it would overwrite is contradictory — the same
        // explicit-vs-default split as `--plan` / `--algorithm` above.
        if args.get("algorithm").is_some() && ControllerKind::parse(spec)? != ControllerKind::Static
        {
            return Err(conflicting_options(
                "--controller",
                "--algorithm",
                "an adaptive controller rewrites the schedule per round; \
                 start it from --plan instead",
            ));
        }
        cfg.controller = ControllerKind::parse(spec)?;
    }
    cfg.backend = match args.get_or("backend", "mock").as_str() {
        "mock" => BackendKind::Mock { hidden: 32 },
        "pjrt" => BackendKind::Pjrt {
            model: args.get_or("model", "mlp_synth"),
            artifacts_dir: args.get("artifacts-dir").map(PathBuf::from),
        },
        other => {
            return Err(cfel::CfelError::Config(format!("unknown backend {other:?}")))
        }
    };
    cfg.compression =
        cfel::compression::Compressor::parse(&args.get_or("compression", &cfg.compression.name()))?;
    cfg.secagg =
        cfel::config::SecaggMode::parse(&args.get_or("secagg", &cfg.secagg.name()))?;
    cfg.participation = args.get_f64("participation", cfg.participation);
    if let Some(path) = args.get("scenario") {
        // The scenario owns the world shape: it fixes the device/cluster
        // counts and the topology (any --devices/--clusters/--topology
        // values are superseded), while --heterogeneity/--stragglers are
        // rejected by validate below — capability profiles live in the
        // scenario.
        let s = cfel::scenario::Scenario::load(std::path::Path::new(path))?;
        cfg.n_devices = s.n_devices;
        cfg.n_clusters = s.n_clusters();
        cfg.topology = s.topology.clone();
        cfg.scenario = Some(s);
    }
    cfg.validate()?;

    if args.get_bool("dry-run") || args.get_bool("print-plan") {
        print_dry_run(&cfg);
        return Ok(());
    }

    let mut saved_coord = None;
    let history = if mode == "cloud" {
        let opts = cfel::rpc::CloudOpts {
            listen: args.get_or("listen", "127.0.0.1:0"),
            edges: args.get_usize("edges", 1),
            verbose: !args.get_bool("quiet"),
            ..Default::default()
        };
        cfel::rpc::run_cloud(&cfg, &opts)?
    } else {
        let mut coord = Coordinator::from_config(&cfg)?;
        coord.verbose = !args.get_bool("quiet");
        eprintln!(
            "[cfel] {} | backend {} | n={} m={} tau={} q={} pi={} | topology {} | data {} | latency {} | policy {}",
            cfg.run_label(),
            coord.backend.name(),
            cfg.n_devices,
            cfg.n_clusters,
            cfg.tau,
            cfg.q,
            cfg.pi,
            coord.scenario.topology,
            cfg.data.name(),
            cfg.latency.name(),
            cfg.resolved_policy().name()
        );
        let history = coord.run()?;
        saved_coord = Some(coord);
        history
    };

    if let Some(csv_path) = args.get("csv") {
        let mut w = CsvWriter::create(std::path::Path::new(csv_path), ROUND_HEADER)?;
        let series = cfg.run_label();
        for rec in &history {
            w.round_row(&series, rec)?;
        }
        eprintln!("[cfel] wrote {csv_path}");
    }
    if args.get_bool("digest") {
        println!("history_digest: {:016x}", cfel::metrics::history_digest(&history));
    }

    let last = history.last().expect("at least one round");
    let best = best_accuracy(&history);
    println!("rounds:          {}", history.len());
    println!("final accuracy:  {:.4}", last.test_accuracy);
    println!("best accuracy:   {best:.4}");
    println!("final loss:      {:.4}", last.train_loss);
    println!(
        "sim time:        {:.1} s ({})",
        last.sim_time_s,
        if cfg.latency == LatencyMode::EventDriven { "event sim" } else { "Eq. 8" }
    );
    if cfg.latency == LatencyMode::EventDriven {
        let dropped: usize = history.iter().map(|r| r.dropped_devices).sum();
        println!("policy:          {}", cfg.resolved_policy().name());
        println!("deadline drops:  {dropped} device-rounds");
        let late: usize = history.iter().map(|r| r.late_devices).sum();
        let stale: usize = history.iter().map(|r| r.stale_merged).sum();
        if late > 0 || stale > 0 {
            println!("late reports:    {late} deferred, {stale} merged stale");
        }
    }
    println!("wall time:       {:.1} s", last.wall_time_s);
    if let Some((r, t)) = time_to_accuracy(&history, best * 0.9) {
        println!("90%-of-best hit: round {r} / {t:.1} sim-s");
    }
    if let Some(path) = args.get("save") {
        // Persist the size-weighted global model. The cloud's mirror
        // world holds the final cluster models too, but checkpointing is
        // a local-mode workflow — keep the failure mode explicit.
        let coord = saved_coord
            .as_ref()
            .ok_or_else(|| cfel::CfelError::Config("--save requires --mode local".into()))?;
        let sizes: Vec<usize> = coord.clusters.iter().map(|c| c.n_samples).collect();
        let models: Vec<Vec<f32>> = coord.clusters.iter().map(|c| c.model.clone()).collect();
        let global = cfel::aggregation::global_average(&models, &sizes)?;
        let state = cfel::model::ModelState::from_params(global);
        cfel::model::checkpoint::save(
            std::path::Path::new(path),
            &state,
            coord.backend.name(),
            history.len(),
        )?;
        eprintln!("[cfel] saved checkpoint to {path}");
    }
    Ok(())
}

/// `--dry-run` / `--print-plan`: show what would run — the resolved plan
/// with its per-round communication structure, the headline config, the
/// resolved scenario's roster layout and its world-event timeline —
/// without building data or training anything. The scenario (explicit or
/// the flat lowering) is fully validated here, so a broken `--scenario`
/// file fails in the dry run.
fn print_dry_run(cfg: &ExperimentConfig) {
    let plan = cfg.resolved_plan();
    let comms = plan.comms();
    let scenario = cfg.resolved_scenario();
    println!("plan:       {plan}");
    println!(
        "  per round: {} edge phase(s) ({} via edge uplink, {} via masked edge uplink, \
         {} via cloud uplink), {} gossip step(s), cloud aggregation: {}",
        plan.edge_phases(),
        comms.edge_uploads,
        comms.masked_uploads,
        comms.cloud_uploads,
        comms.gossip_pi,
        if plan.has_cloud_aggregate() { "yes" } else { "no" }
    );
    println!("series:     {}", cfg.run_label());
    println!("secagg:     {}", cfg.secagg.name());
    println!("rounds:     {}", cfg.rounds);
    println!("seed:       {}", cfg.seed);
    println!("scenario:   {}", scenario.name);
    println!("topology:   {}", scenario.topology);
    println!("data:       {}", cfg.data.name());
    println!("latency:    {}", cfg.latency.name());
    println!("policy:     {}", cfg.resolved_policy().name());
    println!("controller: {}", cfg.controller.name());
    let dormant = scenario.dormant_count();
    println!(
        "layout:     {} devices / {} clusters{}",
        cfg.n_devices,
        cfg.n_clusters,
        if dormant > 0 {
            format!(" ({dormant} dormant until a join event)")
        } else {
            String::new()
        }
    );
    let shown = scenario.rosters.len().min(8);
    for (ci, roster) in scenario.rosters.iter().take(shown).enumerate() {
        println!("  cluster {ci}: {} device(s) {}", roster.len(), roster_label(roster));
    }
    if scenario.rosters.len() > shown {
        println!("  ... ({} more clusters)", scenario.rosters.len() - shown);
    }
    println!("timeline:   {}", scenario.timeline.summary());
    println!("(dry run — nothing was trained)");
}

/// Compact roster rendering: a contiguous range as `a..=b`, anything else
/// as an id list capped at 8 entries.
fn roster_label(roster: &[usize]) -> String {
    if roster.is_empty() {
        return "(empty)".into();
    }
    if roster.windows(2).all(|w| w[1] == w[0] + 1) {
        return format!("{}..={}", roster[0], roster[roster.len() - 1]);
    }
    let ids: Vec<String> = roster.iter().take(8).map(|d| d.to_string()).collect();
    let more = if roster.len() > 8 {
        format!(", +{} more", roster.len() - 8)
    } else {
        String::new()
    };
    format!("[{}{}]", ids.join(", "), more)
}

fn cmd_figures(argv: &[String]) -> i32 {
    let cmd = Command::new("cfel figures", "regenerate paper figures/tables")
        .flag_default("fig", "all", "fig2|fig3|fig4|fig5|fig6|table1|runtime|all")
        .flag_default("out", "results", "output directory")
        .flag_default("rounds", "30", "global rounds per run")
        .flag_default("seed", "1", "seed")
        .flag_default("backend", "mock", "mock | pjrt")
        .flag_default("model", "mlp_synth", "artifact model name (pjrt)")
        .bool_flag("verbose", "per-round logging");
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    let backend = match args.get_or("backend", "mock").as_str() {
        "pjrt" => BackendKind::Pjrt {
            model: args.get_or("model", "mlp_synth"),
            artifacts_dir: None,
        },
        _ => BackendKind::Mock { hidden: 32 },
    };
    let opts = FigureOpts {
        out_dir: PathBuf::from(args.get_or("out", "results")),
        rounds: args.get_usize("rounds", 30),
        seed: args.get_usize("seed", 1) as u64,
        backend,
        verbose: args.get_bool("verbose"),
    };
    match run_figure(&args.get_or("fig", "all"), &opts) {
        Ok(summary) => {
            println!("{summary}");
            println!("\n[cfel] CSVs + summaries written to {}", opts.out_dir.display());
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_topology(argv: &[String]) -> i32 {
    let cmd = Command::new("cfel topology", "spectral diagnostics for a backhaul graph")
        .flag_default("kind", "ring", "ring | complete | star | line | er:<p>")
        .flag_default("m", "8", "number of edge servers")
        .flag_default("pi", "10", "gossip steps")
        .flag_default("seed", "1", "seed (ER graphs)");
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    let kind = args.get_or("kind", "ring");
    let m = args.get_usize("m", 8);
    let pi = args.get_usize("pi", 10) as u32;
    let rng = Rng::new(args.get_usize("seed", 1) as u64);
    match Graph::by_name(&kind, m, &rng) {
        Ok(g) => {
            let h = MixingMatrix::metropolis(&g);
            println!("topology:  {} (m={m}, {} edges)", g.name(), g.edge_count());
            println!("connected: {}", g.is_connected());
            println!("zeta:      {:.6}", h.zeta());
            println!("omega1(pi={pi}): {:.6}", h.omega1(pi));
            println!("omega2(pi={pi}): {:.6}", h.omega2(pi));
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_artifacts(argv: &[String]) -> i32 {
    let cmd = Command::new("cfel artifacts", "inspect the AOT artifact manifest")
        .flag("dir", "artifacts directory (default: <repo>/artifacts)");
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    let dir = args
        .get("dir")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {}", dir.display());
            for (name, e) in &m.models {
                println!(
                    "  {name}: {} params, batch {}, input {:?}, {} classes, {:.2} MFLOPs/sample",
                    e.schema.param_count,
                    e.batch_size,
                    e.input_dim,
                    e.num_classes,
                    e.flops_per_sample / 1e6
                );
                println!(
                    "    train: {} | eval: {}",
                    e.train_hlo.file_name().unwrap().to_string_lossy(),
                    e.eval_hlo.file_name().unwrap().to_string_lossy()
                );
            }
            println!(
                "  aggregate: rows={} dim={}",
                m.aggregate.rows, m.aggregate.dim
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}
