//! Micro-benchmark harness used by the `cargo bench` targets.
//!
//! criterion is not in the offline vendor set, so `rust/benches/*.rs` are
//! `harness = false` binaries built on this module: warmup, repeated timed
//! iterations, robust summary (mean ± stddev, median, p10/p90), and an
//! optional throughput label. Output is stable, grep-able text that
//! EXPERIMENTS.md quotes directly.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// One benchmark's collected samples (seconds per iteration).
#[derive(Debug)]
pub struct Sample {
    pub name: String,
    pub secs: Vec<f64>,
    pub throughput_items: Option<f64>,
}

impl Sample {
    pub fn report(&self) -> String {
        let m = stats::mean(&self.secs);
        let sd = stats::percentile(&self.secs, 0.5);
        let p10 = stats::percentile(&self.secs, 0.1);
        let p90 = stats::percentile(&self.secs, 0.9);
        let mut line = format!(
            "{:<44} mean {:>10}  median {:>10}  p10 {:>10}  p90 {:>10}  n={}",
            self.name,
            stats::fmt_duration(m),
            stats::fmt_duration(sd),
            stats::fmt_duration(p10),
            stats::fmt_duration(p90),
            self.secs.len()
        );
        if let Some(items) = self.throughput_items {
            if m > 0.0 {
                line.push_str(&format!("  [{:.1} items/s]", items / m));
            }
        }
        line
    }

    /// Machine-readable form of this sample (one entry of the array
    /// [`Bench::write_json`] emits).
    pub fn to_json(&self) -> Json {
        let m = stats::mean(&self.secs);
        let mut j = Json::obj();
        j.set("name", Json::from_str_val(&self.name))
            .set("iters", Json::from_usize(self.secs.len()))
            .set("mean_s", Json::from_f64(m))
            .set("median_s", Json::from_f64(stats::percentile(&self.secs, 0.5)))
            .set("p10_s", Json::from_f64(stats::percentile(&self.secs, 0.1)))
            .set("p90_s", Json::from_f64(stats::percentile(&self.secs, 0.9)));
        if let Some(items) = self.throughput_items {
            j.set("items", Json::from_f64(items));
            if m > 0.0 {
                j.set("items_per_s", Json::from_f64(items / m));
            }
        }
        j
    }
}

/// Bench runner with fixed warmup/measure counts.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    samples: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // CFEL_BENCH_ITERS / CFEL_BENCH_WARMUP override for quick runs.
        let iters = std::env::var("CFEL_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(10);
        let warmup = std::env::var("CFEL_BENCH_WARMUP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(2);
        Self { warmup, iters, samples: Vec::new() }
    }

    pub fn with_iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }

    /// Time `f` (called once per iteration); the closure's return value is
    /// black-boxed so the work is not optimised away.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Sample {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut secs = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            secs.push(t0.elapsed().as_secs_f64());
        }
        self.samples.push(Sample { name: name.to_string(), secs, throughput_items: None });
        let s = self.samples.last().unwrap();
        println!("{}", s.report());
        s
    }

    /// Like [`run`], attaching an items/sec throughput to the report.
    pub fn run_throughput<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: F,
    ) -> &Sample {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut secs = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            secs.push(t0.elapsed().as_secs_f64());
        }
        self.samples.push(Sample {
            name: name.to_string(),
            secs,
            throughput_items: Some(items),
        });
        let s = self.samples.last().unwrap();
        println!("{}", s.report());
        s
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Dump every collected sample as pretty JSON:
    /// `{"bench": <name>, "samples": [<Sample::to_json>, ...]}`.
    /// This is what `BENCH_scale.json` and the `CFEL_BENCH_JSON` lanes
    /// are built from — stable keys, parseable with `Json::parse_file`.
    pub fn write_json(&self, path: &std::path::Path, bench_name: &str) -> std::io::Result<()> {
        let mut root = Json::obj();
        root.set("bench", Json::from_str_val(bench_name)).set(
            "samples",
            Json::Arr(self.samples.iter().map(Sample::to_json).collect()),
        );
        std::fs::write(path, root.pretty() + "\n")
    }
}

/// Standard header so all bench binaries print a uniform preamble.
pub fn header(title: &str, detail: &str) {
    println!("\n=== bench: {title} ===");
    if !detail.is_empty() {
        println!("{detail}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let mut b = Bench { warmup: 1, iters: 3, samples: vec![] };
        let s = b.run("noop", || 1 + 1);
        assert_eq!(s.secs.len(), 3);
        assert!(s.report().contains("noop"));
        assert_eq!(b.samples().len(), 1);
    }

    #[test]
    fn json_dump_round_trips() {
        let mut b = Bench { warmup: 0, iters: 2, samples: vec![] };
        b.run_throughput("lane", 10.0, || 1 + 1);
        let dir = std::env::temp_dir().join("cfel_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        b.write_json(&path, "unit").unwrap();
        let j = Json::parse_file(&path).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "unit");
        let samples = j.get("samples").unwrap().as_arr().unwrap();
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].get("name").unwrap().as_str().unwrap(), "lane");
        assert_eq!(samples[0].get("iters").unwrap().as_usize().unwrap(), 2);
        assert!(samples[0].get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn throughput_label_present() {
        let mut b = Bench { warmup: 0, iters: 2, samples: vec![] };
        let s = b.run_throughput("tp", 100.0, || {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        assert!(s.report().contains("items/s"));
    }
}
