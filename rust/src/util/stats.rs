//! Summary statistics shared by metrics, the bench harness, and the
//! coordinator's round accounting.

/// Sum per-device SGD steps across a round's edge phases into one
/// `(device, total_steps)` list in ascending device order — the Eq. 8
/// workload input. Shared by the plan interpreter and the frozen legacy
/// round loop (formerly lived in `coordinator/cefedavg.rs`).
pub fn merge_steps(raw: Vec<(usize, usize)>) -> Vec<(usize, usize)> {
    let mut map = std::collections::BTreeMap::new();
    for (dev, s) in raw {
        *map.entry(dev).or_insert(0usize) += s;
    }
    map.into_iter().collect()
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile of a sample via linear interpolation (q in [0, 1]).
/// Sorts a copy; fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Format a duration in seconds with an adaptive unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.2} h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_steps_sums_per_device() {
        let merged = merge_steps(vec![(1, 3), (0, 2), (1, 4)]);
        assert_eq!(merged, vec![(0, 2), (1, 7)]);
        assert!(merge_steps(Vec::new()).is_empty());
    }

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn welford_degenerate() {
        let mut w = Welford::default();
        assert_eq!(w.variance(), 0.0);
        w.push(3.0);
        assert_eq!(w.mean(), 3.0);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn minmax_mean() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
        assert!((mean(&xs) - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn duration_units() {
        assert!(fmt_duration(5e-9).contains("ns"));
        assert!(fmt_duration(5e-5).contains("µs"));
        assert!(fmt_duration(5e-2).contains("ms"));
        assert!(fmt_duration(5.0).contains(" s"));
        assert!(fmt_duration(600.0).contains("min"));
        assert!(fmt_duration(100_000.0).contains(" h"));
    }
}
