//! From-scratch substrates for the offline build.
//!
//! The vendored crate set only carries the `xla` crate's closure, so the
//! usual ecosystem dependencies are implemented here and unit-tested in
//! place (see DESIGN.md §1):
//!
//! * [`rng`] — deterministic splittable PRNG (SplitMix64 core) with
//!   normal / Dirichlet / shuffle sampling (replaces `rand`).
//! * [`json`] — JSON parser + writer for the artifact manifest, configs
//!   and results (replaces `serde_json`).
//! * [`cli`] — flag parser for the binary and examples (replaces `clap`).
//! * [`threadpool`] — persistent worker-pool `parallel_map` (replaces
//!   `rayon`).
//! * [`stats`] — summary statistics used by metrics and the bench harness.
//! * [`bench`] — micro-benchmark harness behind `cargo bench`
//!   (`harness = false` targets; replaces `criterion`).
//! * [`proptest`] — seeded property-testing helper (replaces `proptest`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
