//! Scoped data-parallel helper (replaces `rayon` in the offline build).
//!
//! The coordinator's only parallel pattern is "run the same closure over a
//! work list of device indices" (local training within a round), so the
//! abstraction is a single [`parallel_map`] built on `std::thread::scope`
//! with a shared atomic work queue — no channels, no per-item spawn cost.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default: respects
/// `CFEL_THREADS`, otherwise `available_parallelism`, clamped to the job.
pub fn default_threads(jobs: usize) -> usize {
    let hw = std::env::var("CFEL_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    hw.clamp(1, jobs.max(1))
}

/// Apply `f(i)` for every `i in 0..n` on up to `threads` workers and return
/// the results in index order. `f` must be `Sync` (it is shared, not
/// cloned); captured state must be thread-safe.
///
/// With `threads <= 1` everything runs inline on the caller's thread — the
/// mode used by the PJRT backend, whose executables are not `Send`.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }

    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let results_ptr = SendPtr(results.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let f = &f;
            let next = &next;
            let results_ptr = &results_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = f(i);
                // SAFETY: each index i is claimed exactly once by exactly
                // one worker (fetch_add), and the vec outlives the scope.
                unsafe {
                    *results_ptr.0.add(i) = Some(val);
                }
            });
        }
    });

    results
        .into_iter()
        .map(|r| r.expect("worker failed to fill slot"))
        .collect()
}

/// Wrapper making a raw pointer Sync for the disjoint-index write pattern.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_order() {
        let out = parallel_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_inline() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_is_empty() {
        let out: Vec<usize> = parallel_map(0, 8, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn every_item_processed_exactly_once() {
        let counter = AtomicU64::new(0);
        let n = 1000;
        let out = parallel_map(n, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), n as u64);
        assert_eq!(out.len(), n);
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn default_threads_clamps() {
        assert_eq!(default_threads(1), 1);
        assert!(default_threads(1_000_000) >= 1);
    }
}
