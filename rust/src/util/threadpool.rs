//! Persistent data-parallel worker pool (replaces `rayon` in the offline
//! build).
//!
//! The system's only parallel pattern is "run the same closure over a work
//! list" — device training within a round, per-cluster event-shard drains
//! (`netsim::event`), per-cluster eval — so the abstraction stays a single
//! [`parallel_map`]. Earlier revisions spawned `std::thread::scope` workers
//! per call; at one call per edge phase that cost a thread spawn/join storm
//! every round. Workers are now a process-wide pool of persistent threads
//! that park on a condvar between jobs, so steady-state rounds pay one
//! mutex/condvar handshake per `parallel_map` instead of `threads` spawns.
//! Persistent workers are also what make the event engine's thread-local
//! phase scratch effective: warm buffers survive from round to round.
//!
//! Determinism: work item `i` writes its result into slot `i` of a
//! pre-sized buffer and the buffer is returned in index order, so which
//! worker computes which index never influences the output (see
//! docs/DETERMINISM.md). With `threads <= 1` everything runs inline on the
//! caller's thread — the mode used by the PJRT backend, whose executables
//! are not `Send`.
//!
//! Scheduling rules that keep the single job slot deadlock-free:
//! - a nested `parallel_map` (called from inside a work item) runs inline;
//! - a `parallel_map` submitted while another thread's job occupies the
//!   slot runs inline (concurrent test binaries hit this; results are
//!   index-ordered either way, so determinism is unaffected);
//! - a panicking work item is caught on the worker, counted as done so the
//!   submitter never blocks forever, and re-raised on the submitting
//!   thread once the job completes.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of worker threads to use by default: respects
/// `CFEL_THREADS`, otherwise `available_parallelism`, clamped to the job.
pub fn default_threads(jobs: usize) -> usize {
    let hw = std::env::var("CFEL_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    hw.clamp(1, jobs.max(1))
}

/// Type-erased pointer to the submitter's stack-held work closure: the
/// data pointer plus a monomorphized trampoline that calls it. Valid only
/// while the submitting `parallel_map` frame is alive — which the
/// completion protocol guarantees whenever the pointer is dereferenced
/// (see the safety notes on [`Job`]).
#[derive(Clone, Copy)]
struct TaskPtr {
    data: *const (),
    call: fn(*const (), usize),
}

unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

fn task_ptr_of<F: Fn(usize) + Sync>(f: &F) -> TaskPtr {
    fn call<F: Fn(usize) + Sync>(data: *const (), i: usize) {
        // SAFETY: `data` points at a live `F`. Items are only claimable
        // while the submitter is blocked inside `parallel_map` (it waits
        // for `done == n` before returning), so the closure outlives
        // every call made through this pointer.
        unsafe { (*(data.cast::<F>()))(i) }
    }
    TaskPtr { data: (f as *const F).cast::<()>(), call: call::<F> }
}

/// One `parallel_map` invocation, shared between the submitting thread
/// and the pool workers.
///
/// Lifecycle: the submitter publishes the job in the pool's single slot,
/// participates in the claim loop itself, then blocks until `done == n`
/// and retires the slot. Workers that grab the `Arc` late (after all
/// items are claimed) only read atomics and exit — they never touch
/// `task` — so the raw closure pointer is dereferenced strictly within
/// the submitter's stack frame.
struct Job {
    /// Next unclaimed work-item index (fetch_add claim ticket).
    next: AtomicUsize,
    /// Completed work items; the submitter returns at `done == n`.
    done: AtomicUsize,
    n: usize,
    /// Pool workers allowed to join (the submitter is the `+1`-th hand).
    max_workers: usize,
    /// Workers that joined so far (concurrency cap bookkeeping).
    joined: AtomicUsize,
    /// Set when any work item panicked; re-raised by the submitter.
    panicked: AtomicBool,
    task: TaskPtr,
}

impl Job {
    /// Claim-and-run loop shared by pool workers and the submitter.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            // A panicking item must still count as done, or the submitter
            // would wait forever; the flag re-raises it there.
            if catch_unwind(AssertUnwindSafe(|| (self.task.call)(self.task.data, i))).is_err() {
                self.panicked.store(true, Ordering::Release);
            }
            // Release pairs with the submitter's Acquire in `is_done`:
            // observing `done == n` implies every result write is visible.
            self.done.fetch_add(1, Ordering::Release);
        }
    }

    fn is_done(&self) -> bool {
        self.done.load(Ordering::Acquire) >= self.n
    }
}

struct PoolState {
    /// The currently published job, if any. One at a time by design:
    /// concurrent submitters fall back to inline execution.
    job: Option<Arc<Job>>,
    /// Monotone publication id so a worker never re-enters a job it
    /// already left (the slot may still hold it while the submitter
    /// drains stragglers).
    seq: u64,
    /// Worker threads spawned so far; grown on demand, never reaped.
    workers: usize,
}

struct Pool {
    state: Mutex<PoolState>,
    /// Wakes parked workers when a job is published.
    work_cv: Condvar,
    /// Wakes the submitter when the last work item completes.
    done_cv: Condvar,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState { job: None, seq: 0, workers: 0 }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
    })
}

thread_local! {
    /// True on pool workers (always) and on a submitter inside its own
    /// claim loop: a nested `parallel_map` sees it and runs inline
    /// instead of deadlocking on the single job slot.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

/// Body of every persistent worker: park until a job with a new `seq`
/// appears, join it (unless fully staffed), drain claims, notify the
/// submitter if the job is complete, park again. Workers live for the
/// process — the pool is process-wide state, like the thread-locals it
/// keeps warm.
fn worker_loop() {
    let pool = pool();
    IN_POOL_JOB.with(|f| f.set(true));
    let mut last_seq = 0u64;
    loop {
        let (job, seq) = {
            let mut st = pool.state.lock().expect("pool mutex");
            loop {
                match (&st.job, st.seq) {
                    (Some(j), s) if s != last_seq => break (Arc::clone(j), s),
                    _ => st = pool.work_cv.wait(st).expect("pool mutex"),
                }
            }
        };
        last_seq = seq;
        // Concurrency cap: at most `max_workers` pool hands per job
        // (plus the submitter), so CFEL_THREADS stays an upper bound on
        // the job's parallelism even when the pool has grown larger.
        if job.joined.fetch_add(1, Ordering::Relaxed) < job.max_workers {
            job.work();
            if job.is_done() {
                // The last hand out notifies under the lock so the
                // submitter's wait cannot miss it.
                let _guard = pool.state.lock().expect("pool mutex");
                pool.done_cv.notify_all();
            }
        }
    }
}

/// Apply `f(i)` for every `i in 0..n` on up to `threads` workers (the
/// caller's thread plus `threads - 1` persistent pool workers) and return
/// the results in index order. `f` must be `Sync` (it is shared, not
/// cloned); captured state must be thread-safe.
///
/// With `threads <= 1` everything runs inline on the caller's thread — the
/// mode used by the PJRT backend, whose executables are not `Send`.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 || IN_POOL_JOB.with(|g| g.get()) {
        return (0..n).map(f).collect();
    }

    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let results_ptr = SendPtr(results.as_mut_ptr());
    let runner = move |i: usize| {
        let val = f(i);
        // SAFETY: each index is claimed exactly once (fetch_add ticket),
        // so disjoint slots never alias, and `results` outlives every
        // write — the function only returns after `done == n`.
        unsafe {
            *results_ptr.0.add(i) = Some(val);
        }
    };

    let job = Arc::new(Job {
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        n,
        max_workers: threads - 1,
        joined: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
        task: task_ptr_of(&runner),
    });

    let pool = pool();
    let published = {
        let mut st = pool.state.lock().expect("pool mutex");
        if st.job.is_some() {
            false
        } else {
            while st.workers < threads - 1 {
                st.workers += 1;
                std::thread::Builder::new()
                    .name(format!("cfel-pool-{}", st.workers))
                    .spawn(worker_loop)
                    .expect("spawn pool worker");
            }
            st.seq = st.seq.wrapping_add(1);
            st.job = Some(Arc::clone(&job));
            pool.work_cv.notify_all();
            true
        }
    };

    if !published {
        // Another thread's job occupies the slot: run inline (same
        // index-ordered writes, no pool involvement, no deadlock).
        for i in 0..n {
            runner(i);
        }
    } else {
        // Participate in our own job, then wait out any straggling claims
        // still executing on pool workers and retire the slot.
        IN_POOL_JOB.with(|g| g.set(true));
        job.work();
        IN_POOL_JOB.with(|g| g.set(false));
        let mut st = pool.state.lock().expect("pool mutex");
        while !job.is_done() {
            st = pool.done_cv.wait(st).expect("pool mutex");
        }
        st.job = None;
        drop(st);
        if job.panicked.load(Ordering::Acquire) {
            panic!("parallel_map: a work item panicked on a pool worker");
        }
    }

    results
        .into_iter()
        .map(|r| r.expect("worker failed to fill slot"))
        .collect()
}

/// Wrapper making a raw pointer Sync for the disjoint-index write pattern.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_order() {
        let out = parallel_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_inline() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_is_empty() {
        let out: Vec<usize> = parallel_map(0, 8, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn every_item_processed_exactly_once() {
        let counter = AtomicU64::new(0);
        let n = 1000;
        let out = parallel_map(n, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), n as u64);
        assert_eq!(out.len(), n);
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn default_threads_clamps() {
        assert_eq!(default_threads(1), 1);
        assert!(default_threads(1_000_000) >= 1);
    }

    #[test]
    fn pool_is_reused_across_calls() {
        // Steady-state shape: many parallel_map calls in sequence (one
        // per edge phase) against the same persistent pool.
        for round in 0..50 {
            let out = parallel_map(64, 4, |i| i + round);
            assert_eq!(out, (0..64).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn nested_calls_run_inline() {
        // A work item that itself calls parallel_map must not deadlock on
        // the single job slot; the inner call runs inline.
        let out = parallel_map(8, 4, |i| parallel_map(4, 4, move |j| i * 10 + j));
        for (i, inner) in out.iter().enumerate() {
            assert_eq!(inner, &(0..4).map(|j| i * 10 + j).collect::<Vec<_>>());
        }
    }

    #[test]
    fn concurrent_submitters_both_complete() {
        // Two OS threads submitting at once: one wins the job slot, the
        // other falls back inline. Both must return correct results.
        let run = || parallel_map(500, 4, |i| i * 3);
        let want: Vec<usize> = (0..500).map(|i| i * 3).collect();
        std::thread::scope(|scope| {
            let a = scope.spawn(run);
            let b = scope.spawn(run);
            assert_eq!(a.join().unwrap(), want);
            assert_eq!(b.join().unwrap(), want);
        });
    }

    #[test]
    fn panicking_item_propagates_without_deadlock() {
        let caught = std::panic::catch_unwind(|| {
            parallel_map(16, 4, |i| {
                if i == 7 {
                    panic!("item 7 failed");
                }
                i
            })
        });
        assert!(caught.is_err());
        // The pool must stay usable after a panicked job.
        let out = parallel_map(16, 4, |i| i);
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }
}
