//! Seeded property-testing helper (replaces `proptest` in the offline build).
//!
//! A property is a closure from a per-case [`Rng`] to `Result<(), String>`;
//! [`check`] runs it over many derived streams and reports the failing seed
//! so a failure is reproducible with `check_one`. No shrinking — cases are
//! generated small-biased instead (generators below favour boundary sizes),
//! which in practice localises failures as well for this codebase.

use crate::util::rng::Rng;

/// Number of cases per property (override with CFEL_PROPTEST_CASES).
pub fn default_cases() -> u64 {
    std::env::var("CFEL_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` independent streams derived from `seed`.
/// Panics with the failing case index + message on the first failure.
pub fn check<F>(name: &str, seed: u64, cases: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.split(case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed}): {msg}\n\
                 reproduce with util::proptest::check_one({name:?}, {seed}, {case}, prop)"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn check_one<F>(name: &str, seed: u64, case: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed).split(case);
    if let Err(msg) = prop(&mut rng) {
        panic!("property {name:?} case {case} (seed {seed}): {msg}");
    }
}

// ----- small-biased generators ---------------------------------------------

/// Integer in [lo, hi] biased toward the boundaries and small values.
pub fn int_biased(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    debug_assert!(lo <= hi);
    match rng.below(8) {
        0 => lo,
        1 => hi,
        2 => lo + (hi - lo).min(1),
        _ => lo + rng.below(hi - lo + 1),
    }
}

/// A vector of f32s with mixed magnitudes (incl. zeros and negatives).
pub fn vec_f32(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| match rng.below(10) {
            0 => 0.0,
            1 => rng.normal() * 1e3,
            2 => rng.normal() * 1e-3,
            _ => rng.normal(),
        })
        .collect()
}

/// Positive weights summing to 1.
pub fn simplex(rng: &mut Rng, len: usize) -> Vec<f64> {
    rng.dirichlet(1.0, len)
}

/// Assert helper producing the Result<(), String> shape properties use.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate-equality helper for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // count via interior mutability in a cell
        let counter = std::cell::Cell::new(0u64);
        check("trivial", 1, 32, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn failing_property_panics_with_case() {
        check("always-fails", 2, 8, |_| Err("boom".into()));
    }

    #[test]
    fn failing_case_is_reproducible() {
        // Find a failing case for a property that fails ~50% of the time,
        // then verify check_one reproduces the same failure.
        let root = Rng::new(77);
        let prop = |rng: &mut Rng| -> Result<(), String> {
            if rng.below(2) == 0 {
                Err("coin".into())
            } else {
                Ok(())
            }
        };
        let mut failing = None;
        for case in 0..64 {
            let mut rng = root.split(case);
            if prop(&mut rng).is_err() {
                failing = Some(case);
                break;
            }
        }
        let case = failing.expect("coin never failed in 64 cases");
        let mut rng = Rng::new(77).split(case);
        assert!(prop(&mut rng).is_err(), "not reproducible");
    }

    #[test]
    fn int_biased_hits_bounds() {
        let mut rng = Rng::new(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..200 {
            let v = int_biased(&mut rng, 3, 17);
            assert!((3..=17).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 17;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn simplex_sums_to_one() {
        let mut rng = Rng::new(6);
        let s = simplex(&mut rng, 7);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6));
        assert!(!close(1.0, 2.0, 1e-6));
        assert!(close(1e6, 1e6 + 1.0, 1e-5));
    }
}
