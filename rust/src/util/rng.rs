//! Deterministic, splittable pseudo-random number generation.
//!
//! The whole reproduction must be replayable from a single experiment seed:
//! every device, partitioner and data generator receives its own
//! independent stream derived with [`Rng::split`] (SplitMix64-style stream
//! derivation over a xoshiro256** core), so adding devices or reordering
//! parallel work never perturbs other streams — the invariant the
//! determinism integration tests rely on.

/// Splittable PRNG: xoshiro256** core seeded through SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start in the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    /// Derive an independent stream labelled by `stream`.
    ///
    /// Used to give each device / cluster / subsystem its own generator:
    /// `rng.split(device_id)` is stable no matter how many other streams
    /// exist or in which order they are consumed.
    pub fn split(&self, stream: u64) -> Rng {
        let mut sm = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47)
            ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Next raw 64-bit value (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes:
        // 128-bit multiply keeps modulo bias negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call, cache dropped
    /// for stream-stability under splitting).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Gamma(shape k, scale 1) — Marsaglia–Tsang, used for Dirichlet draws.
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            // Boosting trick: Gamma(k) = Gamma(k+1) * U^(1/k).
            let u = self.f64().max(1e-300);
            return self.gamma(k + 1.0) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// A Dirichlet(alpha * 1_k) draw of length `k` (Hsu et al. [41] splits).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Draw an index from an (unnormalised) non-negative weight vector.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_independent_of_consumption() {
        let root = Rng::new(7);
        let mut s1 = root.split(3);
        let first = s1.next_u64();
        // Consuming another stream must not alter stream 3.
        let mut s2 = root.split(9);
        let _ = s2.next_u64();
        let mut s1b = root.split(3);
        assert_eq!(first, s1b.next_u64());
    }

    #[test]
    fn split_streams_differ_by_label() {
        let root = Rng::new(7);
        let a = root.split(0).next_u64();
        let b = root.split(1).next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(123);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentration_matters() {
        let mut r = Rng::new(3);
        let d = r.dirichlet(0.5, 10);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(d.iter().all(|&v| v >= 0.0));
        // Small alpha ⇒ sparse draws; large alpha ⇒ near-uniform.
        let sparse: f64 = (0..200)
            .map(|i| {
                let mut rr = Rng::new(100 + i);
                *rr.dirichlet(0.1, 10)
                    .iter()
                    .max_by(|a, b| a.partial_cmp(b).unwrap())
                    .unwrap()
            })
            .sum::<f64>()
            / 200.0;
        let dense: f64 = (0..200)
            .map(|i| {
                let mut rr = Rng::new(300 + i);
                *rr.dirichlet(100.0, 10)
                    .iter()
                    .max_by(|a, b| a.partial_cmp(b).unwrap())
                    .unwrap()
            })
            .sum::<f64>()
            / 200.0;
        assert!(sparse > dense + 0.2, "sparse {sparse} dense {dense}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gamma(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(2);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(4);
        let picks = r.choose(100, 10);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        assert!(picks.iter().all(|&p| p < 100));
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(8);
        let w = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted(&w), 2);
        }
        let w2 = [1.0, 3.0];
        let ones = (0..10_000).filter(|_| r.weighted(&w2) == 1).count();
        let frac = ones as f64 / 10_000.0;
        assert!((frac - 0.75).abs() < 0.03, "frac {frac}");
    }
}
