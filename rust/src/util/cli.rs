//! Tiny command-line flag parser (replaces `clap` in the offline build).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generates a usage string from the declared options.

use std::collections::BTreeMap;

/// Declarative description of one flag.
#[derive(Debug, Clone)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub boolean: bool,
}

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    eprintln!("invalid --{name} value {v:?}; using {default}");
                    default
                })
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    eprintln!("invalid --{name} value {v:?}; using {default}");
                    default
                })
            })
            .unwrap_or(default)
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some(""))
    }
}

/// A declared command with flags; parses and validates argv.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<Flag>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, flags: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, boolean: false });
        self
    }

    pub fn flag_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.flags.push(Flag { name, help, default: Some(default), boolean: false });
        self
    }

    pub fn bool_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, boolean: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for f in &self.flags {
            let val = if f.boolean { "" } else { " <value>" };
            let def = f
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{val}\n      {}{def}\n", f.name, f.help));
        }
        s.push_str("  --help\n      print this message\n");
        s
    }

    /// Parse an argv slice (excluding the program/subcommand name).
    /// Returns Err(usage) on `--help` or an unknown/malformed flag.
    pub fn parse(&self, argv: &[String]) -> std::result::Result<Args, String> {
        let mut args = Args::default();
        for f in &self.flags {
            if let Some(d) = f.default {
                args.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let flag = self
                    .flags
                    .iter()
                    .find(|f| f.name == key)
                    .ok_or_else(|| format!("unknown flag --{key}\n\n{}", self.usage()))?;
                let value = if flag.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| format!("--{key} needs a value"))?
                };
                args.values.insert(key.to_string(), value);
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .flag_default("rounds", "10", "number of rounds")
            .flag("model", "model name")
            .bool_flag("verbose", "chatty output")
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("rounds", 0), 10);
        assert_eq!(a.get("model"), None);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd()
            .parse(&argv(&["--rounds", "5", "--model=cnn", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("rounds", 0), 5);
        assert_eq!(a.get("model"), Some("cnn"));
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&argv(&["pos1", "--rounds", "3", "pos2"])).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn unknown_flag_and_help_error() {
        assert!(cmd().parse(&argv(&["--nope"])).is_err());
        let usage = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(usage.contains("--rounds"));
        assert!(usage.contains("default: 10"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&argv(&["--model"])).is_err());
    }

    #[test]
    fn numeric_fallbacks() {
        let a = cmd().parse(&argv(&["--rounds", "abc"])).unwrap();
        assert_eq!(a.get_usize("rounds", 0), 0);
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }
}
