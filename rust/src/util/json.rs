//! Minimal JSON codec (parser + writer).
//!
//! Consumes `artifacts/manifest.json`, experiment config files and emits
//! results; replaces `serde_json`, which is absent from the offline vendor
//! set. Supports the full JSON grammar except for `\u` surrogate pairs
//! being passed through unvalidated (sufficient for our ASCII artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{CfelError, Result};

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (stable diffs for results files).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- constructors ---------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — builder use only).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn from_f64(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn from_usize(v: usize) -> Json {
        Json::Num(v as f64)
    }

    pub fn from_str_val(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    // ----- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| CfelError::Json(format!("missing key {key:?}"))),
            _ => Err(CfelError::Json(format!("not an object (key {key:?})"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            _ => Err(CfelError::Json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            return Err(CfelError::Json(format!("expected usize, got {v}")));
        }
        Ok(v as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(CfelError::Json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(CfelError::Json(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(CfelError::Json(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(CfelError::Json(format!("expected object, got {self:?}"))),
        }
    }

    // ----- parse ------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(CfelError::Json(format!(
                "trailing garbage at byte {}",
                p.i
            )));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)?;
        Json::parse(&text)
    }

    // ----- serialize ----------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, v: f64) {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err<T>(&self, msg: &str) -> Result<T> {
        Err(CfelError::Json(format!("{msg} at byte {}", self.i)))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            self.err(&format!("expected {word}"))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| CfelError::Json("invalid utf8 in number".into()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| CfelError::Json(format!("bad number {text:?}")))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| {
                                        CfelError::Json("bad \\u escape".into())
                                    })?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| {
                                CfelError::Json(format!("bad \\u escape {hex:?}"))
                            })?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| CfelError::Json("invalid utf8".into()))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ A ü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A ü");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let text = r#"{"models":{"mlp":{"n":3,"params":[{"shape":[2,3]}]}},"v":1.5}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn typed_accessors_error_cleanly() {
        let v = Json::parse(r#"{"n": 1.5, "s": "x"}"#).unwrap();
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("s").unwrap().as_f64().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_arr().is_err());
    }

    #[test]
    fn builder_and_integer_formatting() {
        let mut o = Json::obj();
        o.set("count", Json::from_usize(7))
            .set("name", Json::from_str_val("x"));
        let s = o.to_string();
        assert_eq!(s, r#"{"count":7,"name":"x"}"#);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = std::path::Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/manifest.json"
        ));
        if path.exists() {
            let v = Json::parse_file(path).unwrap();
            assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        }
    }
}
