//! Communication compression for model uploads (paper §2's
//! quantization/sparsification line of work [8, 24, 25], implemented as a
//! first-class extension: the paper lists compression as composable with
//! CE-FedAvg since only sums of model parameters are exchanged).
//!
//! A [`Compressor`] maps a flat model to a lossy, smaller representation;
//! the coordinator applies it to every device→edge upload and every
//! backhaul exchange, and the Eq. 8 simulator scales the transmitted bits
//! by [`Compressor::bits_per_value`]. The `ablation` experiment measures
//! the accuracy/latency trade-off.

use crate::error::{CfelError, Result};

/// A lossy model codec. `roundtrip` must be idempotent on its own output
/// (compressing an already-compressed model is a no-op) — the property
/// test below pins this.
#[derive(Debug, Clone, PartialEq)]
pub enum Compressor {
    /// Identity (no compression).
    None,
    /// Keep the top-`fraction` entries by magnitude, zero the rest
    /// (ATOMO-style sparsification [24]). Transmitted bits per original
    /// value ≈ fraction · (32 value + 32 index).
    TopK { fraction: f64 },
    /// Uniform symmetric quantization to `bits`-bit integers with a
    /// per-model scale (FedPAQ-style [25]).
    Quantize { bits: u32 },
}

impl Compressor {
    pub fn parse(s: &str) -> Result<Compressor> {
        // Every rejection quotes the grammar — a bad `--compression`
        // spec teaches its own syntax, whichever branch it died in.
        let bad = |detail: String| {
            CfelError::Config(format!(
                "{detail} (none | topk:<frac> | quantize:<bits>)"
            ))
        };
        if s == "none" {
            return Ok(Compressor::None);
        }
        if let Some(f) = s.strip_prefix("topk:") {
            let fraction: f64 = f
                .parse()
                .map_err(|_| bad(format!("bad topk fraction {f:?}")))?;
            if !(0.0 < fraction && fraction <= 1.0) {
                return Err(bad(format!("topk fraction {fraction} outside (0,1]")));
            }
            return Ok(Compressor::TopK { fraction });
        }
        if let Some(b) = s.strip_prefix("quantize:") {
            let bits: u32 = b
                .parse()
                .map_err(|_| bad(format!("bad quantize bits {b:?}")))?;
            if !(1..=16).contains(&bits) {
                return Err(bad(format!("quantize bits {bits} outside 1..=16")));
            }
            return Ok(Compressor::Quantize { bits });
        }
        Err(bad(format!("unknown compressor {s:?}")))
    }

    pub fn name(&self) -> String {
        match self {
            Compressor::None => "none".into(),
            Compressor::TopK { fraction } => format!("topk:{fraction}"),
            Compressor::Quantize { bits } => format!("quantize:{bits}"),
        }
    }

    /// Average transmitted bits per original f32 value (Eq. 8 scaling).
    pub fn bits_per_value(&self) -> f64 {
        match self {
            Compressor::None => 32.0,
            // value + index per surviving entry.
            Compressor::TopK { fraction } => fraction * 64.0,
            // codes + one f32 scale amortised away.
            Compressor::Quantize { bits } => *bits as f64,
        }
    }

    /// Apply the lossy round-trip in place (what the receiver would see).
    pub fn roundtrip(&self, x: &mut [f32]) {
        match self {
            Compressor::None => {}
            Compressor::TopK { fraction } => topk_inplace(x, *fraction),
            Compressor::Quantize { bits } => quantize_inplace(x, *bits),
        }
    }

    /// Compression ratio vs raw f32 (1.0 = uncompressed).
    pub fn ratio(&self) -> f64 {
        self.bits_per_value() / 32.0
    }
}

fn topk_inplace(x: &mut [f32], fraction: f64) {
    let n = x.len();
    let k = ((n as f64 * fraction).ceil() as usize).clamp(1, n);
    if k == n {
        return;
    }
    // Threshold via select_nth on magnitudes (O(n) average).
    let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
    let idx = n - k;
    mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
    let threshold = mags[idx];
    // Keep values strictly above threshold, plus enough at exactly the
    // threshold to reach k (deterministic: first-come order).
    let mut kept = x.iter().filter(|v| v.abs() > threshold).count();
    for v in x.iter_mut() {
        let mag = v.abs();
        if mag > threshold {
            continue;
        }
        if mag == threshold && kept < k {
            kept += 1;
            continue;
        }
        *v = 0.0;
    }
}

fn quantize_inplace(x: &mut [f32], bits: u32) {
    let levels = ((1u64 << bits) - 1) as f32; // e.g. 255 for 8 bits
    let max = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    if max == 0.0 {
        return;
    }
    let scale = max / (levels / 2.0);
    for v in x.iter_mut() {
        let q = (*v / scale).round().clamp(-(levels / 2.0), levels / 2.0);
        *v = q * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn noisy(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn parse_roundtrip_and_validation() {
        for c in [
            Compressor::None,
            Compressor::TopK { fraction: 0.1 },
            Compressor::Quantize { bits: 8 },
        ] {
            assert_eq!(Compressor::parse(&c.name()).unwrap(), c);
        }
        assert!(Compressor::parse("topk:0").is_err());
        assert!(Compressor::parse("topk:1.5").is_err());
        assert!(Compressor::parse("quantize:0").is_err());
        assert!(Compressor::parse("quantize:33").is_err());
        assert!(Compressor::parse("gzip").is_err());
    }

    #[test]
    fn every_parse_error_quotes_the_grammar() {
        // One probe per rejection branch: unparsable topk fraction,
        // out-of-range topk fraction, unparsable quantize bits,
        // out-of-range quantize bits, and an unknown compressor name.
        for bad in ["topk:zero", "topk:0", "quantize:many", "quantize:99", "gzip"] {
            let err = Compressor::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("(none | topk:<frac> | quantize:<bits>)"),
                "error for {bad:?} should quote the grammar: {err}"
            );
        }
    }

    #[test]
    fn none_is_identity() {
        let mut x = noisy(100, 1);
        let orig = x.clone();
        Compressor::None.roundtrip(&mut x);
        assert_eq!(x, orig);
        assert_eq!(Compressor::None.ratio(), 1.0);
    }

    #[test]
    fn topk_keeps_exactly_k_largest() {
        let mut x = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0, 0.0, -2.0];
        Compressor::TopK { fraction: 0.5 }.roundtrip(&mut x);
        let nonzero: Vec<f32> = x.iter().copied().filter(|&v| v != 0.0).collect();
        assert_eq!(nonzero.len(), 4);
        assert_eq!(nonzero, vec![-5.0, 3.0, 1.0, -2.0]);
    }

    #[test]
    fn topk_handles_ties_deterministically() {
        let mut x = vec![1.0f32; 10];
        Compressor::TopK { fraction: 0.3 }.roundtrip(&mut x);
        assert_eq!(x.iter().filter(|&&v| v != 0.0).count(), 3);
        // First three survive (first-come tie-break).
        assert_eq!(&x[..3], &[1.0, 1.0, 1.0]);
    }

    #[test]
    fn topk_idempotent() {
        let mut x = noisy(500, 2);
        let c = Compressor::TopK { fraction: 0.2 };
        c.roundtrip(&mut x);
        let once = x.clone();
        c.roundtrip(&mut x);
        assert_eq!(x, once);
    }

    #[test]
    fn quantize_bounds_error_by_half_step() {
        let mut x = noisy(1000, 3);
        let orig = x.clone();
        let bits = 8u32;
        Compressor::Quantize { bits }.roundtrip(&mut x);
        let max = orig.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let step = max / (((1u64 << bits) - 1) as f32 / 2.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() <= step / 2.0 + 1e-6, "{a} vs {b} (step {step})");
        }
    }

    #[test]
    fn quantize_idempotent_and_zero_safe() {
        let c = Compressor::Quantize { bits: 4 };
        let mut x = noisy(200, 4);
        c.roundtrip(&mut x);
        let once = x.clone();
        c.roundtrip(&mut x);
        for (a, b) in x.iter().zip(&once) {
            assert!((a - b).abs() < 1e-5);
        }
        let mut z = vec![0.0f32; 8];
        c.roundtrip(&mut z);
        assert_eq!(z, vec![0.0; 8]);
    }

    #[test]
    fn more_bits_less_error() {
        let orig = noisy(2000, 5);
        let err = |bits: u32| {
            let mut x = orig.clone();
            Compressor::Quantize { bits }.roundtrip(&mut x);
            x.iter()
                .zip(&orig)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        assert!(err(2) > err(4));
        assert!(err(4) > err(8));
    }

    #[test]
    fn bits_per_value_scaling() {
        assert_eq!(Compressor::None.bits_per_value(), 32.0);
        assert!((Compressor::TopK { fraction: 0.1 }.bits_per_value() - 6.4).abs() < 1e-12);
        assert_eq!(Compressor::Quantize { bits: 8 }.bits_per_value(), 8.0);
        assert!(Compressor::Quantize { bits: 8 }.ratio() < 1.0);
    }
}
