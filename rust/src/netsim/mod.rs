//! Wireless-network runtime model — the paper's Eq. 8 and §6.1 constants,
//! plus the discrete-event simulation engine that generalises them.
//!
//! The paper estimates training time analytically: per global round, the
//! delay is the slowest device's computation plus the communication of the
//! aggregation pattern of the algorithm in use. This module reproduces
//! that estimator exactly (unit tests pin the closed forms), with the
//! paper's constants as defaults, optional device heterogeneity
//! (`c_k ~ U[0.5, 1]·capacity`), and an optional heavy-tail straggler
//! population ([`StragglerSpec`]).
//!
//! The closed form cannot express reporting deadlines, semi-synchronous
//! round closes, stragglers being dropped from aggregation, or per-device
//! timing. The [`event`] submodule simulates the same round as
//! `ComputeDone` / `UploadDone` / `BackhaulDone` / `RoundClose` events on
//! a virtual clock, with the round-close condition supplied by an
//! [`aggregation::policy::AggregationPolicy`](crate::aggregation::policy::AggregationPolicy);
//! [`LatencyEstimator`] is the coordinator-facing trait with both
//! implementations ([`ClosedFormEstimator`] — the fast default and
//! equivalence oracle — and [`EventDrivenEstimator`]). The [`calendar`]
//! submodule holds the engine's sharded calendar queues (one bucket
//! queue per cluster, merged deterministically at barriers) that carry
//! it to the million-device regime. See the [`event`] module docs for
//! the event model, cohort batching, tie-breaking order, and how close
//! policies interact with the Eq. 6 weight renormalization.

pub mod calendar;
pub mod event;

pub use calendar::{CalendarQueue, ShardedEventQueue};
pub use event::{
    ClosedFormEstimator, DeviceTiming, DeviceTimings, Event, EventDrivenEstimator, EventKind,
    EventQueue, LatencyEstimator, PhaseTiming, RoundTiming, UploadChannel,
};

use crate::error::{CfelError, Result};
use crate::util::rng::Rng;

/// Seconds in a round, per algorithm (see DESIGN.md §5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundLatency {
    pub compute_s: f64,
    pub upload_s: f64,
    pub backhaul_s: f64,
}

impl RoundLatency {
    pub fn total(&self) -> f64 {
        self.compute_s + self.upload_s + self.backhaul_s
    }
}

/// Network + device model with the paper's §6.1 constants as defaults.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// FLOPs to process one sample in one forward pass (manifest field).
    pub flops_per_sample: f64,
    /// Train step ≈ forward + backward ≈ 3× forward (standard estimate).
    pub train_flops_multiplier: f64,
    /// Mini-batch size (samples per SGD step).
    pub batch_size: usize,
    /// Model size in bits (32 · param_count).
    pub model_bits: f64,
    /// Per-device processing capability c_k in FLOP/s.
    pub device_flops: Vec<f64>,
    /// Per-device device→edge uplink override, bits/s (`None` = the
    /// shared `b_d2e`). Filled by explicit scenario capability profiles;
    /// honored by the event simulator, which models uploads per device
    /// (the closed-form Eq. 8 keeps the shared channel).
    pub device_uplink: Vec<Option<f64>>,
    /// Device→edge uplink, bits/s (paper: 10 Mbps).
    pub b_d2e: f64,
    /// Edge↔edge backhaul, bits/s (paper: 50 Mbps).
    pub b_e2e: f64,
    /// Device→cloud uplink, bits/s (paper: 1 Mbps).
    pub b_d2c: f64,
    /// Masked-upload size in bits over `@masked` channels (secure
    /// aggregation ships one u64 word per parameter). 0 = lossless /
    /// secagg off: masked uploads cost exactly `model_bits` and the
    /// mask compute term vanishes — the degenerate mode the equivalence
    /// tests pin bitwise.
    pub secagg_upload_bits: f64,
    /// FLOPs to draw + add one pairwise PRG mask word (per pair, per
    /// word): one xoshiro step plus the wrapping add.
    pub secagg_prg_flops: f64,
    /// FLOPs to fixed-point-encode one parameter (clamp, scale, round,
    /// widen, weight-multiply).
    pub secagg_encode_flops: f64,
    /// Participant-set size the *closed-form* estimator charges mask
    /// generation for (the event engine uses each phase's actual
    /// cohort size). Set by the coordinator from the expected
    /// per-cluster participant count.
    pub secagg_group_size: f64,
}

/// iPhone X processing capacity used by the paper (FLOP/s).
pub const IPHONE_X_FLOPS: f64 = 691.2e9;
pub const MBPS: f64 = 1e6;

/// Heavy-tail straggler model layered on top of the paper's `U[0.5,1]`
/// heterogeneity: a `fraction` of the fleet runs `slowdown`× slower
/// (thermal throttling, background load, an effectively stalled device).
/// Parsed from `<fraction>:<slowdown>`, e.g. `0.1:50`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerSpec {
    /// Fraction of devices affected, in (0, 1].
    pub fraction: f64,
    /// Capacity divisor for affected devices, ≥ 1.
    pub slowdown: f64,
}

impl StragglerSpec {
    pub fn parse(s: &str) -> Result<StragglerSpec> {
        let bad = || {
            CfelError::Config(format!(
                "invalid straggler spec {s:?} (expected <fraction>:<slowdown>, e.g. 0.1:50)"
            ))
        };
        let (f, sl) = s.split_once(':').ok_or_else(bad)?;
        let spec = StragglerSpec {
            fraction: f.parse().map_err(|_| bad())?,
            slowdown: sl.parse().map_err(|_| bad())?,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn name(&self) -> String {
        format!("{}:{}", self.fraction, self.slowdown)
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0 < self.fraction && self.fraction <= 1.0) {
            return Err(CfelError::Config(format!(
                "straggler fraction {} outside (0,1]",
                self.fraction
            )));
        }
        if !(self.slowdown >= 1.0 && self.slowdown.is_finite()) {
            return Err(CfelError::Config(format!(
                "straggler slowdown {} must be >= 1",
                self.slowdown
            )));
        }
        Ok(())
    }
}

impl NetworkModel {
    /// Homogeneous fleet with the paper's constants.
    pub fn paper_defaults(
        n_devices: usize,
        flops_per_sample: f64,
        batch_size: usize,
        param_count: usize,
    ) -> NetworkModel {
        NetworkModel {
            flops_per_sample,
            train_flops_multiplier: 3.0,
            batch_size,
            model_bits: 32.0 * param_count as f64,
            device_flops: vec![IPHONE_X_FLOPS; n_devices],
            device_uplink: vec![None; n_devices],
            b_d2e: 10.0 * MBPS,
            b_e2e: 50.0 * MBPS,
            b_d2c: 1.0 * MBPS,
            secagg_upload_bits: 0.0,
            secagg_prg_flops: 24.0,
            secagg_encode_flops: 8.0,
            secagg_group_size: 0.0,
        }
    }

    /// Seconds device `k` spends fixed-point-encoding and masking one
    /// upload for a secure-aggregation phase with `group_size`
    /// participants: every parameter word is encoded once and masked
    /// once per *other* participant. Exactly 0 when secagg is off or
    /// lossless (`secagg_upload_bits == 0`), so plain runs charge plain
    /// costs bit for bit.
    pub fn mask_seconds(&self, device: usize, group_size: usize) -> f64 {
        if self.secagg_upload_bits == 0.0 {
            return 0.0;
        }
        let words = self.secagg_upload_bits / 64.0;
        let pairs = group_size.saturating_sub(1) as f64;
        (self.secagg_encode_flops + pairs * self.secagg_prg_flops) * words
            / self.device_flops[device]
    }

    /// Draw heterogeneous device capacities c_k ~ U[lo, 1]·capacity, in
    /// place (no fleet-sized clone; same RNG stream as
    /// [`NetworkModel::with_heterogeneity`]).
    pub fn apply_heterogeneity(&mut self, lo_fraction: f64, rng: &Rng) {
        let mut r = rng.split(0xBEEF);
        for c in &mut self.device_flops {
            *c = IPHONE_X_FLOPS * r.uniform(lo_fraction as f32, 1.0) as f64;
        }
    }

    /// Draw heterogeneous device capacities c_k ~ U[lo, 1]·capacity.
    pub fn with_heterogeneity(mut self, lo_fraction: f64, rng: &Rng) -> NetworkModel {
        self.apply_heterogeneity(lo_fraction, rng);
        self
    }

    /// Slow down a deterministic straggler subset of the fleet, in place
    /// (same RNG stream as [`NetworkModel::with_stragglers`]).
    pub fn apply_stragglers(&mut self, spec: StragglerSpec, rng: &Rng) {
        let n = self.device_flops.len();
        let count = ((n as f64 * spec.fraction).ceil() as usize).clamp(1, n);
        let mut r = rng.split(0x57A6);
        for slot in r.choose(n, count) {
            self.device_flops[slot] /= spec.slowdown;
        }
    }

    /// Slow down a deterministic straggler subset of the fleet.
    pub fn with_stragglers(mut self, spec: StragglerSpec, rng: &Rng) -> NetworkModel {
        self.apply_stragglers(spec, rng);
        self
    }

    /// Seconds for one SGD step on device k (workload C / c_k in Eq. 8).
    pub fn step_seconds(&self, device: usize) -> f64 {
        let c = self.flops_per_sample
            * self.train_flops_multiplier
            * self.batch_size as f64;
        c / self.device_flops[device]
    }

    /// max_k over a device subset of `steps_per_device[k] · C / c_k` —
    /// the straggler term of Eq. 8 (devices in a round run in parallel).
    pub fn compute_seconds(&self, device_steps: &[(usize, usize)]) -> f64 {
        device_steps
            .iter()
            .map(|&(dev, steps)| steps as f64 * self.step_seconds(dev))
            .fold(0.0, f64::max)
    }

    /// CE-FedAvg global round (Eq. 8):
    /// `max_k qτ·C/c_k + q·W/b_d2e + π·W/b_e2e`.
    pub fn ce_fedavg_round(
        &self,
        device_steps: &[(usize, usize)],
        q: usize,
        pi: usize,
    ) -> RoundLatency {
        RoundLatency {
            compute_s: self.compute_seconds(device_steps),
            upload_s: q as f64 * self.model_bits / self.b_d2e,
            backhaul_s: pi as f64 * self.model_bits / self.b_e2e,
        }
    }

    /// Cloud FedAvg global round: one device→cloud upload.
    pub fn fedavg_round(&self, device_steps: &[(usize, usize)]) -> RoundLatency {
        RoundLatency {
            compute_s: self.compute_seconds(device_steps),
            upload_s: self.model_bits / self.b_d2c,
            backhaul_s: 0.0,
        }
    }

    /// Hier-FAvg global round: q−1 edge uploads + 1 cloud upload (§6.1
    /// baseline adaptation).
    pub fn hier_favg_round(&self, device_steps: &[(usize, usize)], q: usize) -> RoundLatency {
        RoundLatency {
            compute_s: self.compute_seconds(device_steps),
            upload_s: (q.saturating_sub(1)) as f64 * self.model_bits / self.b_d2e
                + self.model_bits / self.b_d2c,
            backhaul_s: 0.0,
        }
    }

    /// Local-Edge global round: q edge uploads, no backhaul.
    pub fn local_edge_round(&self, device_steps: &[(usize, usize)], q: usize) -> RoundLatency {
        RoundLatency {
            compute_s: self.compute_seconds(device_steps),
            upload_s: q as f64 * self.model_bits / self.b_d2e,
            backhaul_s: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> NetworkModel {
        // 1 MFLOP/sample, batch 50, 1M params.
        NetworkModel::paper_defaults(4, 1e6, 50, 1_000_000)
    }

    #[test]
    fn step_seconds_closed_form() {
        let m = model();
        // C = 3 * 50 * 1e6 = 1.5e8 FLOPs; c = 691.2e9 ⇒ ~2.17e-4 s.
        let want = 1.5e8 / 691.2e9;
        assert!((m.step_seconds(0) - want).abs() < 1e-12);
    }

    #[test]
    fn compute_is_straggler_max() {
        let mut m = model();
        m.device_flops[2] = IPHONE_X_FLOPS / 2.0; // slow device
        let steps = [(0usize, 10usize), (1, 10), (2, 10), (3, 10)];
        let fast = 10.0 * m.step_seconds(0);
        let slow = 10.0 * m.step_seconds(2);
        assert!((m.compute_seconds(&steps) - slow).abs() < 1e-12);
        assert!(slow > fast);
    }

    #[test]
    fn eq8_ce_fedavg_closed_form() {
        let m = model();
        // Eq. 8 with q=8, τ→steps=16 per device, π=10.
        let steps: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let lat = m.ce_fedavg_round(&steps, 8, 10);
        let w = 32.0e6; // bits
        assert!((lat.upload_s - 8.0 * w / 10e6).abs() < 1e-9);
        assert!((lat.backhaul_s - 10.0 * w / 50e6).abs() < 1e-9);
        assert!((lat.compute_s - 16.0 * m.step_seconds(0)).abs() < 1e-12);
        assert!((lat.total() - (lat.compute_s + lat.upload_s + lat.backhaul_s)).abs() < 1e-12);
    }

    #[test]
    fn paper_ordering_ce_beats_cloud_per_round() {
        // With the paper's bandwidths the cloud upload (1 Mbps) dominates:
        // per global round FedAvg/Hier must be slower than CE-FedAvg.
        let m = model();
        let steps: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let ce = m.ce_fedavg_round(&steps, 8, 10).total();
        let cloud = m.fedavg_round(&steps).total();
        let hier = m.hier_favg_round(&steps, 8).total();
        let local = m.local_edge_round(&steps, 8).total();
        // Amusing constant coincidence: with q=8, π=10 and the paper's
        // bandwidths, q/b_d2e + π/b_e2e = 1/b_d2c exactly, so per-round
        // CE == FedAvg; CE's runtime win in Fig. 2 comes from needing
        // fewer rounds (and beats Hier per round outright).
        assert!(ce <= cloud + 1e-9, "ce {ce} cloud {cloud}");
        assert!(ce < hier, "ce {ce} hier {hier}");
        assert!(local < ce, "local {local} ce {ce}"); // no backhaul at all
        // With fewer gossip steps CE is strictly cheaper per round too.
        let ce5 = m.ce_fedavg_round(&steps, 8, 5).total();
        assert!(ce5 < cloud, "ce5 {ce5} cloud {cloud}");
    }

    #[test]
    fn hier_has_q_minus_1_edge_uploads() {
        let m = model();
        let steps = [(0usize, 4usize)];
        let lat = m.hier_favg_round(&steps, 8);
        let w = 32.0e6;
        assert!((lat.upload_s - (7.0 * w / 10e6 + w / 1e6)).abs() < 1e-9);
    }

    #[test]
    fn heterogeneity_in_range_and_deterministic() {
        let rng = Rng::new(4);
        let m = model().with_heterogeneity(0.5, &rng);
        for &c in &m.device_flops {
            assert!(c >= 0.5 * IPHONE_X_FLOPS - 1.0 && c <= IPHONE_X_FLOPS);
        }
        let m2 = model().with_heterogeneity(0.5, &Rng::new(4));
        assert_eq!(m.device_flops, m2.device_flops);
    }

    #[test]
    fn straggler_spec_parse_roundtrip_and_validation() {
        let s = StragglerSpec::parse("0.1:50").unwrap();
        assert_eq!(s, StragglerSpec { fraction: 0.1, slowdown: 50.0 });
        assert_eq!(StragglerSpec::parse(&s.name()).unwrap(), s);
        assert!(StragglerSpec::parse("0.1").is_err());
        assert!(StragglerSpec::parse("1.5:2").is_err());
        assert!(StragglerSpec::parse("0.5:0.2").is_err());
    }

    #[test]
    fn stragglers_slow_a_deterministic_subset() {
        let spec = StragglerSpec { fraction: 0.5, slowdown: 10.0 };
        let rng = Rng::new(7);
        let m = model().with_stragglers(spec, &rng);
        let slowed = m
            .device_flops
            .iter()
            .filter(|&&c| (c - IPHONE_X_FLOPS / 10.0).abs() < 1.0)
            .count();
        assert_eq!(slowed, 2); // ceil(0.5 * 4)
        let m2 = model().with_stragglers(spec, &Rng::new(7));
        assert_eq!(m.device_flops, m2.device_flops);
    }

    #[test]
    fn mask_seconds_zero_when_off_and_scales_with_group() {
        let mut m = model();
        assert_eq!(m.mask_seconds(0, 10), 0.0, "secagg off must cost nothing");
        m.secagg_upload_bits = 64.0 * 1_000_000.0; // one word per param
        let solo = m.mask_seconds(0, 1);
        let ten = m.mask_seconds(0, 10);
        assert!(solo > 0.0 && ten > solo);
        // encode once + 9 PRG pairs, 1e6 words, on the paper device.
        let want = (8.0 + 9.0 * 24.0) * 1e6 / IPHONE_X_FLOPS;
        assert!((ten - want).abs() < 1e-15, "ten {ten} want {want}");
    }

    #[test]
    fn bigger_model_costs_more_everywhere() {
        let small = NetworkModel::paper_defaults(2, 1e6, 50, 100_000);
        let big = NetworkModel::paper_defaults(2, 1e6, 50, 10_000_000);
        let steps = [(0usize, 4usize), (1, 4)];
        assert!(big.ce_fedavg_round(&steps, 2, 2).total()
            > small.ce_fedavg_round(&steps, 2, 2).total());
    }
}
