//! Calendar (bucket) event queues and their per-cluster sharding.
//!
//! The metropolitan regime — hundreds of clusters, up to a million
//! devices — makes one global `BinaryHeap` the event engine's bottleneck:
//! every `ComputeDone`/`UploadDone` of every cluster funnels through a
//! single `O(log n)` heap even though clusters never exchange events
//! inside an edge phase. This module replaces it with:
//!
//! - [`CalendarQueue`]: a bucket queue over the phase's time horizon.
//!   Events hash into fixed-width time buckets; only the bucket under the
//!   pop cursor is kept sorted (descending, so the minimum pops from the
//!   end), future buckets absorb pushes unsorted and are sorted once when
//!   the cursor reaches them. Pop order is exactly the global sorted
//!   `(time, kind, id)` order — see the invariant notes on
//!   [`CalendarQueue::schedule`] — so swapping the heap for the calendar
//!   is observationally invisible (pinned by the unit tests below and by
//!   `rust/tests/sharded_queue.rs` against the heap reference).
//! - [`ShardedEventQueue`]: one `CalendarQueue` per cluster. Within a
//!   phase each shard drains independently (clusters only interact at
//!   gossip/cloud barriers, where [`ShardedEventQueue::barrier_clock`]
//!   merges the shard clocks by max, ties toward the lowest shard).
//!   [`ShardedEventQueue::pop_merged`] exposes the deterministic global
//!   interleaving — ordered by the same `(time, kind, id)` tie-break,
//!   then lowest shard index — which the equivalence proptest compares
//!   against a single-heap run. Because shards are independent, the
//!   estimator drains each cluster's calendar on its own worker thread
//!   (`EventDrivenEstimator::simulate_phases` routes through
//!   `util::threadpool::parallel_map`); `ShardedEventQueue` remains the
//!   merged-view reference that the tests pin that parallel drain
//!   against.
//!
//! Determinism: nothing here consults wall-clock time, iteration order of
//! hashed containers, or thread identity. Bucket membership is a pure
//! function of the event timestamp, ties within a bucket resolve by the
//! total [`Event`] order, and the merged view breaks residual ties by
//! shard index. See `docs/DETERMINISM.md`.

use crate::netsim::event::Event;

/// Bucket queue over `[0, horizon]` with a monotone virtual clock.
///
/// The final bucket is the open overflow interval `[horizon, ∞)` so late
/// drains and generous timeouts never fall off the calendar.
#[derive(Debug)]
pub struct CalendarQueue {
    buckets: Vec<Vec<Event>>,
    /// Seconds per bucket; `∞` collapses the calendar to one bucket
    /// (degenerate horizons — empty phases — still behave).
    width_s: f64,
    /// Index of the bucket currently being drained. Buckets behind the
    /// cursor are empty forever; the cursor bucket is sorted descending.
    cursor: usize,
    clock_s: f64,
    processed: usize,
    len: usize,
}

impl CalendarQueue {
    /// A calendar sized for `expected_events` spread over `horizon_s`
    /// seconds. Both are hints: more events or later timestamps still
    /// work, they just share buckets (the last bucket catches everything
    /// past the horizon).
    pub fn new(horizon_s: f64, expected_events: usize) -> CalendarQueue {
        let mut q = CalendarQueue {
            buckets: Vec::new(),
            width_s: f64::INFINITY,
            cursor: 0,
            clock_s: 0.0,
            processed: 0,
            len: 0,
        };
        q.reset(horizon_s, expected_events);
        q
    }

    /// Restore a drained queue to the exact observable state of
    /// `CalendarQueue::new(horizon_s, expected_events)` while keeping the
    /// bucket allocations. The event engine's per-thread phase scratch
    /// reuses one calendar across phases this way, so steady-state rounds
    /// stop re-allocating bucket vectors (see `netsim::event`).
    pub fn reset(&mut self, horizon_s: f64, expected_events: usize) {
        let n_buckets = (expected_events / 4).clamp(1, 4096) + 1;
        for b in &mut self.buckets {
            b.clear();
        }
        self.buckets.resize_with(n_buckets, Vec::new);
        self.width_s = if horizon_s.is_finite() && horizon_s > 0.0 {
            horizon_s / (n_buckets - 1) as f64
        } else {
            f64::INFINITY
        };
        self.cursor = 0;
        self.clock_s = 0.0;
        self.processed = 0;
        self.len = 0;
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.clock_s
    }

    /// Events popped so far (the simulator-throughput metric).
    pub fn processed(&self) -> usize {
        self.processed
    }

    /// Events currently scheduled.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn bucket_of(&self, time_s: f64) -> usize {
        // f64→usize casts saturate, so past-horizon (and +∞) timestamps
        // land in the overflow bucket; width ∞ maps everything to 0.
        ((time_s / self.width_s) as usize).min(self.buckets.len() - 1)
    }

    /// Schedule an event; must not be in the virtual past.
    ///
    /// Invariant: every live event sits in a bucket `>= cursor`. An event
    /// whose natural bucket is behind the cursor (its timestamp is `>=
    /// clock` but earlier in the cursor bucket's range) is clamped into
    /// the cursor bucket, where the sorted insert restores its place in
    /// the global order; events in later buckets all carry later
    /// timestamps than anything clampable, so cross-bucket order holds.
    pub fn schedule(&mut self, ev: Event) {
        debug_assert!(
            ev.time_s >= self.clock_s,
            "event at {} scheduled before clock {}",
            ev.time_s,
            self.clock_s
        );
        let b = self.bucket_of(ev.time_s).max(self.cursor);
        if b == self.cursor {
            // The cursor bucket is sorted descending; keep it that way.
            let bucket = &mut self.buckets[b];
            let pos = bucket.partition_point(|e| *e > ev);
            bucket.insert(pos, ev);
        } else {
            self.buckets[b].push(ev);
        }
        self.len += 1;
    }

    /// The earliest scheduled event, without popping it.
    pub fn peek(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
            // Entering a new bucket: sort it once, descending, so the
            // minimum is at the end. Later pushes binary-insert.
            let c = self.cursor;
            self.buckets[c].sort_unstable_by(|a, b| b.cmp(a));
        }
        self.buckets[self.cursor].last().copied()
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<Event> {
        self.peek()?;
        let ev = self.buckets[self.cursor].pop().expect("peek saw an event");
        self.len -= 1;
        self.clock_s = ev.time_s;
        self.processed += 1;
        Some(ev)
    }
}

/// Per-cluster calendar shards with a deterministic merged view.
#[derive(Debug)]
pub struct ShardedEventQueue {
    shards: Vec<CalendarQueue>,
}

impl ShardedEventQueue {
    /// One shard per `(horizon_s, expected_events)` sizing hint.
    pub fn with_horizons(horizons: &[(f64, usize)]) -> ShardedEventQueue {
        ShardedEventQueue {
            shards: horizons
                .iter()
                .map(|&(h, n)| CalendarQueue::new(h, n))
                .collect(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard_mut(&mut self, shard: usize) -> &mut CalendarQueue {
        &mut self.shards[shard]
    }

    /// Schedule an event on one shard.
    pub fn schedule(&mut self, shard: usize, ev: Event) {
        self.shards[shard].schedule(ev);
    }

    /// Pop the globally earliest event across all shards: the usual
    /// `(time, kind, id)` order, residual ties broken toward the lowest
    /// shard index. This is the deterministic interleaving a single
    /// global heap would produce when every event id is globally unique
    /// (pinned by `rust/tests/sharded_queue.rs`).
    pub fn pop_merged(&mut self) -> Option<(usize, Event)> {
        let mut best: Option<(usize, Event)> = None;
        for (s, q) in self.shards.iter_mut().enumerate() {
            if let Some(ev) = q.peek() {
                let better = match best {
                    None => true,
                    Some((_, b)) => ev < b,
                };
                if better {
                    best = Some((s, ev));
                }
            }
        }
        let (s, _) = best?;
        let ev = self.shards[s].pop().expect("peek saw an event");
        Some((s, ev))
    }

    /// Barrier merge of the shard clocks: the latest shard time, ties
    /// toward the lowest shard index — the same fold the coordinator's
    /// `barrier_clocks` applies at gossip/cloud steps.
    pub fn barrier_clock(&self) -> f64 {
        let mut t = 0.0f64;
        for q in &self.shards {
            if q.now() > t {
                t = q.now();
            }
        }
        t
    }

    /// Total events popped across all shards.
    pub fn processed(&self) -> usize {
        self.shards.iter().map(|q| q.processed()).sum()
    }

    /// Events currently scheduled across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|q| q.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::event::{EventKind, EventQueue};
    use crate::util::rng::Rng;

    fn random_events(rng: &mut Rng, n: usize, horizon: f64) -> Vec<Event> {
        (0..n)
            .map(|id| {
                let kind = match rng.below(4) {
                    0 => EventKind::ComputeDone,
                    1 => EventKind::UploadDone,
                    2 => EventKind::BackhaulDone,
                    _ => EventKind::RoundClose,
                };
                // Coarse timestamps force plenty of exact ties.
                let time_s = (rng.f64() * horizon * 8.0).floor() / 8.0;
                Event { time_s, kind, id }
            })
            .collect()
    }

    #[test]
    fn calendar_pop_order_matches_heap() {
        let mut rng = Rng::new(42);
        for case in 0..50 {
            let n = 1 + (case % 40);
            let horizon = 10.0;
            let events = random_events(&mut rng, n, horizon);
            let mut cal = CalendarQueue::new(horizon, n);
            let mut heap = EventQueue::new();
            for &ev in &events {
                cal.schedule(ev);
                heap.schedule(ev);
            }
            loop {
                match (cal.pop(), heap.pop()) {
                    (None, None) => break,
                    (a, b) => assert_eq!(a, b, "case {case}"),
                }
            }
            assert_eq!(cal.processed(), n);
        }
    }

    #[test]
    fn interleaved_schedule_during_pops_stays_sorted() {
        // Pops trigger pushes at later times — the event-engine access
        // pattern — including times behind the cursor's bucket start
        // (clamped into the cursor bucket).
        let mut cal = CalendarQueue::new(8.0, 16);
        let mut heap = EventQueue::new();
        for id in 0..8 {
            let ev = Event {
                time_s: id as f64,
                kind: EventKind::ComputeDone,
                id,
            };
            cal.schedule(ev);
            heap.schedule(ev);
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            assert_eq!(a, b);
            let Some(ev) = a else { break };
            if ev.kind == EventKind::ComputeDone {
                let up = Event {
                    time_s: ev.time_s + 0.25,
                    kind: EventKind::UploadDone,
                    id: ev.id,
                };
                cal.schedule(up);
                heap.schedule(up);
            }
        }
        assert_eq!(cal.processed(), 16);
        assert!(cal.is_empty());
    }

    #[test]
    fn overflow_bucket_catches_past_horizon_events() {
        let mut cal = CalendarQueue::new(1.0, 4);
        cal.schedule(Event { time_s: 50.0, kind: EventKind::UploadDone, id: 1 });
        cal.schedule(Event { time_s: 0.5, kind: EventKind::ComputeDone, id: 0 });
        cal.schedule(Event { time_s: 9.0, kind: EventKind::UploadDone, id: 0 });
        assert_eq!(cal.pop().unwrap().time_s, 0.5);
        assert_eq!(cal.pop().unwrap().time_s, 9.0);
        assert_eq!(cal.pop().unwrap().time_s, 50.0);
        assert_eq!(cal.pop(), None);
        assert_eq!(cal.now(), 50.0);
    }

    #[test]
    fn degenerate_horizon_still_orders() {
        let mut cal = CalendarQueue::new(0.0, 0);
        cal.schedule(Event { time_s: 2.0, kind: EventKind::ComputeDone, id: 0 });
        cal.schedule(Event { time_s: 1.0, kind: EventKind::ComputeDone, id: 1 });
        assert_eq!(cal.pop().unwrap().id, 1);
        assert_eq!(cal.pop().unwrap().id, 0);
    }

    #[test]
    fn reset_matches_fresh_queue() {
        // Drain a queue, reset it to a different sizing, and check it
        // behaves exactly like a fresh one (same pops, counters zeroed).
        let mut rng = Rng::new(9);
        let mut recycled = CalendarQueue::new(3.0, 64);
        for ev in random_events(&mut rng, 64, 3.0) {
            recycled.schedule(ev);
        }
        while recycled.pop().is_some() {}
        recycled.reset(10.0, 24);
        let mut fresh = CalendarQueue::new(10.0, 24);
        assert_eq!(recycled.processed(), 0);
        assert_eq!(recycled.now(), 0.0);
        let events = random_events(&mut rng, 24, 10.0);
        for &ev in &events {
            recycled.schedule(ev);
            fresh.schedule(ev);
        }
        loop {
            match (recycled.pop(), fresh.pop()) {
                (None, None) => break,
                (a, b) => assert_eq!(a, b),
            }
        }
    }

    #[test]
    fn sharded_merge_matches_single_heap_with_unique_ids() {
        let mut rng = Rng::new(7);
        let shards_n = 5;
        let horizon = 4.0;
        let hints: Vec<(f64, usize)> = (0..shards_n).map(|_| (horizon, 8)).collect();
        let mut sharded = ShardedEventQueue::with_horizons(&hints);
        let mut heap = EventQueue::new();
        let mut id = 0usize;
        for s in 0..shards_n {
            for _ in 0..8 {
                let ev = Event {
                    time_s: (rng.f64() * horizon * 4.0).floor() / 4.0,
                    kind: EventKind::ComputeDone,
                    id,
                };
                id += 1;
                sharded.schedule(s, ev);
                heap.schedule(ev);
            }
        }
        assert_eq!(sharded.len(), 40);
        let mut popped = 0usize;
        while let Some((_, ev)) = sharded.pop_merged() {
            assert_eq!(Some(ev), heap.pop());
            popped += 1;
        }
        assert_eq!(heap.pop(), None);
        assert_eq!(popped, 40);
        assert_eq!(sharded.processed(), 40);
        assert!(sharded.is_empty());
    }

    #[test]
    fn barrier_clock_is_max_over_shards() {
        let mut sharded = ShardedEventQueue::with_horizons(&[(1.0, 2), (1.0, 2)]);
        sharded.schedule(0, Event { time_s: 0.5, kind: EventKind::ComputeDone, id: 0 });
        sharded.schedule(1, Event { time_s: 2.5, kind: EventKind::ComputeDone, id: 1 });
        while sharded.shard_mut(0).pop().is_some() {}
        while sharded.shard_mut(1).pop().is_some() {}
        assert_eq!(sharded.barrier_clock(), 2.5);
    }
}
