//! Discrete-event virtual-clock simulation of a CFEL round.
//!
//! The closed-form Eq. 8 estimator in the parent module collapses a global
//! round into three aggregate terms. This module simulates the same round
//! as *discrete events* on a virtual clock, which is what lets the system
//! express reporting deadlines, stragglers, semi-synchronous round closes,
//! and per-device timing heterogeneity that the closed form cannot.
//!
//! # Event model
//!
//! One edge phase of one cluster is simulated as follows: every
//! participating device `k` owes a [`EventKind::ComputeDone`] at
//! `steps_k · C / c_k` (its local SGD workload over its processing
//! capacity). Popping a `ComputeDone` schedules the matching
//! [`EventKind::UploadDone`] at `t + W / b` where `b` is the phase's
//! [`UploadChannel`] bandwidth — devices transmit on dedicated links, so
//! uploads overlap freely (the paper's model). The inter-cluster
//! aggregation of CE-FedAvg is simulated as π sequential
//! [`EventKind::BackhaulDone`] hops of `W / b_e2e` each (every edge of the
//! backhaul transmits concurrently within a hop).
//!
//! # The million-device engine: shards, cohorts, SoA
//!
//! At metropolitan scale (hundreds of clusters, up to 10⁶ devices) the
//! original one-event-per-device binary heap was the bottleneck. Three
//! rearchitectures keep the observable behaviour bit-identical (see
//! `docs/DETERMINISM.md` and `docs/ARCHITECTURE.md`) while collapsing the
//! asymptotics:
//!
//! - **Sharded calendar queues** ([`crate::netsim::calendar`]): each
//!   cluster's phase runs on its own bucket queue; shards never exchange
//!   events inside a phase and merge only at gossip/cloud barriers, by
//!   the same `(time, kind, id)` tie-break a global heap would apply.
//!   Because shards are independent,
//!   [`EventDrivenEstimator::simulate_phases`] drains each cluster's
//!   calendar on its own worker thread
//!   (`util::threadpool::parallel_map`) and merges results back in
//!   cluster order.
//! - **Cohort batching**: devices sharing a capability profile finish
//!   compute and upload at *exactly* the same f64 timestamps, so each
//!   such cohort schedules one `ComputeDone`/`UploadDone` pair carrying a
//!   member count; the close predicate is consulted per batch via
//!   [`AggregationPolicy::closes_within_batch`]. Because every close
//!   predicate is a function of the cumulative report count, and counts
//!   pass through 1..n in the same order either way, the first closing
//!   count — hence the close time, reason, and every verdict — is
//!   identical to the per-device simulation (pinned bitwise by the tests
//!   below). Per-device timestamps are expanded lazily from the cohort
//!   entry after the drain.
//! - **Struct-of-arrays timing state** ([`DeviceTimings`]): per-device
//!   compute/upload/finish/verdict columns instead of a `Vec` of structs,
//!   so million-row rounds stream through caches and accumulate without
//!   per-device allocation.
//! - **O(1) steady-state allocation**: every thread keeps a phase
//!   scratch (prepared-phase columns, cohort-key index, calendar queue)
//!   that survives from phase to phase — the worker pool's threads are
//!   persistent, so the scratch stays warm across rounds — and retired
//!   [`DeviceTimings`] column sets return to a bounded process-wide free
//!   list via [`DeviceTimings::recycle`], where [`DeviceTimings::acquire`]
//!   picks them up for the next phase.
//!
//! `events` counts are therefore *cohort-granular*: a homogeneous
//! 10⁴-device phase processes 2 queue events, not 2·10⁴.
//!
//! # Round-close policies
//!
//! When the phase stops accepting reports is decided by the configured
//! [`AggregationPolicy`]: the policy may arm one [`EventKind::RoundClose`]
//! timeout event, and is consulted after every `UploadDone` batch whether
//! the phase closes now (the full barrier closes on the last report,
//! semi-sync on the K-th). Events scheduled past the close still pop — the
//! *late-upload drain* — so every device's report time is known; reports
//! that missed the close carry the policy's verdict
//! ([`ReportVerdict::Late`] for semi-sync, [`ReportVerdict::Dropped`] for
//! the deadline) and the coordinator either folds them into a later
//! phase's aggregate with a staleness discount or discards them. See
//! `aggregation::policy` for the three policies and their semantics.
//!
//! # Tie-breaking and determinism
//!
//! Event order is `(time, kind, id)`: simultaneous events pop in
//! `ComputeDone < UploadDone < BackhaulDone < RoundClose` order, and
//! within a kind by ascending id (the cohort's first-seen position in the
//! phase's work list, which the coordinator builds in sorted participant
//! order). `RoundClose` ordering last means a report landing exactly at a
//! deadline/timeout still counts as on time, matching the strict
//! `finish > T_dl` drop rule of the closed analysis. Simulation inputs
//! are derived purely from the experiment seed, and each cluster's phase
//! simulation is a pure function of `(net, work, channel, policy)` —
//! shards never exchange events inside a phase — so draining the shards
//! on worker threads and merging the results by cluster index yields
//! event-driven timing — including which devices a policy drops or
//! defers — that is bit-identical for any `CFEL_THREADS` (pinned by
//! `rust/tests/determinism.rs` and the parallel-vs-sequential proptest
//! in `rust/tests/sharded_queue.rs`; see `docs/DETERMINISM.md`).
//!
//! # Deadlines and Eq. 6 renormalization
//!
//! Under [`aggregation::policy::DeadlineDrop`](crate::aggregation::policy::DeadlineDrop)
//! the phase ends at `min(T_dl, latest report)` — the edge server never
//! waits past the deadline — and a device whose `UploadDone` lands after
//! `T_dl` is excluded from the Eq. 6 intra-cluster average, which
//! renormalizes the surviving sample-count weights automatically (the
//! average is taken over survivors only). If *every* device of a cluster
//! misses the deadline the cluster skips aggregation and keeps its
//! previous edge model for that phase — the same contract semi-sync
//! applies when its timeout fires before any report.
//!
//! # Closed-form equivalence
//!
//! With homogeneous (or merely per-device-constant) workloads, full
//! participation and the full-barrier policy, summing the per-phase
//! barriers reproduces Eq. 8 exactly: `Σ_r max_k(steps·C/c_k) = max_k Σ_r`
//! when the slowest device is the same each phase, and uploads/backhaul
//! hops add up to the closed-form `q·W/b` and `π·W/b_e2e` terms
//! (`rust/tests/event_sim.rs` pins ≤1e-9 relative error for all four
//! algorithms). Under partial participation the two models legitimately
//! diverge: the closed form takes the max over *round-total* per-device
//! steps, while the event simulator charges every phase its own barrier —
//! the more faithful account.

use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Mutex;

use crate::aggregation::policy::{AggregationPolicy, CloseReason, ReportVerdict};
use crate::netsim::calendar::CalendarQueue;
use crate::netsim::{NetworkModel, RoundLatency};
use crate::plan::Plan;
use crate::util::threadpool::{default_threads, parallel_map};

/// Event types, listed in tie-break order (earlier kinds pop first at
/// equal timestamps).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A cohort of devices finished its local SGD steps for this edge
    /// phase.
    ComputeDone,
    /// A cohort's model reports arrived at their aggregation point.
    UploadDone,
    /// One inter-cluster gossip hop completed on the backhaul.
    BackhaulDone,
    /// The policy's timeout fired — the phase closes if it hasn't already.
    /// Ordered after `UploadDone` so a report landing exactly at the
    /// cutoff still counts as on time.
    RoundClose,
}

/// One scheduled occurrence on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Virtual time of the occurrence, seconds from the phase start.
    pub time_s: f64,
    pub kind: EventKind,
    /// Cohort id for compute/upload events; hop index for backhaul; 0 for
    /// the (unique) round-close timeout.
    pub id: usize,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then_with(|| self.kind.cmp(&other.kind))
            .then_with(|| self.id.cmp(&other.id))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Binary-heap event queue with a monotone virtual clock.
///
/// The single-queue reference implementation: the sharded calendar
/// engine in [`crate::netsim::calendar`] must pop in exactly this order
/// (`rust/tests/sharded_queue.rs` pins the equivalence). Still used
/// directly for the tiny backhaul simulation.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
    clock_s: f64,
    processed: usize,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.clock_s
    }

    /// Events popped so far (the simulator-throughput metric).
    pub fn processed(&self) -> usize {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule an event; must not be in the virtual past.
    pub fn schedule(&mut self, ev: Event) {
        debug_assert!(
            ev.time_s >= self.clock_s,
            "event at {} scheduled before clock {}",
            ev.time_s,
            self.clock_s
        );
        self.heap.push(std::cmp::Reverse(ev));
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?.0;
        self.clock_s = ev.time_s;
        self.processed += 1;
        Some(ev)
    }
}

/// Which uplink carries an edge phase's model reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UploadChannel {
    /// Device → edge server (CE-FedAvg, Local-Edge, Hier-FAvg edge rounds).
    DeviceEdge,
    /// Device → cloud (FedAvg; Hier-FAvg's final round of a global round).
    DeviceCloud,
    /// Device → edge server under secure aggregation (`edge(E)@masked`):
    /// same radio as [`UploadChannel::DeviceEdge`], but the payload is the
    /// fixed-point masked encoding (`net.secagg_upload_bits` on the air
    /// when nonzero) and each device pays the mask-generation compute
    /// ([`NetworkModel::mask_seconds`]) before its upload starts.
    DeviceEdgeMasked,
}

impl UploadChannel {
    pub fn bandwidth(self, net: &NetworkModel) -> f64 {
        match self {
            UploadChannel::DeviceEdge | UploadChannel::DeviceEdgeMasked => net.b_d2e,
            UploadChannel::DeviceCloud => net.b_d2c,
        }
    }

    /// Bandwidth the given device reports over: a per-device uplink
    /// override (scenario capability profiles) applies to the edge
    /// channels (masked or not); the cloud channel is always the shared
    /// `b_d2c`. With no override this is exactly
    /// [`UploadChannel::bandwidth`].
    pub fn device_bandwidth(self, net: &NetworkModel, device: usize) -> f64 {
        match self {
            UploadChannel::DeviceEdge | UploadChannel::DeviceEdgeMasked => net
                .device_uplink
                .get(device)
                .copied()
                .flatten()
                .unwrap_or(net.b_d2e),
            UploadChannel::DeviceCloud => net.b_d2c,
        }
    }

    /// Bits one report puts on the air over this channel: the (possibly
    /// compression-scaled) `model_bits`, except masked uploads ship the
    /// secagg encoding when one is configured. Lossless secagg keeps
    /// `secagg_upload_bits == 0`, so its masked phases charge exactly the
    /// plain payload — the bit-identity the degenerate mode pins.
    pub fn upload_bits(self, net: &NetworkModel) -> f64 {
        match self {
            UploadChannel::DeviceEdgeMasked if net.secagg_upload_bits > 0.0 => {
                net.secagg_upload_bits
            }
            _ => net.model_bits,
        }
    }
}

/// One device's simulated timing within an edge phase — the row view of
/// one [`DeviceTimings`] index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceTiming {
    /// Global device id.
    pub device: usize,
    /// Seconds of local compute (steps · C / c_k).
    pub compute_s: f64,
    /// Seconds of model upload (W / channel bandwidth).
    pub upload_s: f64,
    /// Report arrival, seconds from the phase start.
    pub finish_s: f64,
    /// How the report fared against the policy's close.
    pub verdict: ReportVerdict,
}

impl DeviceTiming {
    /// Discarded outright by the close policy (deadline-drop).
    pub fn dropped(&self) -> bool {
        self.verdict == ReportVerdict::Dropped
    }

    /// Missed the close but kept for a stale merge (semi-sync).
    pub fn late(&self) -> bool {
        self.verdict == ReportVerdict::Late
    }
}

/// Per-device timing state in struct-of-arrays layout: one column per
/// field, indexed by work-list slot (sorted participant order). At
/// million-device scale the columnar layout is what keeps verdict
/// classification and accumulation cache-resident; [`DeviceTimings::get`]
/// materializes a [`DeviceTiming`] row view on demand.
#[derive(Debug, Clone, Default)]
pub struct DeviceTimings {
    /// Global device id per slot.
    pub device: Vec<usize>,
    /// Seconds of local compute per slot.
    pub compute_s: Vec<f64>,
    /// Seconds of model upload per slot.
    pub upload_s: Vec<f64>,
    /// Report arrival per slot, seconds from the phase start.
    pub finish_s: Vec<f64>,
    /// Close-policy verdict per slot.
    pub verdict: Vec<ReportVerdict>,
}

impl DeviceTimings {
    pub fn with_capacity(n: usize) -> DeviceTimings {
        DeviceTimings {
            device: Vec::with_capacity(n),
            compute_s: Vec::with_capacity(n),
            upload_s: Vec::with_capacity(n),
            finish_s: Vec::with_capacity(n),
            verdict: Vec::with_capacity(n),
        }
    }

    pub fn len(&self) -> usize {
        self.device.len()
    }

    pub fn is_empty(&self) -> bool {
        self.device.is_empty()
    }

    /// Append one row.
    pub fn push(&mut self, t: DeviceTiming) {
        self.device.push(t.device);
        self.compute_s.push(t.compute_s);
        self.upload_s.push(t.upload_s);
        self.finish_s.push(t.finish_s);
        self.verdict.push(t.verdict);
    }

    /// Row view of slot `i`. Panics if out of range.
    pub fn get(&self, i: usize) -> DeviceTiming {
        DeviceTiming {
            device: self.device[i],
            compute_s: self.compute_s[i],
            upload_s: self.upload_s[i],
            finish_s: self.finish_s[i],
            verdict: self.verdict[i],
        }
    }

    /// Iterate row views in slot order.
    pub fn iter(&self) -> impl Iterator<Item = DeviceTiming> + '_ {
        (0..self.len()).map(|i| self.get(i))
    }

    /// Append all rows of `other`, column-wise.
    pub fn extend_from(&mut self, other: &DeviceTimings) {
        self.device.extend_from_slice(&other.device);
        self.compute_s.extend_from_slice(&other.compute_s);
        self.upload_s.extend_from_slice(&other.upload_s);
        self.finish_s.extend_from_slice(&other.finish_s);
        self.verdict.extend_from_slice(&other.verdict);
    }

    /// Take a cleared column set from the process-wide free list (or
    /// allocate a fresh one) with room for `n` rows. Pair with
    /// [`DeviceTimings::recycle`] so steady-state rounds reuse the same
    /// capacity instead of growing new columns every phase.
    pub fn acquire(n: usize) -> DeviceTimings {
        let mut t = TIMING_POOL
            .lock()
            .map(|mut pool| pool.pop().unwrap_or_default())
            .unwrap_or_default();
        t.clear();
        t.reserve(n);
        t
    }

    /// Drop all rows, keeping every column's capacity.
    pub fn clear(&mut self) {
        self.device.clear();
        self.compute_s.clear();
        self.upload_s.clear();
        self.finish_s.clear();
        self.verdict.clear();
    }

    /// Reserve room for at least `n` additional rows in every column.
    pub fn reserve(&mut self, n: usize) {
        self.device.reserve(n);
        self.compute_s.reserve(n);
        self.upload_s.reserve(n);
        self.finish_s.reserve(n);
        self.verdict.reserve(n);
    }

    /// Return this column set's capacity to the process-wide free list.
    /// A no-op when the pool is full or its lock is poisoned — recycling
    /// is purely an allocation optimization, never a correctness
    /// dependency.
    pub fn recycle(mut self) {
        self.clear();
        if let Ok(mut pool) = TIMING_POOL.lock() {
            if pool.len() < TIMING_POOL_MAX {
                pool.push(self);
            }
        }
    }
}

/// Process-wide free list of retired [`DeviceTimings`] column sets.
/// Bounded so pathological fan-out cannot hoard memory; beyond the cap,
/// recycled buffers simply drop.
static TIMING_POOL: Mutex<Vec<DeviceTimings>> = Mutex::new(Vec::new());
const TIMING_POOL_MAX: usize = 256;

/// Simulated timing of one cluster's edge phase.
#[derive(Debug, Clone)]
pub struct PhaseTiming {
    /// Phase duration: when the policy closed the round.
    pub duration_s: f64,
    /// Compute portion of the duration (the straggler barrier, capped at
    /// the close).
    pub compute_s: f64,
    /// Upload portion of the duration (`duration - compute`).
    pub upload_s: f64,
    /// Per-device timing columns, in work-list (sorted participant) order.
    pub devices: DeviceTimings,
    /// Queue events processed by the simulation. Cohort-granular:
    /// simultaneous devices sharing a capability profile ride one
    /// `ComputeDone`/`UploadDone` pair; includes the late-upload drain
    /// and any timeout event.
    pub events: usize,
    /// Why the phase stopped accepting reports.
    pub close_reason: CloseReason,
}

/// Per-global-round accumulator the event estimator fills phase by phase;
/// empty in closed-form mode. Lives inside the coordinator's `RoundStats`.
#[derive(Debug, Clone, Default)]
pub struct RoundTiming {
    /// Accumulated virtual time per cluster (clusters progress through
    /// their edge phases independently and only barrier at the
    /// inter-cluster aggregation).
    pub cluster_time_s: Vec<f64>,
    /// Accumulated compute portion per cluster.
    pub cluster_compute_s: Vec<f64>,
    /// Accumulated upload portion per cluster.
    pub cluster_upload_s: Vec<f64>,
    /// Every simulated device timing of the round (all phases appended),
    /// in struct-of-arrays layout.
    pub device_timings: DeviceTimings,
    /// Reports that made their phase close this round.
    pub on_time_devices: usize,
    /// Reports that missed their close but were kept for a stale merge.
    pub late_devices: usize,
    /// Kept-late reports from *any* earlier phase that were folded into
    /// one of this round's aggregates (filled by the coordinator's drain).
    pub stale_merged: usize,
    /// Simulated backhaul seconds of this round's gossip steps, recorded
    /// once by the plan interpreter when each step's hops are simulated
    /// for the clock barrier (so the round-latency breakdown does not
    /// re-simulate them).
    pub gossip_s: f64,
    /// Devices discarded outright by the close policy this round.
    pub dropped_devices: usize,
    /// Phase-close reason counts, indexed by [`CloseReason::index`].
    pub close_reasons: [usize; 4],
    /// Total events processed this round (cohort-granular).
    pub events_processed: usize,
    /// Virtual seconds of fixed-point encode + pairwise mask generation
    /// charged to this round's secure-aggregation phases, summed over
    /// participating devices. Folded by the coordinator's trainer (both
    /// latency modes); exactly 0.0 for non-secagg and lossless runs.
    pub secagg_mask_s: f64,
    /// Extra bits this round's masked uploads put on the air versus the
    /// plain payload (participants · (secagg bits − model bits)). Exactly
    /// 0.0 for non-secagg and lossless runs.
    pub secagg_extra_bits: f64,
}

impl RoundTiming {
    /// Fold one cluster's phase into the round accumulator.
    pub fn record_phase(&mut self, cluster: usize, n_clusters: usize, pt: &PhaseTiming) {
        if self.cluster_time_s.len() < n_clusters {
            self.cluster_time_s.resize(n_clusters, 0.0);
            self.cluster_compute_s.resize(n_clusters, 0.0);
            self.cluster_upload_s.resize(n_clusters, 0.0);
        }
        self.cluster_time_s[cluster] += pt.duration_s;
        self.cluster_compute_s[cluster] += pt.compute_s;
        self.cluster_upload_s[cluster] += pt.upload_s;
        for v in &pt.devices.verdict {
            match v {
                ReportVerdict::OnTime => self.on_time_devices += 1,
                ReportVerdict::Late => self.late_devices += 1,
                ReportVerdict::Dropped => self.dropped_devices += 1,
            }
        }
        if !pt.devices.is_empty() {
            self.close_reasons[pt.close_reason.index()] += 1;
        }
        self.events_processed += pt.events;
        self.device_timings.extend_from(&pt.devices);
    }

    /// Return the round's device-timing columns to the process-wide free
    /// list (leaving the accumulator otherwise untouched). Called by the
    /// coordinator once the round's record has been derived, so the next
    /// round's [`RoundTiming::record_phase`] appends into recycled
    /// capacity.
    pub fn recycle(&mut self) {
        std::mem::take(&mut self.device_timings).recycle();
    }

    /// Compact close-reason label for the round: "-" when no phases were
    /// simulated, the reason's name when unanimous, "mixed" otherwise.
    pub fn close_reason_summary(&self) -> String {
        let total: usize = self.close_reasons.iter().sum();
        if total == 0 {
            return "-".into();
        }
        for r in CloseReason::ALL {
            if self.close_reasons[r.index()] == total {
                return r.name().into();
            }
        }
        "mixed".into()
    }
}

/// How the coordinator turns a round's training into simulated latency.
///
/// Two implementations: [`ClosedFormEstimator`] replays the paper's Eq. 8
/// (the fast default and the oracle for the equivalence tests) and
/// [`EventDrivenEstimator`] runs the discrete-event simulation above
/// (required for any policy other than the full barrier). Selected by the
/// config's `latency` field / the CLI's `--latency` flag.
pub trait LatencyEstimator: Send + Sync {
    fn name(&self) -> &'static str;

    /// Simulate one cluster's edge phase under the given close policy.
    /// `work` is `(device, steps)` in sorted participant order. Returns
    /// `None` in closed-form mode — no per-phase simulation, nobody is
    /// deferred or dropped, the coordinator keeps its Eq. 8 round-level
    /// path.
    fn phase_timing(
        &self,
        net: &NetworkModel,
        work: &[(usize, usize)],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> Option<PhaseTiming>;

    /// Simulate every cluster's edge phase of one plan step in a single
    /// call; `work[i]` is cluster `i`'s `(device, steps)` list and the
    /// result is index-aligned. The default forwards to
    /// [`LatencyEstimator::phase_timing`] per cluster;
    /// [`EventDrivenEstimator`] overrides it to drain each cluster's
    /// calendar shard on its own worker thread, merged back in cluster
    /// order. Returns `None` in closed-form mode.
    fn phase_timings(
        &self,
        net: &NetworkModel,
        work: &[Vec<(usize, usize)>],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> Option<Vec<PhaseTiming>> {
        work.iter()
            .map(|w| self.phase_timing(net, w, channel, policy))
            .collect()
    }

    /// Latency of one whole global round of `plan`. `device_steps` are
    /// the merged per-device round totals (the Eq. 8 inputs); `timing` is
    /// the event accumulator (empty in closed-form mode). The plan's
    /// communication structure — how many report phases ride each uplink,
    /// how many gossip hops the backhaul carries — replaces the old
    /// closed `AlgorithmKind` dispatch.
    fn round_latency(
        &self,
        net: &NetworkModel,
        plan: &Plan,
        device_steps: &[(usize, usize)],
        timing: &RoundTiming,
    ) -> RoundLatency;
}

/// The paper's closed-form Eq. 8 — one aggregate number per round.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClosedFormEstimator;

impl LatencyEstimator for ClosedFormEstimator {
    fn name(&self) -> &'static str {
        "closed-form"
    }

    fn phase_timing(
        &self,
        _net: &NetworkModel,
        _work: &[(usize, usize)],
        _channel: UploadChannel,
        _policy: &dyn AggregationPolicy,
    ) -> Option<PhaseTiming> {
        None
    }

    /// The generalized Eq. 8: the straggler-max compute term plus one
    /// closed-form communication term per plan upload/gossip count. For
    /// the canned plans this reproduces the paper's per-algorithm closed
    /// forms (`NetworkModel::{ce_fedavg,fedavg,hier_favg,local_edge}_round`)
    /// bit for bit — same multiplication/association order, and the
    /// absent terms contribute an exact `+ 0.0`.
    fn round_latency(
        &self,
        net: &NetworkModel,
        plan: &Plan,
        device_steps: &[(usize, usize)],
        _timing: &RoundTiming,
    ) -> RoundLatency {
        let comms = plan.comms();
        if comms.masked_uploads == 0 || net.secagg_upload_bits == 0.0 {
            // Plain runs — and lossless secagg, whose masked uploads ship
            // the plain f32 payload and cost no mask compute: charge them
            // as edge uploads in the same fold, so the degenerate mode is
            // bit-identical to `--secagg off` (`masked_uploads` is 0 there,
            // making the `+` an exact integer no-op).
            return RoundLatency {
                compute_s: net.compute_seconds(device_steps),
                upload_s: (comms.edge_uploads + comms.masked_uploads) as f64 * net.model_bits
                    / net.b_d2e
                    + comms.cloud_uploads as f64 * net.model_bits / net.b_d2c,
                backhaul_s: comms.gossip_pi as f64 * net.model_bits / net.b_e2e,
            };
        }
        // Masked runs: every masked phase adds per-device mask compute
        // inside the straggler max (the device must encode + mask before
        // it can transmit) and ships the secagg payload on the d2e radio.
        // The closed form has no per-phase participant sets, so it charges
        // mask generation for the configured expected group size.
        let group = net.secagg_group_size.max(0.0) as usize;
        let compute_s = device_steps
            .iter()
            .map(|&(dev, steps)| {
                steps as f64 * net.step_seconds(dev)
                    + comms.masked_uploads as f64 * net.mask_seconds(dev, group)
            })
            .fold(0.0, f64::max);
        RoundLatency {
            compute_s,
            upload_s: comms.edge_uploads as f64 * net.model_bits / net.b_d2e
                + comms.cloud_uploads as f64 * net.model_bits / net.b_d2c
                + comms.masked_uploads as f64 * net.secagg_upload_bits / net.b_d2e,
            backhaul_s: comms.gossip_pi as f64 * net.model_bits / net.b_e2e,
        }
    }
}

/// A run of devices sharing exact per-device compute and upload seconds
/// (the same capability profile): one queue event stands in for all of
/// them.
#[derive(Debug, Clone, Copy)]
struct Cohort {
    compute_s: f64,
    upload_s: f64,
    count: usize,
}

/// Per-slot timings plus the cohort table of one phase, computed before
/// any event is scheduled. Lives in the per-thread [`PhaseScratch`] and
/// is refilled in place phase after phase.
#[derive(Default)]
struct PreparedPhase {
    /// Per-slot compute seconds (`steps · C / c_k`).
    compute: Vec<f64>,
    /// Per-slot upload seconds (`W / device bandwidth`). Without
    /// per-device overrides every entry is the shared `W / b` the
    /// pre-scenario simulator charged (bit-identical).
    upload: Vec<f64>,
    /// Cohorts in first-seen work-list order (the cohort id is the event
    /// id, so ties break by earliest member slot).
    cohorts: Vec<Cohort>,
    timeout: Option<(f64, CloseReason)>,
    /// Latest finish (or finite timeout) — the calendar's bucket horizon.
    horizon_s: f64,
}

impl PreparedPhase {
    /// Refill this prepared phase in place for a new `(work, channel,
    /// policy)` tuple, reusing the per-slot columns, the cohort table,
    /// and the caller's cohort-key `index` (cleared here). Bit-identical
    /// to building a fresh `PreparedPhase`: the `HashMap` is only probed
    /// per key, never iterated, so its bucket order cannot influence any
    /// output.
    fn prepare(
        &mut self,
        index: &mut HashMap<(u64, u64), usize>,
        net: &NetworkModel,
        work: &[(usize, usize)],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) {
        self.compute.clear();
        self.upload.clear();
        self.cohorts.clear();
        index.clear();
        self.compute.reserve(work.len());
        self.upload.reserve(work.len());
        for &(dev, steps) in work {
            let mut c = steps as f64 * net.step_seconds(dev);
            if channel == UploadChannel::DeviceEdgeMasked {
                // Secure aggregation: the device encodes and masks its
                // update before transmitting. Zero (so `c` is unchanged
                // bitwise — compute seconds are never −0.0) when secagg
                // is off or lossless.
                c += net.mask_seconds(dev, work.len());
            }
            let u = channel.upload_bits(net) / channel.device_bandwidth(net, dev);
            self.compute.push(c);
            self.upload.push(u);
            // Cohort key: exact bit patterns, so members share *identical*
            // event timestamps and the expansion below is lossless.
            match index.entry((c.to_bits(), u.to_bits())) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    self.cohorts[*e.get()].count += 1;
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(self.cohorts.len());
                    self.cohorts.push(Cohort { compute_s: c, upload_s: u, count: 1 });
                }
            }
        }
        self.timeout = policy.timeout();
        let mut horizon_s = self
            .cohorts
            .iter()
            .map(|c| c.compute_s + c.upload_s)
            .fold(0.0, f64::max);
        if let Some((t, _)) = self.timeout {
            if t.is_finite() {
                horizon_s = horizon_s.max(t);
            }
        }
        self.horizon_s = horizon_s;
    }

    /// Queue-sizing hint: one compute + one upload event per cohort, plus
    /// a possible timeout.
    fn expected_events(&self) -> usize {
        self.cohorts.len() * 2 + 1
    }

    /// Schedule the initial events (cohort `ComputeDone`s and the armed
    /// timeout, if any) onto a fresh queue.
    fn arm(&self, queue: &mut CalendarQueue) {
        for (cid, c) in self.cohorts.iter().enumerate() {
            queue.schedule(Event {
                time_s: c.compute_s,
                kind: EventKind::ComputeDone,
                id: cid,
            });
        }
        if let Some((t, _)) = self.timeout {
            queue.schedule(Event { time_s: t, kind: EventKind::RoundClose, id: 0 });
        }
    }

    /// Drain the queue to completion (the late-upload drain included) and
    /// expand the cohort outcome into per-slot SoA timing.
    fn run(
        &self,
        work: &[(usize, usize)],
        policy: &dyn AggregationPolicy,
        queue: &mut CalendarQueue,
    ) -> PhaseTiming {
        if work.is_empty() {
            return PhaseTiming {
                duration_s: 0.0,
                compute_s: 0.0,
                upload_s: 0.0,
                devices: DeviceTimings::default(),
                events: 0,
                close_reason: CloseReason::AllReported,
            };
        }
        let total = work.len();
        let mut reported = 0usize;
        let mut close: Option<(f64, CloseReason)> = None;
        while let Some(ev) = queue.pop() {
            match ev.kind {
                EventKind::ComputeDone => {
                    let cohort = self.cohorts[ev.id];
                    queue.schedule(Event {
                        time_s: ev.time_s + cohort.upload_s,
                        kind: EventKind::UploadDone,
                        id: ev.id,
                    });
                }
                EventKind::UploadDone => {
                    let batch = self.cohorts[ev.id].count;
                    if close.is_none() {
                        if let Some(k) = policy.closes_within_batch(reported, batch, total) {
                            let reason = if k == total {
                                CloseReason::AllReported
                            } else {
                                CloseReason::KthReport
                            };
                            close = Some((ev.time_s, reason));
                        }
                    }
                    reported += batch;
                }
                EventKind::RoundClose => {
                    if close.is_none() {
                        let (_, reason) = self
                            .timeout
                            .expect("RoundClose events come from the armed timeout");
                        close = Some((ev.time_s, reason));
                    }
                }
                EventKind::BackhaulDone => unreachable!("no backhaul inside an edge phase"),
            }
        }
        let (close_s, close_reason) =
            close.expect("every report arrives eventually, so the phase must close");
        // Lazy cohort expansion: per-slot finish times re-use the exact
        // arithmetic the cohort events carried (compute + upload on the
        // same operand bits), so the row the per-device engine would have
        // produced is reconstructed bit for bit.
        let mut devices = DeviceTimings::acquire(total);
        for (slot, &(dev, _)) in work.iter().enumerate() {
            let finish = self.compute[slot] + self.upload[slot];
            devices.device.push(dev);
            devices.compute_s.push(self.compute[slot]);
            devices.upload_s.push(self.upload[slot]);
            devices.finish_s.push(finish);
            devices.verdict.push(if finish <= close_s {
                ReportVerdict::OnTime
            } else {
                policy.late_verdict()
            });
        }
        let barrier = self.compute.iter().fold(0.0, f64::max).min(close_s);
        PhaseTiming {
            duration_s: close_s,
            compute_s: barrier,
            upload_s: close_s - barrier,
            devices,
            events: queue.processed(),
            close_reason,
        }
    }
}

/// Per-thread simulation scratch: the prepared-phase columns, the
/// cohort-key index, and the calendar queue are refilled in place phase
/// after phase, so a steady-state round allocates nothing here. Pool
/// worker threads are persistent (`util::threadpool`), which is what
/// keeps this scratch warm across rounds.
struct PhaseScratch {
    prep: PreparedPhase,
    index: HashMap<(u64, u64), usize>,
    queue: CalendarQueue,
}

thread_local! {
    static PHASE_SCRATCH: RefCell<PhaseScratch> = RefCell::new(PhaseScratch {
        prep: PreparedPhase::default(),
        index: HashMap::new(),
        queue: CalendarQueue::new(0.0, 0),
    });
}

/// The discrete-event simulator (see the module docs for the event model).
#[derive(Debug, Clone, Copy, Default)]
pub struct EventDrivenEstimator;

impl EventDrivenEstimator {
    /// Run the cohort-batched event simulation of one cluster's edge
    /// phase under `policy`. Reports landing after the policy's close are
    /// still simulated to completion (the late-upload drain) so their
    /// arrival times are known to the coordinator's stale-merge
    /// bookkeeping.
    pub fn simulate_phase(
        net: &NetworkModel,
        work: &[(usize, usize)],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> PhaseTiming {
        PHASE_SCRATCH.with(|scratch| {
            let scratch = &mut *scratch.borrow_mut();
            scratch.prep.prepare(&mut scratch.index, net, work, channel, policy);
            scratch
                .queue
                .reset(scratch.prep.horizon_s, scratch.prep.expected_events());
            if !work.is_empty() {
                scratch.prep.arm(&mut scratch.queue);
            }
            scratch.prep.run(work, policy, &mut scratch.queue)
        })
    }

    /// Simulate every cluster's edge phase of one plan step, one calendar
    /// shard per cluster, drained in parallel on the persistent worker
    /// pool with `default_threads(work.len())` threads. See
    /// [`EventDrivenEstimator::simulate_phases_threads`].
    pub fn simulate_phases(
        net: &NetworkModel,
        work: &[Vec<(usize, usize)>],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> Vec<PhaseTiming> {
        Self::simulate_phases_threads(net, work, channel, policy, default_threads(work.len()))
    }

    /// [`EventDrivenEstimator::simulate_phases`] with an explicit thread
    /// count. Each cluster's calendar queue drains on its own worker
    /// thread (clusters never exchange events within a phase; they merge
    /// at the coordinator's gossip/cloud barriers) and results come back
    /// merged in cluster order, so the output is index-aligned with
    /// `work` and bit-identical to calling
    /// [`EventDrivenEstimator::simulate_phase`] per cluster sequentially
    /// — for any `threads` (pinned by the proptest in
    /// `rust/tests/sharded_queue.rs`).
    pub fn simulate_phases_threads(
        net: &NetworkModel,
        work: &[Vec<(usize, usize)>],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
        threads: usize,
    ) -> Vec<PhaseTiming> {
        parallel_map(work.len(), threads, |ci| {
            Self::simulate_phase(net, &work[ci], channel, policy)
        })
    }

    /// Simulate π sequential gossip hops on the backhaul; returns
    /// (virtual seconds, events processed).
    pub fn simulate_gossip(net: &NetworkModel, pi: usize) -> (f64, usize) {
        let hop = net.model_bits / net.b_e2e;
        let mut queue = EventQueue::new();
        if pi > 0 {
            queue.schedule(Event { time_s: hop, kind: EventKind::BackhaulDone, id: 0 });
        }
        while let Some(ev) = queue.pop() {
            if ev.id + 1 < pi {
                queue.schedule(Event {
                    time_s: ev.time_s + hop,
                    kind: EventKind::BackhaulDone,
                    id: ev.id + 1,
                });
            }
        }
        (queue.now(), queue.processed())
    }
}

impl LatencyEstimator for EventDrivenEstimator {
    fn name(&self) -> &'static str {
        "event"
    }

    fn phase_timing(
        &self,
        net: &NetworkModel,
        work: &[(usize, usize)],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> Option<PhaseTiming> {
        Some(Self::simulate_phase(net, work, channel, policy))
    }

    fn phase_timings(
        &self,
        net: &NetworkModel,
        work: &[Vec<(usize, usize)>],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> Option<Vec<PhaseTiming>> {
        Some(Self::simulate_phases(net, work, channel, policy))
    }

    fn round_latency(
        &self,
        _net: &NetworkModel,
        _plan: &Plan,
        _device_steps: &[(usize, usize)],
        timing: &RoundTiming,
    ) -> RoundLatency {
        // The slowest cluster's trajectory defines the training segment of
        // the round; clusters only barrier at the inter-cluster step.
        // Ties break toward the lowest cluster index (deterministic).
        let mut slowest = 0usize;
        let mut t = f64::NEG_INFINITY;
        for (i, &ct) in timing.cluster_time_s.iter().enumerate() {
            if ct > t {
                t = ct;
                slowest = i;
            }
        }
        let (compute, upload) = if timing.cluster_time_s.is_empty() {
            (0.0, 0.0)
        } else {
            (
                timing.cluster_compute_s[slowest],
                timing.cluster_upload_s[slowest],
            )
        };
        // The plan's gossip steps were already simulated (once each) by
        // the interpreter for the clock barrier; reuse that accumulator
        // rather than replaying the hops here.
        RoundLatency {
            compute_s: compute,
            upload_s: upload,
            backhaul_s: timing.gossip_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::policy::{DeadlineDrop, FullBarrier, SemiSync};

    fn net() -> NetworkModel {
        // 1 MFLOP/sample, batch 50, 1M params (the parent module's fixture).
        NetworkModel::paper_defaults(4, 1e6, 50, 1_000_000)
    }

    /// The original one-event-per-device heap simulation, kept verbatim
    /// as the oracle the cohort-batched engine must reproduce bitwise
    /// (all fields except the cohort-granular `events` count).
    fn reference_phase(
        net: &NetworkModel,
        work: &[(usize, usize)],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> PhaseTiming {
        if work.is_empty() {
            return PhaseTiming {
                duration_s: 0.0,
                compute_s: 0.0,
                upload_s: 0.0,
                devices: DeviceTimings::default(),
                events: 0,
                close_reason: CloseReason::AllReported,
            };
        }
        let upload: Vec<f64> = work
            .iter()
            .map(|&(dev, _)| channel.upload_bits(net) / channel.device_bandwidth(net, dev))
            .collect();
        let mut queue = EventQueue::new();
        for (slot, &(dev, steps)) in work.iter().enumerate() {
            let mut c = steps as f64 * net.step_seconds(dev);
            if channel == UploadChannel::DeviceEdgeMasked {
                c += net.mask_seconds(dev, work.len());
            }
            queue.schedule(Event {
                time_s: c,
                kind: EventKind::ComputeDone,
                id: slot,
            });
        }
        let timeout = policy.timeout();
        if let Some((t, _)) = timeout {
            queue.schedule(Event { time_s: t, kind: EventKind::RoundClose, id: 0 });
        }
        let mut compute = vec![0.0f64; work.len()];
        let mut finish = vec![0.0f64; work.len()];
        let mut reported = 0usize;
        let mut close: Option<(f64, CloseReason)> = None;
        while let Some(ev) = queue.pop() {
            match ev.kind {
                EventKind::ComputeDone => {
                    compute[ev.id] = ev.time_s;
                    queue.schedule(Event {
                        time_s: ev.time_s + upload[ev.id],
                        kind: EventKind::UploadDone,
                        id: ev.id,
                    });
                }
                EventKind::UploadDone => {
                    finish[ev.id] = ev.time_s;
                    reported += 1;
                    if close.is_none() && policy.closes_at_report(reported, work.len()) {
                        let reason = if reported == work.len() {
                            CloseReason::AllReported
                        } else {
                            CloseReason::KthReport
                        };
                        close = Some((ev.time_s, reason));
                    }
                }
                EventKind::RoundClose => {
                    if close.is_none() {
                        let (_, reason) = timeout.expect("armed");
                        close = Some((ev.time_s, reason));
                    }
                }
                EventKind::BackhaulDone => unreachable!(),
            }
        }
        let (close_s, close_reason) = close.expect("phase closes");
        let mut devices = DeviceTimings::with_capacity(work.len());
        for (slot, &(dev, _)) in work.iter().enumerate() {
            devices.push(DeviceTiming {
                device: dev,
                compute_s: compute[slot],
                upload_s: upload[slot],
                finish_s: finish[slot],
                verdict: if finish[slot] <= close_s {
                    ReportVerdict::OnTime
                } else {
                    policy.late_verdict()
                },
            });
        }
        let barrier = compute.iter().fold(0.0, f64::max).min(close_s);
        PhaseTiming {
            duration_s: close_s,
            compute_s: barrier,
            upload_s: close_s - barrier,
            devices,
            events: queue.processed(),
            close_reason,
        }
    }

    fn assert_same_phase(a: &PhaseTiming, b: &PhaseTiming) {
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        assert_eq!(a.compute_s.to_bits(), b.compute_s.to_bits());
        assert_eq!(a.upload_s.to_bits(), b.upload_s.to_bits());
        assert_eq!(a.close_reason, b.close_reason);
        assert_eq!(a.devices.len(), b.devices.len());
        for (x, y) in a.devices.iter().zip(b.devices.iter()) {
            assert_eq!(x.device, y.device);
            assert_eq!(x.compute_s.to_bits(), y.compute_s.to_bits());
            assert_eq!(x.upload_s.to_bits(), y.upload_s.to_bits());
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
            assert_eq!(x.verdict, y.verdict);
        }
    }

    #[test]
    fn queue_orders_by_time_kind_id() {
        let mut q = EventQueue::new();
        q.schedule(Event { time_s: 2.0, kind: EventKind::ComputeDone, id: 0 });
        q.schedule(Event { time_s: 1.0, kind: EventKind::UploadDone, id: 1 });
        q.schedule(Event { time_s: 1.0, kind: EventKind::RoundClose, id: 0 });
        q.schedule(Event { time_s: 1.0, kind: EventKind::ComputeDone, id: 1 });
        q.schedule(Event { time_s: 1.0, kind: EventKind::ComputeDone, id: 0 });
        let order: Vec<(f64, EventKind, usize)> = std::iter::from_fn(|| q.pop())
            .map(|e| (e.time_s, e.kind, e.id))
            .collect();
        assert_eq!(
            order,
            vec![
                (1.0, EventKind::ComputeDone, 0),
                (1.0, EventKind::ComputeDone, 1),
                (1.0, EventKind::UploadDone, 1),
                // The timeout pops after a simultaneous report: a device
                // landing exactly at the cutoff is on time.
                (1.0, EventKind::RoundClose, 0),
                (2.0, EventKind::ComputeDone, 0),
            ]
        );
        assert_eq!(q.processed(), 5);
        assert_eq!(q.now(), 2.0);
    }

    #[test]
    fn phase_matches_closed_form_under_full_barrier() {
        let m = net();
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &FullBarrier,
        );
        let want_compute = 16.0 * m.step_seconds(0);
        let want_upload = m.model_bits / m.b_d2e;
        assert!((pt.compute_s - want_compute).abs() < 1e-12);
        assert!((pt.upload_s - want_upload).abs() < 1e-12);
        assert!((pt.duration_s - (want_compute + want_upload)).abs() < 1e-12);
        assert_eq!(pt.devices.len(), 4);
        assert!(pt.devices.iter().all(|d| d.verdict == ReportVerdict::OnTime));
        assert_eq!(pt.close_reason, CloseReason::AllReported);
        // All four homogeneous devices form one cohort: one ComputeDone +
        // one UploadDone (no timeout).
        assert_eq!(pt.events, 2);
    }

    #[test]
    fn deadline_drops_slow_devices_and_caps_duration() {
        let mut m = net();
        m.device_flops[2] /= 1000.0; // straggler: ~3.5 s compute vs ~3.5 ms
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let fast_finish = 16.0 * m.step_seconds(0) + m.model_bits / m.b_d2e;
        let dl = fast_finish * 1.5; // fast devices report, the straggler not
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &DeadlineDrop { deadline_s: dl },
        );
        let dropped: Vec<usize> =
            pt.devices.iter().filter(|d| d.dropped()).map(|d| d.device).collect();
        assert_eq!(dropped, vec![2]);
        assert!((pt.duration_s - dl).abs() < 1e-12, "duration capped at the deadline");
        assert!(pt.devices.get(2).finish_s > dl);
        assert_eq!(pt.close_reason, CloseReason::Deadline);
        // Two cohorts ({0,1,3} and the straggler {2}): 2 computes + 2
        // uploads (the straggler's drains after the close) + 1 timeout.
        assert_eq!(pt.events, 5);
    }

    #[test]
    fn all_dropped_phase_lasts_exactly_the_deadline() {
        let m = net();
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &DeadlineDrop { deadline_s: 1e-9 },
        );
        assert!(pt.devices.iter().all(|d| d.dropped()));
        assert!((pt.duration_s - 1e-9).abs() < 1e-18);
        assert_eq!(pt.close_reason, CloseReason::Deadline);
    }

    #[test]
    fn semi_sync_closes_at_kth_report_and_keeps_late_reports() {
        let mut m = net();
        m.device_flops[1] /= 1000.0; // two stragglers
        m.device_flops[3] /= 2000.0;
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k: 2, timeout_s: f64::INFINITY, staleness_exp: 1.0 },
        );
        // Devices 0 and 2 (full speed) report first; the phase closes on
        // the second report, the stragglers are late-but-kept.
        assert_eq!(pt.close_reason, CloseReason::KthReport);
        let fast_finish = 16.0 * m.step_seconds(0) + m.model_bits / m.b_d2e;
        assert!((pt.duration_s - fast_finish).abs() < 1e-12);
        assert!(pt.devices.get(0).verdict == ReportVerdict::OnTime);
        assert!(pt.devices.get(2).verdict == ReportVerdict::OnTime);
        assert!(pt.devices.get(1).late() && pt.devices.get(3).late());
        // Late uploads drained: their true arrival times are recorded.
        assert!(pt.devices.get(1).finish_s > pt.duration_s);
        assert!(pt.devices.get(3).finish_s > pt.devices.get(1).finish_s);
        // Three cohorts ({0,2}, {1}, {3}), no timeout (infinite).
        assert_eq!(pt.events, 6);
    }

    #[test]
    fn semi_sync_timeout_beats_kth_report_when_earlier() {
        let m = net(); // homogeneous: all reports land together
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k: 4, timeout_s: 1e-9, staleness_exp: 1.0 },
        );
        assert_eq!(pt.close_reason, CloseReason::Timeout);
        assert!((pt.duration_s - 1e-9).abs() < 1e-18);
        assert!(pt.devices.iter().all(|d| d.late()), "everyone is late, nobody dropped");
        // One homogeneous cohort + the timeout event.
        assert_eq!(pt.events, 3);
    }

    #[test]
    fn semi_sync_k_equal_n_matches_full_barrier_exactly() {
        // The degenerate policy: K = N, no timeout, zero staleness
        // exponent. Same close instant, same verdicts, same reason —
        // bit-identical, the oracle the integration suite leans on.
        let mut m = net();
        m.device_flops[1] /= 3.0;
        m.device_flops[2] /= 7.0;
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let barrier = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &FullBarrier,
        );
        let degenerate = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k: 4, timeout_s: f64::INFINITY, staleness_exp: 0.0 },
        );
        assert_eq!(barrier.duration_s.to_bits(), degenerate.duration_s.to_bits());
        assert_eq!(barrier.compute_s.to_bits(), degenerate.compute_s.to_bits());
        assert_eq!(barrier.upload_s.to_bits(), degenerate.upload_s.to_bits());
        assert_eq!(barrier.close_reason, degenerate.close_reason);
        assert_eq!(barrier.events, degenerate.events);
        for (a, b) in barrier.devices.iter().zip(degenerate.devices.iter()) {
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
            assert_eq!(a.verdict, b.verdict);
        }
    }

    #[test]
    fn cohort_engine_matches_per_device_reference_bitwise() {
        // Heterogeneous fleet with a per-device uplink override, under
        // all three policies: the cohort-batched calendar engine must
        // reproduce the one-event-per-device heap oracle bit for bit.
        let mut m = NetworkModel::paper_defaults(9, 1e6, 50, 1_000_000);
        for (d, c) in m.device_flops.iter_mut().enumerate() {
            *c *= 1.0 - 0.1 * (d % 3) as f64; // three capability tiers
        }
        m.device_uplink[4] = Some(1e6);
        let work: Vec<(usize, usize)> = (0..9).map(|d| (d, 8 + 4 * (d % 2))).collect();
        let fast_finish = 8.0 * m.step_seconds(0) + m.model_bits / m.b_d2e;
        let policies: Vec<Box<dyn AggregationPolicy>> = vec![
            Box::new(FullBarrier),
            Box::new(DeadlineDrop { deadline_s: fast_finish * 2.0 }),
            Box::new(SemiSync { k: 5, timeout_s: fast_finish * 3.0, staleness_exp: 1.0 }),
            Box::new(SemiSync { k: 3, timeout_s: f64::INFINITY, staleness_exp: 0.5 }),
        ];
        // With secagg unset, the masked channel degenerates to DeviceEdge.
        for channel in [
            UploadChannel::DeviceEdge,
            UploadChannel::DeviceCloud,
            UploadChannel::DeviceEdgeMasked,
        ] {
            for policy in &policies {
                let fast = EventDrivenEstimator::simulate_phase(&m, &work, channel, &**policy);
                let oracle = reference_phase(&m, &work, channel, &**policy);
                assert_same_phase(&fast, &oracle);
            }
        }
        // And again with real secagg costs charged on the masked channel.
        m.secagg_upload_bits = 64.0 * 1_000_000.0;
        m.secagg_group_size = 9.0;
        for policy in &policies {
            let fast = EventDrivenEstimator::simulate_phase(
                &m,
                &work,
                UploadChannel::DeviceEdgeMasked,
                &**policy,
            );
            let oracle = reference_phase(&m, &work, UploadChannel::DeviceEdgeMasked, &**policy);
            assert_same_phase(&fast, &oracle);
        }
    }

    #[test]
    fn masked_channel_charges_mask_compute_and_inflated_uploads() {
        let mut m = net();
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let plain = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &FullBarrier,
        );
        // Lossless secagg (no upload-bits override): the masked phase is
        // bit-identical to the plain one.
        let lossless = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdgeMasked,
            &FullBarrier,
        );
        assert_same_phase(&plain, &lossless);
        // Real masking: one u64 word per f32 parameter doubles the upload,
        // and every device pays its mask compute before transmitting.
        m.secagg_upload_bits = 2.0 * m.model_bits;
        let masked = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdgeMasked,
            &FullBarrier,
        );
        let mask_s = m.mask_seconds(0, work.len());
        assert!(mask_s > 0.0);
        for (p, q) in plain.devices.iter().zip(masked.devices.iter()) {
            assert!((q.compute_s - (p.compute_s + mask_s)).abs() < 1e-18);
            assert!((q.upload_s - 2.0 * p.upload_s).abs() < 1e-12);
        }
        assert!(masked.duration_s > plain.duration_s);
    }

    #[test]
    fn closed_form_charges_masked_plans() {
        let mut m = net();
        let steps: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let plain_plan = Plan::parse("edge(2)*8; gossip(10)").unwrap();
        let masked_plan = Plan::parse("edge(2)@masked*8; gossip(10)").unwrap();
        let plain = ClosedFormEstimator.round_latency(
            &m,
            &plain_plan,
            &steps,
            &RoundTiming::default(),
        );
        // Lossless secagg: bit-identical to the plain plan.
        let lossless = ClosedFormEstimator.round_latency(
            &m,
            &masked_plan,
            &steps,
            &RoundTiming::default(),
        );
        assert_eq!(plain.compute_s.to_bits(), lossless.compute_s.to_bits());
        assert_eq!(plain.upload_s.to_bits(), lossless.upload_s.to_bits());
        assert_eq!(plain.backhaul_s.to_bits(), lossless.backhaul_s.to_bits());
        // Real masking inflates uploads and adds mask compute to the max.
        m.secagg_upload_bits = 2.0 * m.model_bits;
        m.secagg_group_size = 4.0;
        let masked = ClosedFormEstimator.round_latency(
            &m,
            &masked_plan,
            &steps,
            &RoundTiming::default(),
        );
        assert!((masked.upload_s - 2.0 * plain.upload_s).abs() < 1e-9);
        let want_compute = 16.0 * m.step_seconds(0) + 8.0 * m.mask_seconds(0, 4);
        assert!((masked.compute_s - want_compute).abs() < 1e-15);
        assert_eq!(masked.backhaul_s.to_bits(), plain.backhaul_s.to_bits());
    }

    #[test]
    fn simulate_phases_matches_per_cluster_simulate_phase() {
        let mut m = NetworkModel::paper_defaults(12, 1e6, 50, 1_000_000);
        for (d, c) in m.device_flops.iter_mut().enumerate() {
            *c *= 1.0 - 0.05 * (d % 4) as f64;
        }
        // Uneven split incl. an empty cluster.
        let work: Vec<Vec<(usize, usize)>> = vec![
            (0..5).map(|d| (d, 16)).collect(),
            Vec::new(),
            (5..12).map(|d| (d, 16)).collect(),
        ];
        let policy = SemiSync { k: 3, timeout_s: f64::INFINITY, staleness_exp: 1.0 };
        let batch = EventDrivenEstimator::simulate_phases(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &policy,
        );
        assert_eq!(batch.len(), work.len());
        for (w, pt) in work.iter().zip(&batch) {
            let solo = EventDrivenEstimator::simulate_phase(
                &m,
                w,
                UploadChannel::DeviceEdge,
                &policy,
            );
            assert_same_phase(pt, &solo);
            assert_eq!(pt.events, solo.events);
        }
    }

    #[test]
    fn parallel_drain_bit_identical_across_thread_counts() {
        let mut m = NetworkModel::paper_defaults(12, 1e6, 50, 1_000_000);
        for (d, c) in m.device_flops.iter_mut().enumerate() {
            *c *= 1.0 - 0.05 * (d % 4) as f64;
        }
        let work: Vec<Vec<(usize, usize)>> = vec![
            (0..5).map(|d| (d, 16)).collect(),
            Vec::new(),
            (5..9).map(|d| (d, 8)).collect(),
            (9..12).map(|d| (d, 16)).collect(),
        ];
        let policy = SemiSync { k: 3, timeout_s: f64::INFINITY, staleness_exp: 1.0 };
        let sequential: Vec<PhaseTiming> = work
            .iter()
            .map(|w| {
                EventDrivenEstimator::simulate_phase(&m, w, UploadChannel::DeviceEdge, &policy)
            })
            .collect();
        for threads in [1usize, 2, 4, 8] {
            let parallel = EventDrivenEstimator::simulate_phases_threads(
                &m,
                &work,
                UploadChannel::DeviceEdge,
                &policy,
                threads,
            );
            assert_eq!(parallel.len(), sequential.len());
            for (p, s) in parallel.iter().zip(&sequential) {
                assert_same_phase(p, s);
                assert_eq!(p.events, s.events);
            }
        }
    }

    #[test]
    fn timings_acquire_recycle_round_trip() {
        let mut t = DeviceTimings::acquire(4);
        assert!(t.is_empty());
        t.push(DeviceTiming {
            device: 1,
            compute_s: 1.0,
            upload_s: 2.0,
            finish_s: 3.0,
            verdict: ReportVerdict::OnTime,
        });
        t.recycle();
        // Whatever buffer comes back (recycled or fresh), it starts empty.
        let t2 = DeviceTimings::acquire(2);
        assert!(t2.is_empty());
        t2.recycle();
    }

    #[test]
    fn empty_phase_is_zero() {
        let pt = EventDrivenEstimator::simulate_phase(
            &net(),
            &[],
            UploadChannel::DeviceEdge,
            &DeadlineDrop { deadline_s: 1.0 },
        );
        assert_eq!(pt.duration_s, 0.0);
        assert_eq!(pt.events, 0);
        assert!(pt.devices.is_empty());
    }

    #[test]
    fn gossip_hops_sum_to_closed_form() {
        let m = net();
        let (t, events) = EventDrivenEstimator::simulate_gossip(&m, 10);
        let want = 10.0 * m.model_bits / m.b_e2e;
        assert!((t - want).abs() / want < 1e-12);
        assert_eq!(events, 10);
        let (t0, e0) = EventDrivenEstimator::simulate_gossip(&m, 0);
        assert_eq!((t0, e0), (0.0, 0));
    }

    #[test]
    fn per_device_uplink_override_slows_only_that_device() {
        let mut m = net();
        // Device 1 reports over a 1 Mbps radio instead of the shared 10.
        m.device_uplink[1] = Some(1e6);
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &FullBarrier,
        );
        assert!((pt.devices.get(1).upload_s - m.model_bits / 1e6).abs() < 1e-9);
        for d in [0usize, 2, 3] {
            assert!((pt.devices.get(d).upload_s - m.model_bits / m.b_d2e).abs() < 1e-9);
        }
        // The barrier waits for the overridden device's slower report.
        assert!(pt.devices.get(1).finish_s > pt.devices.get(0).finish_s);
        assert_eq!(pt.duration_s.to_bits(), pt.devices.get(1).finish_s.to_bits());
        // Overrides never touch the cloud channel.
        let cloud = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceCloud,
            &FullBarrier,
        );
        assert!((cloud.devices.get(1).upload_s - m.model_bits / m.b_d2c).abs() < 1e-9);
    }

    #[test]
    fn cloud_channel_uses_cloud_bandwidth() {
        let m = net();
        let work = [(0usize, 16usize)];
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceCloud,
            &FullBarrier,
        );
        assert!((pt.devices.get(0).upload_s - m.model_bits / m.b_d2c).abs() < 1e-12);
    }

    #[test]
    fn round_timing_accumulates_phases_and_verdicts() {
        let mut m = net();
        m.device_flops[3] /= 1000.0;
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k: 3, timeout_s: f64::INFINITY, staleness_exp: 1.0 },
        );
        let mut rt = RoundTiming::default();
        rt.record_phase(1, 2, &pt);
        rt.record_phase(1, 2, &pt);
        assert!((rt.cluster_time_s[1] - 2.0 * pt.duration_s).abs() < 1e-12);
        assert_eq!(rt.cluster_time_s[0], 0.0);
        assert_eq!(rt.device_timings.len(), 8);
        assert_eq!(rt.on_time_devices, 6);
        assert_eq!(rt.late_devices, 2);
        assert_eq!(rt.dropped_devices, 0);
        assert_eq!(rt.close_reasons[CloseReason::KthReport.index()], 2);
        assert_eq!(rt.close_reason_summary(), "kth-report");
        // The estimator picks cluster 1 (the slowest) for the breakdown;
        // with no gossip recorded, no backhaul is charged.
        let plan = Plan::parse("edge(16)*2").unwrap();
        let lat = EventDrivenEstimator.round_latency(&m, &plan, &[], &rt);
        assert!((lat.total() - 2.0 * pt.duration_s).abs() < 1e-9);
        assert_eq!(lat.backhaul_s, 0.0);
        // Gossip hops recorded by the interpreter ride into the breakdown.
        let hops = EventDrivenEstimator::simulate_gossip(&m, 10).0;
        rt.gossip_s += hops;
        let lat_g = EventDrivenEstimator.round_latency(&m, &plan, &[], &rt);
        assert_eq!(lat_g.backhaul_s.to_bits(), hops.to_bits());
    }

    #[test]
    fn closed_form_round_latency_matches_the_per_algorithm_forms() {
        // The plan-structured Eq. 8 must be bit-identical to the paper's
        // per-algorithm closed forms for the canned shapes (tau=2, q=8,
        // pi=10; steps = q·tau per device).
        let m = net();
        let steps: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let cases = [
            ("edge(2)*8; gossip(10)", m.ce_fedavg_round(&steps, 8, 10)),
            ("edge(16)@cloud; cloud", m.fedavg_round(&steps)),
            ("edge(2)*7; edge(2)@cloud; cloud", m.hier_favg_round(&steps, 8)),
            ("edge(2)*8", m.local_edge_round(&steps, 8)),
        ];
        for (spec, want) in cases {
            let plan = Plan::parse(spec).unwrap();
            let got = ClosedFormEstimator.round_latency(
                &m,
                &plan,
                &steps,
                &RoundTiming::default(),
            );
            assert_eq!(got.compute_s.to_bits(), want.compute_s.to_bits(), "{spec}");
            assert_eq!(got.upload_s.to_bits(), want.upload_s.to_bits(), "{spec}");
            assert_eq!(got.backhaul_s.to_bits(), want.backhaul_s.to_bits(), "{spec}");
        }
    }

    #[test]
    fn close_reason_summary_handles_empty_and_mixed() {
        let rt = RoundTiming::default();
        assert_eq!(rt.close_reason_summary(), "-");
        let mut rt = RoundTiming::default();
        rt.close_reasons[CloseReason::AllReported.index()] = 1;
        rt.close_reasons[CloseReason::Timeout.index()] = 1;
        assert_eq!(rt.close_reason_summary(), "mixed");
    }
}
