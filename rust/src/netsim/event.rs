//! Discrete-event virtual-clock simulation of a CFEL round.
//!
//! The closed-form Eq. 8 estimator in the parent module collapses a global
//! round into three aggregate terms. This module simulates the same round
//! as *per-device discrete events* on a virtual clock, which is what lets
//! the system express reporting deadlines, stragglers, semi-synchronous
//! round closes, and per-device timing heterogeneity that the closed form
//! cannot.
//!
//! # Event model
//!
//! One edge phase of one cluster is simulated as follows: every
//! participating device `k` schedules a [`EventKind::ComputeDone`] event at
//! `steps_k · C / c_k` (its local SGD workload over its processing
//! capacity). Popping a `ComputeDone` schedules the device's
//! [`EventKind::UploadDone`] at `t + W / b` where `b` is the phase's
//! [`UploadChannel`] bandwidth — devices transmit on dedicated links, so
//! uploads overlap freely (the paper's model). The inter-cluster
//! aggregation of CE-FedAvg is simulated as π sequential
//! [`EventKind::BackhaulDone`] hops of `W / b_e2e` each (every edge of the
//! backhaul transmits concurrently within a hop).
//!
//! # Round-close policies
//!
//! When the phase stops accepting reports is decided by the configured
//! [`AggregationPolicy`]: the policy may arm one [`EventKind::RoundClose`]
//! timeout event, and is consulted after every `UploadDone` whether the
//! phase closes now (the full barrier closes on the last report, semi-sync
//! on the K-th). Events scheduled past the close still pop — the
//! *late-upload drain* — so every device's report time is known; reports
//! that missed the close carry the policy's verdict
//! ([`ReportVerdict::Late`] for semi-sync, [`ReportVerdict::Dropped`] for
//! the deadline) and the coordinator either folds them into a later
//! phase's aggregate with a staleness discount or discards them. See
//! `aggregation::policy` for the three policies and their semantics.
//!
//! # Tie-breaking and determinism
//!
//! The event queue is a binary min-heap ordered by `(time, kind, id)`:
//! simultaneous events pop in `ComputeDone < UploadDone < BackhaulDone <
//! RoundClose` order, and within a kind by ascending id (the device's slot
//! in the phase's work list, which the coordinator builds in sorted
//! participant order). `RoundClose` ordering last means a report landing
//! exactly at a deadline/timeout still counts as on time, matching the
//! strict `finish > T_dl` drop rule of the closed analysis. Simulation
//! inputs are derived purely from the experiment seed and the simulation
//! runs single-threaded after the training join, so event-driven timing —
//! including which devices a policy drops or defers — is bit-identical for
//! any `CFEL_THREADS` (pinned by `rust/tests/determinism.rs`).
//!
//! # Deadlines and Eq. 6 renormalization
//!
//! Under [`aggregation::policy::DeadlineDrop`](crate::aggregation::policy::DeadlineDrop)
//! the phase ends at `min(T_dl, latest report)` — the edge server never
//! waits past the deadline — and a device whose `UploadDone` lands after
//! `T_dl` is excluded from the Eq. 6 intra-cluster average, which
//! renormalizes the surviving sample-count weights automatically (the
//! average is taken over survivors only). If *every* device of a cluster
//! misses the deadline the cluster skips aggregation and keeps its
//! previous edge model for that phase — the same contract semi-sync
//! applies when its timeout fires before any report.
//!
//! # Closed-form equivalence
//!
//! With homogeneous (or merely per-device-constant) workloads, full
//! participation and the full-barrier policy, summing the per-phase
//! barriers reproduces Eq. 8 exactly: `Σ_r max_k(steps·C/c_k) = max_k Σ_r`
//! when the slowest device is the same each phase, and uploads/backhaul
//! hops add up to the closed-form `q·W/b` and `π·W/b_e2e` terms
//! (`rust/tests/event_sim.rs` pins ≤1e-9 relative error for all four
//! algorithms). Under partial participation the two models legitimately
//! diverge: the closed form takes the max over *round-total* per-device
//! steps, while the event simulator charges every phase its own barrier —
//! the more faithful account.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::aggregation::policy::{AggregationPolicy, CloseReason, ReportVerdict};
use crate::netsim::{NetworkModel, RoundLatency};
use crate::plan::Plan;

/// Event types, listed in tie-break order (earlier kinds pop first at
/// equal timestamps).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A device finished its local SGD steps for this edge phase.
    ComputeDone,
    /// A device's model report arrived at its aggregation point.
    UploadDone,
    /// One inter-cluster gossip hop completed on the backhaul.
    BackhaulDone,
    /// The policy's timeout fired — the phase closes if it hasn't already.
    /// Ordered after `UploadDone` so a report landing exactly at the
    /// cutoff still counts as on time.
    RoundClose,
}

/// One scheduled occurrence on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Virtual time of the occurrence, seconds from the phase start.
    pub time_s: f64,
    pub kind: EventKind,
    /// Work-list slot for compute/upload events; hop index for backhaul;
    /// 0 for the (unique) round-close timeout.
    pub id: usize,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_s
            .total_cmp(&other.time_s)
            .then_with(|| self.kind.cmp(&other.kind))
            .then_with(|| self.id.cmp(&other.id))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Binary-heap event queue with a monotone virtual clock.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
    clock_s: f64,
    processed: usize,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.clock_s
    }

    /// Events popped so far (the simulator-throughput metric).
    pub fn processed(&self) -> usize {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule an event; must not be in the virtual past.
    pub fn schedule(&mut self, ev: Event) {
        debug_assert!(
            ev.time_s >= self.clock_s,
            "event at {} scheduled before clock {}",
            ev.time_s,
            self.clock_s
        );
        self.heap.push(std::cmp::Reverse(ev));
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?.0;
        self.clock_s = ev.time_s;
        self.processed += 1;
        Some(ev)
    }
}

/// Which uplink carries an edge phase's model reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UploadChannel {
    /// Device → edge server (CE-FedAvg, Local-Edge, Hier-FAvg edge rounds).
    DeviceEdge,
    /// Device → cloud (FedAvg; Hier-FAvg's final round of a global round).
    DeviceCloud,
}

impl UploadChannel {
    pub fn bandwidth(self, net: &NetworkModel) -> f64 {
        match self {
            UploadChannel::DeviceEdge => net.b_d2e,
            UploadChannel::DeviceCloud => net.b_d2c,
        }
    }

    /// Bandwidth the given device reports over: a per-device uplink
    /// override (scenario capability profiles) applies to the edge
    /// channel; the cloud channel is always the shared `b_d2c`. With no
    /// override this is exactly [`UploadChannel::bandwidth`].
    pub fn device_bandwidth(self, net: &NetworkModel, device: usize) -> f64 {
        match self {
            UploadChannel::DeviceEdge => net
                .device_uplink
                .get(device)
                .copied()
                .flatten()
                .unwrap_or(net.b_d2e),
            UploadChannel::DeviceCloud => net.b_d2c,
        }
    }
}

/// One device's simulated timing within an edge phase.
#[derive(Debug, Clone)]
pub struct DeviceTiming {
    /// Global device id.
    pub device: usize,
    /// Seconds of local compute (steps · C / c_k).
    pub compute_s: f64,
    /// Seconds of model upload (W / channel bandwidth).
    pub upload_s: f64,
    /// Report arrival, seconds from the phase start.
    pub finish_s: f64,
    /// How the report fared against the policy's close.
    pub verdict: ReportVerdict,
}

impl DeviceTiming {
    /// Discarded outright by the close policy (deadline-drop).
    pub fn dropped(&self) -> bool {
        self.verdict == ReportVerdict::Dropped
    }

    /// Missed the close but kept for a stale merge (semi-sync).
    pub fn late(&self) -> bool {
        self.verdict == ReportVerdict::Late
    }
}

/// Simulated timing of one cluster's edge phase.
#[derive(Debug, Clone)]
pub struct PhaseTiming {
    /// Phase duration: when the policy closed the round.
    pub duration_s: f64,
    /// Compute portion of the duration (the straggler barrier, capped at
    /// the close).
    pub compute_s: f64,
    /// Upload portion of the duration (`duration - compute`).
    pub upload_s: f64,
    /// Per-device timing, in work-list (sorted participant) order.
    pub devices: Vec<DeviceTiming>,
    /// Events processed by the simulation (includes the late-upload drain
    /// and any timeout event).
    pub events: usize,
    /// Why the phase stopped accepting reports.
    pub close_reason: CloseReason,
}

/// Per-global-round accumulator the event estimator fills phase by phase;
/// empty in closed-form mode. Lives inside the coordinator's `RoundStats`.
#[derive(Debug, Clone, Default)]
pub struct RoundTiming {
    /// Accumulated virtual time per cluster (clusters progress through
    /// their edge phases independently and only barrier at the
    /// inter-cluster aggregation).
    pub cluster_time_s: Vec<f64>,
    /// Accumulated compute portion per cluster.
    pub cluster_compute_s: Vec<f64>,
    /// Accumulated upload portion per cluster.
    pub cluster_upload_s: Vec<f64>,
    /// Every simulated device timing of the round (all phases appended).
    pub device_timings: Vec<DeviceTiming>,
    /// Reports that made their phase close this round.
    pub on_time_devices: usize,
    /// Reports that missed their close but were kept for a stale merge.
    pub late_devices: usize,
    /// Kept-late reports from *any* earlier phase that were folded into
    /// one of this round's aggregates (filled by the coordinator's drain).
    pub stale_merged: usize,
    /// Simulated backhaul seconds of this round's gossip steps, recorded
    /// once by the plan interpreter when each step's hops are simulated
    /// for the clock barrier (so the round-latency breakdown does not
    /// re-simulate them).
    pub gossip_s: f64,
    /// Devices discarded outright by the close policy this round.
    pub dropped_devices: usize,
    /// Phase-close reason counts, indexed by [`CloseReason::index`].
    pub close_reasons: [usize; 4],
    /// Total events processed this round.
    pub events_processed: usize,
}

impl RoundTiming {
    /// Fold one cluster's phase into the round accumulator.
    pub fn record_phase(&mut self, cluster: usize, n_clusters: usize, pt: &PhaseTiming) {
        if self.cluster_time_s.len() < n_clusters {
            self.cluster_time_s.resize(n_clusters, 0.0);
            self.cluster_compute_s.resize(n_clusters, 0.0);
            self.cluster_upload_s.resize(n_clusters, 0.0);
        }
        self.cluster_time_s[cluster] += pt.duration_s;
        self.cluster_compute_s[cluster] += pt.compute_s;
        self.cluster_upload_s[cluster] += pt.upload_s;
        for d in &pt.devices {
            match d.verdict {
                ReportVerdict::OnTime => self.on_time_devices += 1,
                ReportVerdict::Late => self.late_devices += 1,
                ReportVerdict::Dropped => self.dropped_devices += 1,
            }
        }
        if !pt.devices.is_empty() {
            self.close_reasons[pt.close_reason.index()] += 1;
        }
        self.events_processed += pt.events;
        self.device_timings.extend(pt.devices.iter().cloned());
    }

    /// Compact close-reason label for the round: "-" when no phases were
    /// simulated, the reason's name when unanimous, "mixed" otherwise.
    pub fn close_reason_summary(&self) -> String {
        let total: usize = self.close_reasons.iter().sum();
        if total == 0 {
            return "-".into();
        }
        for r in CloseReason::ALL {
            if self.close_reasons[r.index()] == total {
                return r.name().into();
            }
        }
        "mixed".into()
    }
}

/// How the coordinator turns a round's training into simulated latency.
///
/// Two implementations: [`ClosedFormEstimator`] replays the paper's Eq. 8
/// (the fast default and the oracle for the equivalence tests) and
/// [`EventDrivenEstimator`] runs the discrete-event simulation above
/// (required for any policy other than the full barrier). Selected by the
/// config's `latency` field / the CLI's `--latency` flag.
pub trait LatencyEstimator: Send + Sync {
    fn name(&self) -> &'static str;

    /// Simulate one cluster's edge phase under the given close policy.
    /// `work` is `(device, steps)` in sorted participant order. Returns
    /// `None` in closed-form mode — no per-phase simulation, nobody is
    /// deferred or dropped, the coordinator keeps its Eq. 8 round-level
    /// path.
    fn phase_timing(
        &self,
        net: &NetworkModel,
        work: &[(usize, usize)],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> Option<PhaseTiming>;

    /// Latency of one whole global round of `plan`. `device_steps` are
    /// the merged per-device round totals (the Eq. 8 inputs); `timing` is
    /// the event accumulator (empty in closed-form mode). The plan's
    /// communication structure — how many report phases ride each uplink,
    /// how many gossip hops the backhaul carries — replaces the old
    /// closed `AlgorithmKind` dispatch.
    fn round_latency(
        &self,
        net: &NetworkModel,
        plan: &Plan,
        device_steps: &[(usize, usize)],
        timing: &RoundTiming,
    ) -> RoundLatency;
}

/// The paper's closed-form Eq. 8 — one aggregate number per round.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClosedFormEstimator;

impl LatencyEstimator for ClosedFormEstimator {
    fn name(&self) -> &'static str {
        "closed-form"
    }

    fn phase_timing(
        &self,
        _net: &NetworkModel,
        _work: &[(usize, usize)],
        _channel: UploadChannel,
        _policy: &dyn AggregationPolicy,
    ) -> Option<PhaseTiming> {
        None
    }

    /// The generalized Eq. 8: the straggler-max compute term plus one
    /// closed-form communication term per plan upload/gossip count. For
    /// the canned plans this reproduces the paper's per-algorithm closed
    /// forms (`NetworkModel::{ce_fedavg,fedavg,hier_favg,local_edge}_round`)
    /// bit for bit — same multiplication/association order, and the
    /// absent terms contribute an exact `+ 0.0`.
    fn round_latency(
        &self,
        net: &NetworkModel,
        plan: &Plan,
        device_steps: &[(usize, usize)],
        _timing: &RoundTiming,
    ) -> RoundLatency {
        let comms = plan.comms();
        RoundLatency {
            compute_s: net.compute_seconds(device_steps),
            upload_s: comms.edge_uploads as f64 * net.model_bits / net.b_d2e
                + comms.cloud_uploads as f64 * net.model_bits / net.b_d2c,
            backhaul_s: comms.gossip_pi as f64 * net.model_bits / net.b_e2e,
        }
    }
}

/// The discrete-event simulator (see the module docs for the event model).
#[derive(Debug, Clone, Copy, Default)]
pub struct EventDrivenEstimator;

impl EventDrivenEstimator {
    /// Run the per-device event simulation of one cluster's edge phase
    /// under `policy`. Reports landing after the policy's close are still
    /// simulated to completion (the late-upload drain) so their arrival
    /// times are known to the coordinator's stale-merge bookkeeping.
    pub fn simulate_phase(
        net: &NetworkModel,
        work: &[(usize, usize)],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> PhaseTiming {
        if work.is_empty() {
            return PhaseTiming {
                duration_s: 0.0,
                compute_s: 0.0,
                upload_s: 0.0,
                devices: Vec::new(),
                events: 0,
                close_reason: CloseReason::AllReported,
            };
        }
        // Per-device upload seconds: devices transmit on dedicated links,
        // and a scenario capability profile may give a device its own
        // uplink bandwidth. Without overrides every entry is the shared
        // `W / b` the pre-scenario simulator charged (bit-identical).
        let upload: Vec<f64> = work
            .iter()
            .map(|&(dev, _)| net.model_bits / channel.device_bandwidth(net, dev))
            .collect();
        let mut queue = EventQueue::new();
        for (slot, &(dev, steps)) in work.iter().enumerate() {
            queue.schedule(Event {
                time_s: steps as f64 * net.step_seconds(dev),
                kind: EventKind::ComputeDone,
                id: slot,
            });
        }
        let timeout = policy.timeout();
        if let Some((t, _)) = timeout {
            queue.schedule(Event { time_s: t, kind: EventKind::RoundClose, id: 0 });
        }
        let mut compute = vec![0.0f64; work.len()];
        let mut finish = vec![0.0f64; work.len()];
        let mut reported = 0usize;
        let mut close: Option<(f64, CloseReason)> = None;
        while let Some(ev) = queue.pop() {
            match ev.kind {
                EventKind::ComputeDone => {
                    compute[ev.id] = ev.time_s;
                    queue.schedule(Event {
                        time_s: ev.time_s + upload[ev.id],
                        kind: EventKind::UploadDone,
                        id: ev.id,
                    });
                }
                EventKind::UploadDone => {
                    finish[ev.id] = ev.time_s;
                    reported += 1;
                    if close.is_none() && policy.closes_at_report(reported, work.len()) {
                        let reason = if reported == work.len() {
                            CloseReason::AllReported
                        } else {
                            CloseReason::KthReport
                        };
                        close = Some((ev.time_s, reason));
                    }
                }
                EventKind::RoundClose => {
                    if close.is_none() {
                        let (_, reason) =
                            timeout.expect("RoundClose events come from the armed timeout");
                        close = Some((ev.time_s, reason));
                    }
                }
                EventKind::BackhaulDone => unreachable!("no backhaul inside an edge phase"),
            }
        }
        let (close_s, close_reason) =
            close.expect("every report arrives eventually, so the phase must close");
        let devices: Vec<DeviceTiming> = work
            .iter()
            .enumerate()
            .map(|(slot, &(dev, _))| DeviceTiming {
                device: dev,
                compute_s: compute[slot],
                upload_s: upload[slot],
                finish_s: finish[slot],
                verdict: if finish[slot] <= close_s {
                    ReportVerdict::OnTime
                } else {
                    policy.late_verdict()
                },
            })
            .collect();
        let barrier = compute.iter().fold(0.0, f64::max).min(close_s);
        PhaseTiming {
            duration_s: close_s,
            compute_s: barrier,
            upload_s: close_s - barrier,
            devices,
            events: queue.processed(),
            close_reason,
        }
    }

    /// Simulate π sequential gossip hops on the backhaul; returns
    /// (virtual seconds, events processed).
    pub fn simulate_gossip(net: &NetworkModel, pi: usize) -> (f64, usize) {
        let hop = net.model_bits / net.b_e2e;
        let mut queue = EventQueue::new();
        if pi > 0 {
            queue.schedule(Event { time_s: hop, kind: EventKind::BackhaulDone, id: 0 });
        }
        while let Some(ev) = queue.pop() {
            if ev.id + 1 < pi {
                queue.schedule(Event {
                    time_s: ev.time_s + hop,
                    kind: EventKind::BackhaulDone,
                    id: ev.id + 1,
                });
            }
        }
        (queue.now(), queue.processed())
    }
}

impl LatencyEstimator for EventDrivenEstimator {
    fn name(&self) -> &'static str {
        "event"
    }

    fn phase_timing(
        &self,
        net: &NetworkModel,
        work: &[(usize, usize)],
        channel: UploadChannel,
        policy: &dyn AggregationPolicy,
    ) -> Option<PhaseTiming> {
        Some(Self::simulate_phase(net, work, channel, policy))
    }

    fn round_latency(
        &self,
        _net: &NetworkModel,
        _plan: &Plan,
        _device_steps: &[(usize, usize)],
        timing: &RoundTiming,
    ) -> RoundLatency {
        // The slowest cluster's trajectory defines the training segment of
        // the round; clusters only barrier at the inter-cluster step.
        // Ties break toward the lowest cluster index (deterministic).
        let mut slowest = 0usize;
        let mut t = f64::NEG_INFINITY;
        for (i, &ct) in timing.cluster_time_s.iter().enumerate() {
            if ct > t {
                t = ct;
                slowest = i;
            }
        }
        let (compute, upload) = if timing.cluster_time_s.is_empty() {
            (0.0, 0.0)
        } else {
            (
                timing.cluster_compute_s[slowest],
                timing.cluster_upload_s[slowest],
            )
        };
        // The plan's gossip steps were already simulated (once each) by
        // the interpreter for the clock barrier; reuse that accumulator
        // rather than replaying the hops here.
        RoundLatency {
            compute_s: compute,
            upload_s: upload,
            backhaul_s: timing.gossip_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::policy::{DeadlineDrop, FullBarrier, SemiSync};

    fn net() -> NetworkModel {
        // 1 MFLOP/sample, batch 50, 1M params (the parent module's fixture).
        NetworkModel::paper_defaults(4, 1e6, 50, 1_000_000)
    }

    #[test]
    fn queue_orders_by_time_kind_id() {
        let mut q = EventQueue::new();
        q.schedule(Event { time_s: 2.0, kind: EventKind::ComputeDone, id: 0 });
        q.schedule(Event { time_s: 1.0, kind: EventKind::UploadDone, id: 1 });
        q.schedule(Event { time_s: 1.0, kind: EventKind::RoundClose, id: 0 });
        q.schedule(Event { time_s: 1.0, kind: EventKind::ComputeDone, id: 1 });
        q.schedule(Event { time_s: 1.0, kind: EventKind::ComputeDone, id: 0 });
        let order: Vec<(f64, EventKind, usize)> = std::iter::from_fn(|| q.pop())
            .map(|e| (e.time_s, e.kind, e.id))
            .collect();
        assert_eq!(
            order,
            vec![
                (1.0, EventKind::ComputeDone, 0),
                (1.0, EventKind::ComputeDone, 1),
                (1.0, EventKind::UploadDone, 1),
                // The timeout pops after a simultaneous report: a device
                // landing exactly at the cutoff is on time.
                (1.0, EventKind::RoundClose, 0),
                (2.0, EventKind::ComputeDone, 0),
            ]
        );
        assert_eq!(q.processed(), 5);
        assert_eq!(q.now(), 2.0);
    }

    #[test]
    fn phase_matches_closed_form_under_full_barrier() {
        let m = net();
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &FullBarrier,
        );
        let want_compute = 16.0 * m.step_seconds(0);
        let want_upload = m.model_bits / m.b_d2e;
        assert!((pt.compute_s - want_compute).abs() < 1e-12);
        assert!((pt.upload_s - want_upload).abs() < 1e-12);
        assert!((pt.duration_s - (want_compute + want_upload)).abs() < 1e-12);
        assert_eq!(pt.devices.len(), 4);
        assert!(pt.devices.iter().all(|d| d.verdict == ReportVerdict::OnTime));
        assert_eq!(pt.close_reason, CloseReason::AllReported);
        // Two events per device: ComputeDone + UploadDone (no timeout).
        assert_eq!(pt.events, 8);
    }

    #[test]
    fn deadline_drops_slow_devices_and_caps_duration() {
        let mut m = net();
        m.device_flops[2] /= 1000.0; // straggler: ~3.5 s compute vs ~3.5 ms
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let fast_finish = 16.0 * m.step_seconds(0) + m.model_bits / m.b_d2e;
        let dl = fast_finish * 1.5; // fast devices report, the straggler not
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &DeadlineDrop { deadline_s: dl },
        );
        let dropped: Vec<usize> =
            pt.devices.iter().filter(|d| d.dropped()).map(|d| d.device).collect();
        assert_eq!(dropped, vec![2]);
        assert!((pt.duration_s - dl).abs() < 1e-12, "duration capped at the deadline");
        assert!(pt.devices[2].finish_s > dl);
        assert_eq!(pt.close_reason, CloseReason::Deadline);
        // The straggler's upload still drains after the close.
        assert_eq!(pt.events, 9, "4 computes + 4 uploads + 1 timeout");
    }

    #[test]
    fn all_dropped_phase_lasts_exactly_the_deadline() {
        let m = net();
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &DeadlineDrop { deadline_s: 1e-9 },
        );
        assert!(pt.devices.iter().all(|d| d.dropped()));
        assert!((pt.duration_s - 1e-9).abs() < 1e-18);
        assert_eq!(pt.close_reason, CloseReason::Deadline);
    }

    #[test]
    fn semi_sync_closes_at_kth_report_and_keeps_late_reports() {
        let mut m = net();
        m.device_flops[1] /= 1000.0; // two stragglers
        m.device_flops[3] /= 2000.0;
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k: 2, timeout_s: f64::INFINITY, staleness_exp: 1.0 },
        );
        // Devices 0 and 2 (full speed) report first; the phase closes on
        // the second report, the stragglers are late-but-kept.
        assert_eq!(pt.close_reason, CloseReason::KthReport);
        let fast_finish = 16.0 * m.step_seconds(0) + m.model_bits / m.b_d2e;
        assert!((pt.duration_s - fast_finish).abs() < 1e-12);
        assert!(pt.devices[0].verdict == ReportVerdict::OnTime);
        assert!(pt.devices[2].verdict == ReportVerdict::OnTime);
        assert!(pt.devices[1].late() && pt.devices[3].late());
        // Late uploads drained: their true arrival times are recorded.
        assert!(pt.devices[1].finish_s > pt.duration_s);
        assert!(pt.devices[3].finish_s > pt.devices[1].finish_s);
    }

    #[test]
    fn semi_sync_timeout_beats_kth_report_when_earlier() {
        let m = net(); // homogeneous: all reports land together
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k: 4, timeout_s: 1e-9, staleness_exp: 1.0 },
        );
        assert_eq!(pt.close_reason, CloseReason::Timeout);
        assert!((pt.duration_s - 1e-9).abs() < 1e-18);
        assert!(pt.devices.iter().all(|d| d.late()), "everyone is late, nobody dropped");
    }

    #[test]
    fn semi_sync_k_equal_n_matches_full_barrier_exactly() {
        // The degenerate policy: K = N, no timeout, zero staleness
        // exponent. Same close instant, same verdicts, same reason —
        // bit-identical, the oracle the integration suite leans on.
        let mut m = net();
        m.device_flops[1] /= 3.0;
        m.device_flops[2] /= 7.0;
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let barrier = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &FullBarrier,
        );
        let degenerate = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k: 4, timeout_s: f64::INFINITY, staleness_exp: 0.0 },
        );
        assert_eq!(barrier.duration_s.to_bits(), degenerate.duration_s.to_bits());
        assert_eq!(barrier.compute_s.to_bits(), degenerate.compute_s.to_bits());
        assert_eq!(barrier.upload_s.to_bits(), degenerate.upload_s.to_bits());
        assert_eq!(barrier.close_reason, degenerate.close_reason);
        assert_eq!(barrier.events, degenerate.events);
        for (a, b) in barrier.devices.iter().zip(&degenerate.devices) {
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
            assert_eq!(a.verdict, b.verdict);
        }
    }

    #[test]
    fn empty_phase_is_zero() {
        let pt = EventDrivenEstimator::simulate_phase(
            &net(),
            &[],
            UploadChannel::DeviceEdge,
            &DeadlineDrop { deadline_s: 1.0 },
        );
        assert_eq!(pt.duration_s, 0.0);
        assert_eq!(pt.events, 0);
        assert!(pt.devices.is_empty());
    }

    #[test]
    fn gossip_hops_sum_to_closed_form() {
        let m = net();
        let (t, events) = EventDrivenEstimator::simulate_gossip(&m, 10);
        let want = 10.0 * m.model_bits / m.b_e2e;
        assert!((t - want).abs() / want < 1e-12);
        assert_eq!(events, 10);
        let (t0, e0) = EventDrivenEstimator::simulate_gossip(&m, 0);
        assert_eq!((t0, e0), (0.0, 0));
    }

    #[test]
    fn per_device_uplink_override_slows_only_that_device() {
        let mut m = net();
        // Device 1 reports over a 1 Mbps radio instead of the shared 10.
        m.device_uplink[1] = Some(1e6);
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &FullBarrier,
        );
        assert!((pt.devices[1].upload_s - m.model_bits / 1e6).abs() < 1e-9);
        for d in [0usize, 2, 3] {
            assert!((pt.devices[d].upload_s - m.model_bits / m.b_d2e).abs() < 1e-9);
        }
        // The barrier waits for the overridden device's slower report.
        assert!(pt.devices[1].finish_s > pt.devices[0].finish_s);
        assert_eq!(pt.duration_s.to_bits(), pt.devices[1].finish_s.to_bits());
        // Overrides never touch the cloud channel.
        let cloud = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceCloud,
            &FullBarrier,
        );
        assert!((cloud.devices[1].upload_s - m.model_bits / m.b_d2c).abs() < 1e-9);
    }

    #[test]
    fn cloud_channel_uses_cloud_bandwidth() {
        let m = net();
        let work = [(0usize, 16usize)];
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceCloud,
            &FullBarrier,
        );
        assert!((pt.devices[0].upload_s - m.model_bits / m.b_d2c).abs() < 1e-12);
    }

    #[test]
    fn round_timing_accumulates_phases_and_verdicts() {
        let mut m = net();
        m.device_flops[3] /= 1000.0;
        let work: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let pt = EventDrivenEstimator::simulate_phase(
            &m,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k: 3, timeout_s: f64::INFINITY, staleness_exp: 1.0 },
        );
        let mut rt = RoundTiming::default();
        rt.record_phase(1, 2, &pt);
        rt.record_phase(1, 2, &pt);
        assert!((rt.cluster_time_s[1] - 2.0 * pt.duration_s).abs() < 1e-12);
        assert_eq!(rt.cluster_time_s[0], 0.0);
        assert_eq!(rt.device_timings.len(), 8);
        assert_eq!(rt.on_time_devices, 6);
        assert_eq!(rt.late_devices, 2);
        assert_eq!(rt.dropped_devices, 0);
        assert_eq!(rt.close_reasons[CloseReason::KthReport.index()], 2);
        assert_eq!(rt.close_reason_summary(), "kth-report");
        // The estimator picks cluster 1 (the slowest) for the breakdown;
        // with no gossip recorded, no backhaul is charged.
        let plan = Plan::parse("edge(16)*2").unwrap();
        let lat = EventDrivenEstimator.round_latency(&m, &plan, &[], &rt);
        assert!((lat.total() - 2.0 * pt.duration_s).abs() < 1e-9);
        assert_eq!(lat.backhaul_s, 0.0);
        // Gossip hops recorded by the interpreter ride into the breakdown.
        let hops = EventDrivenEstimator::simulate_gossip(&m, 10).0;
        rt.gossip_s += hops;
        let lat_g = EventDrivenEstimator.round_latency(&m, &plan, &[], &rt);
        assert_eq!(lat_g.backhaul_s.to_bits(), hops.to_bits());
    }

    #[test]
    fn closed_form_round_latency_matches_the_per_algorithm_forms() {
        // The plan-structured Eq. 8 must be bit-identical to the paper's
        // per-algorithm closed forms for the canned shapes (tau=2, q=8,
        // pi=10; steps = q·tau per device).
        let m = net();
        let steps: Vec<(usize, usize)> = (0..4).map(|d| (d, 16)).collect();
        let cases = [
            ("edge(2)*8; gossip(10)", m.ce_fedavg_round(&steps, 8, 10)),
            ("edge(16)@cloud; cloud", m.fedavg_round(&steps)),
            ("edge(2)*7; edge(2)@cloud; cloud", m.hier_favg_round(&steps, 8)),
            ("edge(2)*8", m.local_edge_round(&steps, 8)),
        ];
        for (spec, want) in cases {
            let plan = Plan::parse(spec).unwrap();
            let got = ClosedFormEstimator.round_latency(
                &m,
                &plan,
                &steps,
                &RoundTiming::default(),
            );
            assert_eq!(got.compute_s.to_bits(), want.compute_s.to_bits(), "{spec}");
            assert_eq!(got.upload_s.to_bits(), want.upload_s.to_bits(), "{spec}");
            assert_eq!(got.backhaul_s.to_bits(), want.backhaul_s.to_bits(), "{spec}");
        }
    }

    #[test]
    fn close_reason_summary_handles_empty_and_mixed() {
        let rt = RoundTiming::default();
        assert_eq!(rt.close_reason_summary(), "-");
        let mut rt = RoundTiming::default();
        rt.close_reasons[CloseReason::AllReported.index()] = 1;
        rt.close_reasons[CloseReason::Timeout.index()] = 1;
        assert_eq!(rt.close_reason_summary(), "mixed");
    }
}
