//! Synthetic FEMNIST-like / CIFAR-like data generators.
//!
//! Class-prototype model: each class c has a fixed prototype vector p_c
//! (drawn once from the generator seed); a sample of class c is
//! `a·p_c + noise`, optionally plus a per-writer style vector s_k (the
//! FEMNIST writer effect). Classes are linearly separable in expectation
//! with controllable SNR, so convergence/accuracy dynamics behave like a
//! real classification task while remaining fully deterministic and
//! offline. See DESIGN.md §1 for the substitution argument.

use crate::data::Dataset;
use crate::util::rng::Rng;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub dim: usize,
    pub num_classes: usize,
    /// Scale of the class prototype (signal).
    pub signal: f32,
    /// Std of per-sample additive noise.
    pub noise: f32,
    /// Std of the per-writer style shift (0 = no writer effect).
    pub writer_style: f32,
}

impl SyntheticSpec {
    /// FEMNIST-like: 28×28 grayscale, 62 classes, strong writer effect.
    pub fn femnist_like() -> SyntheticSpec {
        SyntheticSpec {
            dim: 28 * 28,
            num_classes: 62,
            signal: 1.0,
            noise: 0.8,
            writer_style: 0.5,
        }
    }

    /// CIFAR-like: 32×32×3, 10 classes, no writer effect (the paper uses a
    /// Dirichlet split on a common pool instead).
    pub fn cifar_like() -> SyntheticSpec {
        SyntheticSpec {
            dim: 32 * 32 * 3,
            num_classes: 10,
            signal: 1.0,
            noise: 1.0,
            writer_style: 0.0,
        }
    }

    /// Small synthetic task matching the `mlp_synth` model (fast tests).
    pub fn mlp_synth() -> SyntheticSpec {
        SyntheticSpec { dim: 64, num_classes: 10, signal: 1.0, noise: 0.6, writer_style: 0.3 }
    }
}

/// The class-prototype bank for one generator seed.
pub struct Prototypes {
    spec: SyntheticSpec,
    /// Row-major `[num_classes, dim]`.
    protos: Vec<f32>,
}

impl Prototypes {
    pub fn new(spec: SyntheticSpec, rng: &Rng) -> Prototypes {
        let mut r = rng.split(0xC1A5);
        let mut protos = vec![0.0f32; spec.num_classes * spec.dim];
        for v in &mut protos {
            *v = r.normal() * spec.signal;
        }
        Prototypes { spec, protos }
    }

    pub fn spec(&self) -> &SyntheticSpec {
        &self.spec
    }

    fn proto(&self, class: usize) -> &[f32] {
        &self.protos[class * self.spec.dim..(class + 1) * self.spec.dim]
    }

    /// One sample of `class` with a writer style vector (may be zeros).
    fn sample_into(&self, class: usize, style: &[f32], rng: &mut Rng, out: &mut Vec<f32>) {
        let p = self.proto(class);
        out.clear();
        out.reserve(self.spec.dim);
        for d in 0..self.spec.dim {
            out.push(p[d] + style[d] + rng.normal() * self.spec.noise);
        }
    }

    /// Generate `count` samples whose labels follow `label_probs`
    /// (length = num_classes), with a writer style drawn from `writer_rng`.
    /// Returns a dataset local to one writer/device.
    pub fn writer_dataset(
        &self,
        count: usize,
        label_probs: &[f64],
        writer_rng: &Rng,
    ) -> Dataset {
        assert_eq!(label_probs.len(), self.spec.num_classes);
        let mut style_rng = writer_rng.split(1);
        let style: Vec<f32> = (0..self.spec.dim)
            .map(|_| style_rng.normal() * self.spec.writer_style)
            .collect();
        let mut sample_rng = writer_rng.split(2);
        let mut ds = Dataset::new(self.spec.dim, self.spec.num_classes);
        let mut buf = Vec::new();
        for _ in 0..count {
            let c = sample_rng.weighted(label_probs);
            self.sample_into(c, &style, &mut sample_rng, &mut buf);
            ds.push(&buf, c as u32);
        }
        ds
    }

    /// Generate a balanced global pool of `count` samples (CIFAR path —
    /// partitioned across devices afterwards by `data::partition`).
    pub fn global_pool(&self, count: usize, rng: &Rng) -> Dataset {
        let mut r = rng.split(3);
        let zeros = vec![0.0f32; self.spec.dim];
        let mut ds = Dataset::new(self.spec.dim, self.spec.num_classes);
        let mut buf = Vec::new();
        for i in 0..count {
            let c = i % self.spec.num_classes; // exactly balanced
            self.sample_into(c, &zeros, &mut r, &mut buf);
            ds.push(&buf, c as u32);
        }
        ds
    }
}

/// A federated dataset: per-device training shards + a common test set
/// (paper §6.1: common test set = union of per-device test splits for
/// FEMNIST, the held-out global pool for CIFAR).
pub struct FederatedData {
    pub device_train: Vec<Dataset>,
    pub test: Dataset,
}

impl FederatedData {
    pub fn total_train(&self) -> usize {
        self.device_train.iter().map(|d| d.len()).sum()
    }
}

/// FEMNIST-style federation: each device is a writer with its own label
/// distribution Dirichlet(`label_alpha`) and style; 90/10 train/test split
/// per writer, common test = union of writer test shards (paper §6.1).
pub fn femnist_federation(
    spec: SyntheticSpec,
    n_devices: usize,
    samples_per_device: usize,
    label_alpha: f64,
    rng: &Rng,
) -> FederatedData {
    let protos = Prototypes::new(spec.clone(), rng);
    let mut device_train = Vec::with_capacity(n_devices);
    let mut test = Dataset::new(spec.dim, spec.num_classes);
    for k in 0..n_devices {
        let wrng = rng.split(0x3EED_0000 + k as u64);
        let mut lrng = wrng.split(0);
        let probs = lrng.dirichlet(label_alpha, spec.num_classes);
        let full = protos.writer_dataset(samples_per_device, &probs, &wrng);
        // 90/10 split: the last tenth goes to the common test set.
        let n_train = (full.len() * 9) / 10;
        let mut train = Dataset::new(spec.dim, spec.num_classes);
        for i in 0..full.len() {
            if i < n_train {
                train.push(full.feature(i), full.labels[i]);
            } else {
                test.push(full.feature(i), full.labels[i]);
            }
        }
        device_train.push(train);
    }
    FederatedData { device_train, test }
}

/// CIFAR-style federation: balanced global pool split across devices with
/// the given partitioner output, held-out balanced test pool.
pub fn pool_federation(
    spec: SyntheticSpec,
    pool_size: usize,
    test_size: usize,
    device_indices: &[Vec<usize>],
    rng: &Rng,
) -> FederatedData {
    let protos = Prototypes::new(spec.clone(), rng);
    let pool = protos.global_pool(pool_size, &rng.split(100));
    let test = protos.global_pool(test_size, &rng.split(200));
    let device_train = device_indices
        .iter()
        .map(|idx| {
            let mut d = Dataset::new(spec.dim, spec.num_classes);
            for &i in idx {
                d.push(pool.feature(i), pool.labels[i]);
            }
            d
        })
        .collect();
    FederatedData { device_train, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototypes_deterministic() {
        let spec = SyntheticSpec::mlp_synth();
        let a = Prototypes::new(spec.clone(), &Rng::new(5));
        let b = Prototypes::new(spec, &Rng::new(5));
        assert_eq!(a.protos, b.protos);
    }

    #[test]
    fn writer_dataset_respects_label_distribution() {
        let spec = SyntheticSpec::mlp_synth();
        let protos = Prototypes::new(spec.clone(), &Rng::new(1));
        // All mass on class 3.
        let mut probs = vec![0.0; spec.num_classes];
        probs[3] = 1.0;
        let ds = protos.writer_dataset(50, &probs, &Rng::new(2));
        assert_eq!(ds.len(), 50);
        assert!(ds.labels.iter().all(|&l| l == 3));
        ds.validate().unwrap();
    }

    #[test]
    fn global_pool_is_balanced() {
        let spec = SyntheticSpec::mlp_synth();
        let protos = Prototypes::new(spec.clone(), &Rng::new(1));
        let ds = protos.global_pool(100, &Rng::new(2));
        let counts = ds.class_counts();
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-prototype classification on fresh samples should beat
        // chance by a wide margin — the learnability guarantee the
        // convergence experiments rely on.
        let spec = SyntheticSpec::mlp_synth();
        let protos = Prototypes::new(spec.clone(), &Rng::new(7));
        let ds = protos.global_pool(200, &Rng::new(8));
        let mut correct = 0;
        for i in 0..ds.len() {
            let x = ds.feature(i);
            let best = (0..spec.num_classes)
                .max_by(|&a, &b| {
                    let da: f32 = x
                        .iter()
                        .zip(protos.proto(a))
                        .map(|(u, v)| -((u - v) * (u - v)))
                        .sum();
                    let db: f32 = x
                        .iter()
                        .zip(protos.proto(b))
                        .map(|(u, v)| -((u - v) * (u - v)))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as u32 == ds.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.len() as f64;
        assert!(acc > 0.8, "nearest-prototype accuracy {acc}");
    }

    #[test]
    fn femnist_federation_shapes() {
        let fed = femnist_federation(SyntheticSpec::mlp_synth(), 8, 40, 0.3, &Rng::new(3));
        assert_eq!(fed.device_train.len(), 8);
        assert!(fed.device_train.iter().all(|d| d.len() == 36)); // 90%
        assert_eq!(fed.test.len(), 8 * 4); // union of 10% shards
        assert_eq!(fed.total_train(), 8 * 36);
    }

    #[test]
    fn femnist_devices_are_heterogeneous() {
        let fed = femnist_federation(SyntheticSpec::mlp_synth(), 4, 100, 0.3, &Rng::new(3));
        // Label histograms across devices should differ (non-IID writers).
        let h0 = fed.device_train[0].class_counts();
        let h1 = fed.device_train[1].class_counts();
        assert_ne!(h0, h1);
    }

    #[test]
    fn pool_federation_respects_indices() {
        let spec = SyntheticSpec::mlp_synth();
        let idx = vec![vec![0, 2, 4], vec![1, 3]];
        let fed = pool_federation(spec, 10, 20, &idx, &Rng::new(4));
        assert_eq!(fed.device_train[0].len(), 3);
        assert_eq!(fed.device_train[1].len(), 2);
        assert_eq!(fed.test.len(), 20);
        // labels follow pool positions: pool label of i is i % 10
        assert_eq!(fed.device_train[0].labels, vec![0, 2, 4]);
    }
}
