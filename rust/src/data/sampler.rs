//! Epoch-based mini-batch sampling over a device's local dataset.
//!
//! The paper (following Reddi et al. [42]) runs τ local *epochs* rather
//! than τ fixed steps; [`EpochSampler`] shuffles per epoch and yields
//! fixed-size [`Batch`]es (padding the tail batch by cycling, with `valid`
//! recording the real count so step-weighted aggregation stays exact).

use crate::data::{Batch, Dataset};
use crate::util::rng::Rng;

/// Deterministic per-device batch sampler.
pub struct EpochSampler {
    batch_size: usize,
    rng: Rng,
    order: Vec<usize>,
}

impl EpochSampler {
    pub fn new(n_samples: usize, batch_size: usize, rng: Rng) -> EpochSampler {
        assert!(n_samples > 0, "sampler over empty dataset");
        assert!(batch_size > 0);
        EpochSampler { batch_size, rng, order: (0..n_samples).collect() }
    }

    /// Number of batches in one epoch (ceil division).
    pub fn batches_per_epoch(&self) -> usize {
        self.order.len().div_ceil(self.batch_size)
    }

    /// Shuffle and return the batch index lists for one epoch.
    pub fn epoch(&mut self) -> Vec<Vec<usize>> {
        self.rng.shuffle(&mut self.order);
        self.order
            .chunks(self.batch_size)
            .map(|c| c.to_vec())
            .collect()
    }

    /// Convenience: gather one epoch of concrete batches from `data`.
    pub fn epoch_batches(&mut self, data: &Dataset) -> Vec<Batch> {
        self.epoch()
            .into_iter()
            .map(|idx| Batch::gather(data, &idx, self.batch_size))
            .collect()
    }
}

/// Split a test set into fixed-size batches (no shuffling; padded tail).
pub fn eval_batches(data: &Dataset, batch_size: usize) -> Vec<Batch> {
    assert!(!data.is_empty());
    (0..data.len())
        .collect::<Vec<_>>()
        .chunks(batch_size)
        .map(|c| Batch::gather(data, c, batch_size))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let mut d = Dataset::new(1, 2);
        for i in 0..n {
            d.push(&[i as f32], (i % 2) as u32);
        }
        d
    }

    #[test]
    fn epoch_covers_every_sample_once() {
        let mut s = EpochSampler::new(10, 3, Rng::new(1));
        let batches = s.epoch();
        assert_eq!(batches.len(), 4);
        let mut all: Vec<usize> = batches.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn epochs_reshuffle() {
        let mut s = EpochSampler::new(20, 20, Rng::new(2));
        let a = s.epoch()[0].clone();
        let b = s.epoch()[0].clone();
        assert_ne!(a, b);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = EpochSampler::new(12, 4, Rng::new(3));
        let mut b = EpochSampler::new(12, 4, Rng::new(3));
        assert_eq!(a.epoch(), b.epoch());
    }

    #[test]
    fn batch_gathering_pads_tail() {
        let d = toy(5);
        let mut s = EpochSampler::new(5, 4, Rng::new(4));
        let batches = s.epoch_batches(&d);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].valid, 4);
        assert_eq!(batches[1].valid, 1);
        assert_eq!(batches[1].x.len(), 4); // padded to batch_size * dim
    }

    #[test]
    fn batches_per_epoch_ceil() {
        assert_eq!(EpochSampler::new(10, 3, Rng::new(0)).batches_per_epoch(), 4);
        assert_eq!(EpochSampler::new(9, 3, Rng::new(0)).batches_per_epoch(), 3);
    }

    #[test]
    fn eval_batches_preserve_order() {
        let d = toy(7);
        let bs = eval_batches(&d, 3);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[0].x, vec![0.0, 1.0, 2.0]);
        assert_eq!(bs[2].valid, 1);
    }
}
