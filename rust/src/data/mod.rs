//! Datasets, synthetic generators, partitioners and batch sampling.
//!
//! The paper evaluates on FEMNIST (naturally non-IID across writers) and
//! CIFAR-10 under a Dirichlet(0.5) device split. Neither dataset is
//! downloadable in this offline environment, so [`synthetic`] provides
//! class-prototype generators with the same *heterogeneity structure*
//! (label skew + per-writer feature shift) — see DESIGN.md §1 for why this
//! preserves the behaviour under study. [`partition`] implements every
//! split the paper uses, including the two-level cluster-IID /
//! cluster-non-IID schemes of Fig. 5.

pub mod partition;
pub mod sampler;
pub mod synthetic;

use crate::error::{CfelError, Result};

/// A flat in-memory dataset: `features` is row-major `[len, dim]`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub dim: usize,
    pub num_classes: usize,
    pub features: Vec<f32>,
    pub labels: Vec<u32>,
}

impl Dataset {
    pub fn new(dim: usize, num_classes: usize) -> Dataset {
        Dataset { dim, num_classes, features: Vec::new(), labels: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn feature(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    pub fn push(&mut self, feature: &[f32], label: u32) {
        debug_assert_eq!(feature.len(), self.dim);
        debug_assert!((label as usize) < self.num_classes);
        self.features.extend_from_slice(feature);
        self.labels.push(label);
    }

    /// Per-class sample counts (partitioners + tests).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.features.len() != self.labels.len() * self.dim {
            return Err(CfelError::Data(format!(
                "feature storage {} != {} samples x dim {}",
                self.features.len(),
                self.labels.len(),
                self.dim
            )));
        }
        if let Some(&l) = self.labels.iter().find(|&&l| l as usize >= self.num_classes) {
            return Err(CfelError::Data(format!(
                "label {l} out of range (num_classes {})",
                self.num_classes
            )));
        }
        Ok(())
    }
}

/// A fixed-size training batch gathered from a dataset (padded + masked).
#[derive(Debug, Clone)]
pub struct Batch {
    /// Row-major `[batch_size, dim]`.
    pub x: Vec<f32>,
    /// `[batch_size]`.
    pub y: Vec<i32>,
    /// Number of real (non-padded) leading examples.
    pub valid: usize,
}

impl Batch {
    /// Gather `indices` from `data`, padding up to `batch_size` by cycling
    /// the gathered examples (masked out via `valid` at evaluation).
    pub fn gather(data: &Dataset, indices: &[usize], batch_size: usize) -> Batch {
        assert!(!indices.is_empty(), "cannot build a batch from no samples");
        assert!(indices.len() <= batch_size);
        let mut x = Vec::with_capacity(batch_size * data.dim);
        let mut y = Vec::with_capacity(batch_size);
        for slot in 0..batch_size {
            let i = indices[slot % indices.len()];
            x.extend_from_slice(data.feature(i));
            y.push(data.labels[i] as i32);
        }
        Batch { x, y, valid: indices.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let mut d = Dataset::new(2, 3);
        d.push(&[0.0, 1.0], 0);
        d.push(&[2.0, 3.0], 1);
        d.push(&[4.0, 5.0], 2);
        d
    }

    #[test]
    fn push_and_access() {
        let d = toy();
        assert_eq!(d.len(), 3);
        assert_eq!(d.feature(1), &[2.0, 3.0]);
        assert_eq!(d.class_counts(), vec![1, 1, 1]);
        d.validate().unwrap();
    }

    #[test]
    fn validate_catches_corruption() {
        let mut d = toy();
        d.labels.push(7); // out of range + storage mismatch
        assert!(d.validate().is_err());
    }

    #[test]
    fn batch_gather_exact() {
        let d = toy();
        let b = Batch::gather(&d, &[2, 0], 2);
        assert_eq!(b.valid, 2);
        assert_eq!(b.y, vec![2, 0]);
        assert_eq!(b.x, vec![4.0, 5.0, 0.0, 1.0]);
    }

    #[test]
    fn batch_gather_pads_by_cycling() {
        let d = toy();
        let b = Batch::gather(&d, &[1], 4);
        assert_eq!(b.valid, 1);
        assert_eq!(b.y, vec![1, 1, 1, 1]);
        assert_eq!(b.x.len(), 8);
    }

    #[test]
    #[should_panic]
    fn batch_gather_rejects_empty() {
        let d = toy();
        let _ = Batch::gather(&d, &[], 4);
    }
}
