//! Device / cluster partitioning schemes (paper §6.1 + Fig. 5).
//!
//! All functions return per-device index lists into a global pool. The
//! invariants (checked by tests + the property suite): partitions are
//! disjoint, conserve samples where the scheme is exhaustive, and every
//! device ends up non-empty.

use crate::error::{CfelError, Result};
use crate::util::rng::Rng;

/// IID: shuffle and deal round-robin; devices differ in size by at most 1.
pub fn iid(n_samples: usize, n_devices: usize, rng: &Rng) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n_samples).collect();
    rng.split(0).shuffle(&mut idx);
    let mut out = vec![Vec::new(); n_devices];
    for (pos, i) in idx.into_iter().enumerate() {
        out[pos % n_devices].push(i);
    }
    out
}

/// Dirichlet(alpha) label-skew split (Hsu et al. [41], the paper's CIFAR
/// default with alpha = 0.5): for each class, split its samples across
/// devices with Dirichlet proportions. Devices left empty (possible at
/// tiny alpha) are topped up with one sample stolen from the largest
/// device so every device can train.
pub fn dirichlet(
    labels: &[u32],
    num_classes: usize,
    n_devices: usize,
    alpha: f64,
    rng: &Rng,
) -> Vec<Vec<usize>> {
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &l) in labels.iter().enumerate() {
        per_class[l as usize].push(i);
    }
    let mut out = vec![Vec::new(); n_devices];
    let mut r = rng.split(1);
    for class_idx in per_class.into_iter() {
        if class_idx.is_empty() {
            continue;
        }
        let props = r.dirichlet(alpha, n_devices);
        // Cumulative allocation keeps exact sample conservation.
        let n = class_idx.len();
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (dev, &p) in props.iter().enumerate() {
            acc += p;
            let end = if dev + 1 == n_devices {
                n
            } else {
                (acc * n as f64).round() as usize
            }
            .clamp(start, n);
            out[dev].extend_from_slice(&class_idx[start..end]);
            start = end;
        }
    }
    rebalance_empty(&mut out);
    out
}

/// Shard split (McMahan et al. [6]): sort by label, cut into
/// `n_devices * shards_per_device` shards, deal `shards_per_device` random
/// shards to each device — every device sees at most `shards_per_device`
/// labels (the paper's "2 shards ⇒ 2 labels per device").
pub fn shards(
    labels: &[u32],
    n_devices: usize,
    shards_per_device: usize,
    rng: &Rng,
) -> Result<Vec<Vec<usize>>> {
    let n_shards = n_devices * shards_per_device;
    if labels.len() < n_shards {
        return Err(CfelError::Data(format!(
            "{} samples cannot fill {n_shards} shards",
            labels.len()
        )));
    }
    let mut idx: Vec<usize> = (0..labels.len()).collect();
    idx.sort_by_key(|&i| (labels[i], i));
    let mut shard_ids: Vec<usize> = (0..n_shards).collect();
    rng.split(2).shuffle(&mut shard_ids);
    let shard_len = labels.len() / n_shards;
    let mut out = vec![Vec::new(); n_devices];
    for (pos, &sid) in shard_ids.iter().enumerate() {
        let dev = pos / shards_per_device;
        let start = sid * shard_len;
        let end = if sid + 1 == n_shards { labels.len() } else { start + shard_len };
        out[dev].extend_from_slice(&idx[start..end]);
    }
    Ok(out)
}

/// Fig. 5 "Cluster IID": the pool is first dealt IID across the clusters,
/// then within each cluster sorted by label and cut into `2 · |roster|`
/// shards, 2 per rostered device. Cluster-level distributions are
/// homogeneous; device-level are 2-label skewed. `rosters` gives each
/// cluster's device ids (arbitrary and non-uniform — the scenario API's
/// layout); `n_devices` sizes the returned per-device index table. Every
/// device must appear in a roster (the pool is dealt exhaustively).
pub fn cluster_iid(
    labels: &[u32],
    rosters: &[Vec<usize>],
    n_devices: usize,
    rng: &Rng,
) -> Result<Vec<Vec<usize>>> {
    let cluster_pools = iid(labels.len(), rosters.len(), &rng.split(3));
    two_level_shards(labels, &cluster_pools, rosters, n_devices, rng)
}

/// Fig. 5 "Cluster Non-IID(C)": sort the pool by label, cut into `C * m`
/// shards, give C shards to each cluster (≈ C labels per cluster), then
/// within each cluster the same 2-shard-per-device split.
pub fn cluster_noniid(
    labels: &[u32],
    rosters: &[Vec<usize>],
    n_devices: usize,
    c_labels: usize,
    rng: &Rng,
) -> Result<Vec<Vec<usize>>> {
    let m = rosters.len();
    let n_shards = c_labels * m;
    if labels.len() < n_shards {
        return Err(CfelError::Data(format!(
            "{} samples cannot fill {n_shards} cluster shards",
            labels.len()
        )));
    }
    let mut idx: Vec<usize> = (0..labels.len()).collect();
    idx.sort_by_key(|&i| (labels[i], i));
    let mut shard_ids: Vec<usize> = (0..n_shards).collect();
    rng.split(4).shuffle(&mut shard_ids);
    let shard_len = labels.len() / n_shards;
    let mut cluster_pools = vec![Vec::new(); m];
    for (pos, &sid) in shard_ids.iter().enumerate() {
        let cluster = pos / c_labels;
        let start = sid * shard_len;
        let end = if sid + 1 == n_shards { labels.len() } else { start + shard_len };
        cluster_pools[cluster].extend_from_slice(&idx[start..end]);
    }
    two_level_shards(labels, &cluster_pools, rosters, n_devices, rng)
}

/// Shared second level of the Fig. 5 schemes: within each cluster pool,
/// sort by label and deal 2 shards to each of the cluster's rostered
/// devices. Shard `pos` of cluster i goes to device `rosters[i][pos / 2]`
/// — with the historical contiguous uniform rosters this is exactly the
/// old `i * devices_per_cluster + pos / 2` layout, bit for bit.
fn two_level_shards(
    labels: &[u32],
    cluster_pools: &[Vec<usize>],
    rosters: &[Vec<usize>],
    n_devices: usize,
    rng: &Rng,
) -> Result<Vec<Vec<usize>>> {
    if rosters.len() != cluster_pools.len() {
        return Err(CfelError::Data(format!(
            "{} rosters for {} cluster pools",
            rosters.len(),
            cluster_pools.len()
        )));
    }
    let mut out = vec![Vec::new(); n_devices];
    for (ci, pool) in cluster_pools.iter().enumerate() {
        let devices = &rosters[ci];
        if devices.is_empty() {
            return Err(CfelError::Data(format!(
                "cluster {ci} rosters no devices; cluster data schemes \
                 need every cluster populated"
            )));
        }
        if let Some(&bad) = devices.iter().find(|&&d| d >= n_devices) {
            return Err(CfelError::Data(format!(
                "cluster {ci} roster names device {bad} >= n_devices {n_devices}"
            )));
        }
        let n_shards = 2 * devices.len();
        if pool.len() < n_shards {
            return Err(CfelError::Data(format!(
                "cluster {ci} pool of {} cannot fill {n_shards} shards",
                pool.len()
            )));
        }
        let mut idx = pool.clone();
        idx.sort_by_key(|&i| (labels[i], i));
        let mut shard_ids: Vec<usize> = (0..n_shards).collect();
        rng.split(5).split(ci as u64).shuffle(&mut shard_ids);
        let shard_len = idx.len() / n_shards;
        for (pos, &sid) in shard_ids.iter().enumerate() {
            let dev = devices[pos / 2];
            let start = sid * shard_len;
            let end = if sid + 1 == n_shards { idx.len() } else { start + shard_len };
            out[dev].extend_from_slice(&idx[start..end]);
        }
    }
    rebalance_empty(&mut out);
    Ok(out)
}

/// Give every empty device one sample from the largest device.
fn rebalance_empty(parts: &mut [Vec<usize>]) {
    loop {
        let Some(empty) = parts.iter().position(|p| p.is_empty()) else {
            return;
        };
        let largest = (0..parts.len())
            .max_by_key(|&i| parts[i].len())
            .expect("non-empty partition list");
        if parts[largest].len() <= 1 {
            return; // nothing to steal; give up gracefully
        }
        let sample = parts[largest].pop().unwrap();
        parts[empty].push(sample);
    }
}

/// Check disjointness + conservation; used by tests and the property suite.
pub fn validate_partition(parts: &[Vec<usize>], n_samples: usize, exhaustive: bool) -> Result<()> {
    let mut seen = vec![false; n_samples];
    let mut total = 0usize;
    for (d, p) in parts.iter().enumerate() {
        for &i in p {
            if i >= n_samples {
                return Err(CfelError::Data(format!("device {d}: index {i} out of range")));
            }
            if seen[i] {
                return Err(CfelError::Data(format!("device {d}: index {i} duplicated")));
            }
            seen[i] = true;
            total += 1;
        }
    }
    if exhaustive && total != n_samples {
        return Err(CfelError::Data(format!(
            "partition covers {total}/{n_samples} samples"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, classes: u32) -> Vec<u32> {
        (0..n).map(|i| (i as u32) % classes).collect()
    }

    /// The historical contiguous uniform layout, as roster lists.
    fn uniform_rosters(m: usize, dpc: usize) -> Vec<Vec<usize>> {
        (0..m).map(|ci| (ci * dpc..(ci + 1) * dpc).collect()).collect()
    }

    #[test]
    fn iid_balanced_and_exhaustive() {
        let parts = iid(103, 8, &Rng::new(1));
        validate_partition(&parts, 103, true).unwrap();
        let sizes: Vec<_> = parts.iter().map(|p| p.len()).collect();
        assert!(sizes.iter().all(|&s| s == 12 || s == 13), "{sizes:?}");
    }

    #[test]
    fn dirichlet_exhaustive_and_skewed() {
        let l = labels(1000, 10);
        let parts = dirichlet(&l, 10, 16, 0.5, &Rng::new(2));
        validate_partition(&parts, 1000, true).unwrap();
        assert!(parts.iter().all(|p| !p.is_empty()));
        // With alpha=0.5, label histograms should differ across devices.
        let hist = |p: &Vec<usize>| {
            let mut h = vec![0usize; 10];
            for &i in p {
                h[l[i] as usize] += 1;
            }
            h
        };
        assert_ne!(hist(&parts[0]), hist(&parts[1]));
    }

    #[test]
    fn dirichlet_small_alpha_is_more_skewed() {
        let l = labels(2000, 10);
        let frac_top = |alpha: f64| {
            let parts = dirichlet(&l, 10, 8, alpha, &Rng::new(3));
            let mut fracs = 0.0;
            for p in &parts {
                let mut h = vec![0usize; 10];
                for &i in p {
                    h[l[i] as usize] += 1;
                }
                let top = *h.iter().max().unwrap() as f64;
                fracs += top / p.len().max(1) as f64;
            }
            fracs / parts.len() as f64
        };
        assert!(frac_top(0.1) > frac_top(100.0) + 0.1);
    }

    #[test]
    fn shards_limit_labels_per_device() {
        let l = labels(1000, 10);
        let parts = shards(&l, 50, 2, &Rng::new(4)).unwrap();
        validate_partition(&parts, 1000, true).unwrap();
        for p in &parts {
            let mut distinct: Vec<u32> = p.iter().map(|&i| l[i]).collect();
            distinct.sort_unstable();
            distinct.dedup();
            assert!(distinct.len() <= 3, "{distinct:?}"); // 2 shards ⇒ ≤3 labels (shard straddle)
        }
    }

    #[test]
    fn shards_rejects_too_few_samples() {
        assert!(shards(&labels(10, 2), 50, 2, &Rng::new(0)).is_err());
    }

    #[test]
    fn cluster_iid_homogeneous_clusters_skewed_devices() {
        let l = labels(1600, 10);
        let m = 4;
        let dpc = 4;
        let parts = cluster_iid(&l, &uniform_rosters(m, dpc), m * dpc, &Rng::new(5)).unwrap();
        validate_partition(&parts, 1600, true).unwrap();
        assert_eq!(parts.len(), 16);
        // Cluster-level histograms near-uniform; device-level skewed.
        for ci in 0..m {
            let mut h = vec![0usize; 10];
            for d in 0..dpc {
                for &i in &parts[ci * dpc + d] {
                    h[l[i] as usize] += 1;
                }
            }
            let total: usize = h.iter().sum();
            for &c in &h {
                let frac = c as f64 / total as f64;
                assert!((frac - 0.1).abs() < 0.05, "cluster {ci}: {h:?}");
            }
        }
        // Each device sees few labels (2 shards; a shard can straddle
        // label boundaries when shard_len is not label-aligned, so the
        // bound is loose — but must stay far below all 10 classes).
        for p in &parts {
            let mut distinct: Vec<u32> = p.iter().map(|&i| l[i]).collect();
            distinct.sort_unstable();
            distinct.dedup();
            assert!(distinct.len() <= 6, "{distinct:?}");
        }
    }

    #[test]
    fn cluster_noniid_limits_cluster_labels() {
        let l = labels(1600, 10);
        let m = 4;
        let dpc = 4;
        for c in [2usize, 5] {
            let parts =
                cluster_noniid(&l, &uniform_rosters(m, dpc), m * dpc, c, &Rng::new(6)).unwrap();
            validate_partition(&parts, 1600, true).unwrap();
            for ci in 0..m {
                let mut distinct: Vec<u32> = Vec::new();
                for d in 0..dpc {
                    distinct.extend(parts[ci * dpc + d].iter().map(|&i| l[i]));
                }
                distinct.sort_unstable();
                distinct.dedup();
                assert!(
                    distinct.len() <= c + 2,
                    "C={c} cluster {ci} saw {} labels",
                    distinct.len()
                );
            }
        }
    }

    #[test]
    fn cluster_noniid_c_increases_cluster_divergence() {
        // Larger C ⇒ clusters see more labels ⇒ cluster histograms closer
        // to uniform ⇒ *smaller* inter-cluster divergence... wait, the
        // paper's C counts labels per cluster: C=8 with 10 classes is close
        // to cluster-IID, C=2 is extreme. Verify the monotonicity used in
        // Fig. 5's interpretation.
        let l = labels(4000, 10);
        let m = 8;
        let dpc = 2;
        let spread = |c: usize| {
            let parts =
                cluster_noniid(&l, &uniform_rosters(m, dpc), m * dpc, c, &Rng::new(7)).unwrap();
            // Mean per-cluster max-label fraction (1.0 = single label).
            let mut acc = 0.0;
            for ci in 0..m {
                let mut h = vec![0usize; 10];
                for d in 0..dpc {
                    for &i in &parts[ci * dpc + d] {
                        h[l[i] as usize] += 1;
                    }
                }
                let total: usize = h.iter().sum();
                acc += *h.iter().max().unwrap() as f64 / total.max(1) as f64;
            }
            acc / m as f64
        };
        assert!(spread(2) > spread(8) + 0.1, "{} vs {}", spread(2), spread(8));
    }

    #[test]
    fn uneven_rosters_partition_by_roster_ids() {
        // Non-uniform, non-contiguous rosters (the scenario layout): the
        // pool must land exactly on the rostered device ids, exhaustively.
        let l = labels(1200, 10);
        let rosters: Vec<Vec<usize>> = vec![vec![0, 2, 4, 6, 8], vec![1, 3, 5], vec![7, 9]];
        let parts = cluster_iid(&l, &rosters, 10, &Rng::new(8)).unwrap();
        validate_partition(&parts, 1200, true).unwrap();
        assert!(parts.iter().all(|p| !p.is_empty()));
        let parts = cluster_noniid(&l, &rosters, 10, 3, &Rng::new(8)).unwrap();
        validate_partition(&parts, 1200, true).unwrap();
        // An empty roster cannot receive its cluster pool.
        let holey: Vec<Vec<usize>> = vec![vec![0, 1], vec![]];
        assert!(cluster_iid(&l, &holey, 2, &Rng::new(8)).is_err());
        // Out-of-range roster ids are rejected.
        let oob: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 99]];
        assert!(cluster_iid(&l, &oob, 4, &Rng::new(8)).is_err());
    }

    #[test]
    fn validate_catches_duplicates_and_range() {
        assert!(validate_partition(&[vec![0, 1], vec![1]], 3, false).is_err());
        assert!(validate_partition(&[vec![5]], 3, false).is_err());
        assert!(validate_partition(&[vec![0], vec![1]], 3, true).is_err());
        validate_partition(&[vec![0, 2], vec![1]], 3, true).unwrap();
    }

    #[test]
    fn rebalance_fills_empty_devices() {
        let mut parts = vec![vec![0, 1, 2, 3], vec![]];
        rebalance_empty(&mut parts);
        assert!(!parts[1].is_empty());
        validate_partition(&parts, 4, true).unwrap();
    }
}
