//! Integration tests: full multi-round runs of all four algorithms on the
//! mock backend — learning, paper orderings, determinism, fault behaviour.

use cfel::config::{AlgorithmKind, DataScheme, ExperimentConfig, FaultSpec};
use cfel::coordinator::Coordinator;
use cfel::metrics::{best_accuracy, time_to_accuracy, History};

fn run(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

fn paper_cfg(alg: AlgorithmKind, rounds: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::paper_system(alg);
    c.rounds = rounds;
    c
}

#[test]
fn all_algorithms_learn_on_the_paper_system() {
    for alg in AlgorithmKind::all() {
        let h = run(&paper_cfg(alg, 10));
        assert_eq!(h.len(), 10);
        let best = best_accuracy(&h);
        assert!(best > 0.3, "{alg:?} best accuracy {best}");
        // train loss must drop substantially
        assert!(
            h.last().unwrap().train_loss < h[0].train_loss * 0.8,
            "{alg:?}: {} -> {}",
            h[0].train_loss,
            h.last().unwrap().train_loss
        );
    }
}

#[test]
fn fig2_orderings_hold() {
    // The paper's headline qualitative results on a single seed batch:
    //  (a) per-round: Hier-FAvg >= CE-FedAvg accuracy early on is not
    //      guaranteed at every round, so compare rounds-to-target;
    //  (b) per-sim-second: CE-FedAvg beats FedAvg and Hier-FAvg;
    //  (c) Local-Edge ends lowest under cluster-skewed data.
    let rounds = 25;
    let mut hs = Vec::new();
    for alg in AlgorithmKind::all() {
        let mut c = paper_cfg(alg, rounds);
        c.data = DataScheme::ClusterNonIid { c_labels: 3 };
        hs.push((alg, run(&c)));
    }
    let get = |alg: AlgorithmKind| &hs.iter().find(|(a, _)| *a == alg).unwrap().1;
    let ce = get(AlgorithmKind::CeFedAvg);
    let fa = get(AlgorithmKind::FedAvg);
    let hier = get(AlgorithmKind::HierFAvg);
    let le = get(AlgorithmKind::LocalEdge);

    // Local-Edge caps out below the cooperative algorithms.
    let b_le = best_accuracy(le);
    for (name, h) in [("ce", ce), ("hier", hier)] {
        assert!(
            best_accuracy(h) > b_le,
            "{name} {} !> local-edge {b_le}",
            best_accuracy(h)
        );
    }

    // Runtime axis: CE reaches the shared target in less simulated time.
    let target = [ce, fa, hier]
        .iter()
        .map(|h| best_accuracy(h))
        .fold(f64::INFINITY, f64::min)
        * 0.9;
    let t_ce = time_to_accuracy(ce, target).expect("ce hits target").1;
    let t_fa = time_to_accuracy(fa, target).expect("fedavg hits target").1;
    let t_hier = time_to_accuracy(hier, target).expect("hier hits target").1;
    assert!(t_ce < t_fa, "ce {t_ce} !< fedavg {t_fa}");
    assert!(t_ce < t_hier, "ce {t_ce} !< hier {t_hier}");
}

#[test]
fn whole_run_is_deterministic_for_seed_and_thread_count() {
    let cfg = paper_cfg(AlgorithmKind::CeFedAvg, 5);
    let a = run(&cfg);
    std::env::set_var("CFEL_THREADS", "1");
    let b = run(&cfg);
    std::env::remove_var("CFEL_THREADS");
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.train_loss, y.train_loss);
        assert_eq!(x.test_accuracy, y.test_accuracy);
        assert_eq!(x.consensus, y.consensus);
    }
}

#[test]
fn seeds_actually_change_the_run() {
    let mut c1 = paper_cfg(AlgorithmKind::CeFedAvg, 3);
    let mut c2 = c1.clone();
    c1.seed = 1;
    c2.seed = 2;
    let (a, b) = (run(&c1), run(&c2));
    assert_ne!(a[0].train_loss, b[0].train_loss);
}

#[test]
fn ce_fedavg_survives_edge_server_failure() {
    let mut c = paper_cfg(AlgorithmKind::CeFedAvg, 14);
    c.data = DataScheme::ClusterNonIid { c_labels: 3 };
    c.fault = Some(FaultSpec::KillCluster { at_round: 5, cluster: 3 });
    let h = run(&c);
    let pre = h[..5]
        .iter()
        .map(|r| r.test_accuracy)
        .fold(0.0f64, f64::max);
    let post = h[5..]
        .iter()
        .map(|r| r.test_accuracy)
        .fold(0.0f64, f64::max);
    assert!(post > pre, "no improvement after fault: {pre} -> {post}");
}

#[test]
fn aggregator_failure_stalls_centralised_algorithms() {
    for alg in [AlgorithmKind::FedAvg, AlgorithmKind::HierFAvg] {
        let mut with_fault = paper_cfg(alg, 14);
        with_fault.data = DataScheme::ClusterNonIid { c_labels: 3 };
        with_fault.fault = Some(FaultSpec::KillAggregator { at_round: 5 });
        let h_f = run(&with_fault);
        let mut clean = with_fault.clone();
        clean.fault = None;
        let h_c = run(&clean);
        // The faulted run must end with model divergence; the clean run
        // stays in consensus.
        assert!(h_f.last().unwrap().consensus > h_c.last().unwrap().consensus);
        // And it loses accuracy relative to the clean run.
        assert!(
            best_accuracy(&h_c) >= best_accuracy(&h_f) - 1e-9,
            "{alg:?}: clean {} < faulted {}",
            best_accuracy(&h_c),
            best_accuracy(&h_f)
        );
    }
}

#[test]
fn heterogeneous_devices_slow_the_simulated_clock_only() {
    let c_hom = paper_cfg(AlgorithmKind::CeFedAvg, 3);
    let mut c_het = c_hom.clone();
    c_het.heterogeneity = Some(0.5);
    let (h_hom, h_het) = (run(&c_hom), run(&c_het));
    // Same learning (the data and updates are unchanged)...
    assert_eq!(h_hom[2].train_loss, h_het[2].train_loss);
    // ...but a slower straggler-bound simulated clock.
    assert!(h_het[2].sim_time_s > h_hom[2].sim_time_s);
}

#[test]
fn eval_every_skips_evaluations() {
    let mut c = paper_cfg(AlgorithmKind::CeFedAvg, 6);
    c.eval_every = 3;
    let h = run(&c);
    assert!(h[0].test_accuracy.is_nan());
    assert!(h[1].test_accuracy.is_nan());
    assert!(!h[2].test_accuracy.is_nan());
    assert!(!h[5].test_accuracy.is_nan());
}

#[test]
fn pool_iid_converges_faster_than_extreme_skew() {
    let mut iid = paper_cfg(AlgorithmKind::CeFedAvg, 20);
    iid.data = DataScheme::PoolIid;
    let mut skew = iid.clone();
    skew.data = DataScheme::ClusterNonIid { c_labels: 2 };
    let (h_iid, h_skew) = (run(&iid), run(&skew));
    assert!(
        best_accuracy(&h_iid) > best_accuracy(&h_skew),
        "iid {} !> skew {}",
        best_accuracy(&h_iid),
        best_accuracy(&h_skew)
    );
}

#[test]
fn dirichlet_alpha_controls_difficulty() {
    let mut mild = paper_cfg(AlgorithmKind::LocalEdge, 15);
    mild.data = DataScheme::PoolDirichlet { alpha: 100.0 };
    let mut harsh = mild.clone();
    harsh.data = DataScheme::PoolDirichlet { alpha: 0.1 };
    let (h_mild, h_harsh) = (run(&mild), run(&harsh));
    assert!(
        best_accuracy(&h_mild) > best_accuracy(&h_harsh),
        "alpha=100 {} !> alpha=0.1 {}",
        best_accuracy(&h_mild),
        best_accuracy(&h_harsh)
    );
}
