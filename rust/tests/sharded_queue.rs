//! Property suite for the sharded calendar-queue engine.
//!
//! Three invariants, all under adversarial cluster splits (`n = m·q + r`
//! with `r ∈ [1, m-1]`, so `ExperimentConfig::cluster_sizes` is forced to
//! remainder-spread — clusters of unequal size) and a Markov churn
//! timeline perturbing the rosters between rounds:
//!
//! 1. **Pop-order equivalence.** Scheduling the same events into one
//!    global [`EventQueue`] (binary heap, the reference) and into a
//!    [`ShardedEventQueue`] (one calendar queue per cluster, merged at
//!    pop time) yields the identical pop sequence — including events
//!    scheduled *during* the drain (`UploadDone` chained off each
//!    `ComputeDone`), coarse-grid timestamps that force `(time, kind,
//!    id)` tie-breaks, and past-horizon times landing in the overflow
//!    bucket.
//! 2. **Batched-phase equivalence.** `simulate_phases` (one calendar
//!    shard per cluster) is bit-identical, field by field, to running
//!    `simulate_phase` per cluster — for a heterogeneous fleet under
//!    both the full-barrier and semi-sync close policies.
//! 3. **Parallel-drain equivalence.** `simulate_phases_threads` — each
//!    cluster's shard drained on its own pool worker, results merged in
//!    cluster order — is bit-identical to the sequential per-cluster
//!    drain for `CFEL_THREADS` ∈ {1, 2, 4}, across rounds of Markov
//!    churn over uneven rosters.
//!
//! See docs/DETERMINISM.md for the contract these pin.

use cfel::aggregation::policy::{AggregationPolicy, FullBarrier, SemiSync};
use cfel::config::ExperimentConfig;
use cfel::netsim::{
    Event, EventDrivenEstimator, EventKind, EventQueue, NetworkModel, PhaseTiming,
    ShardedEventQueue, UploadChannel,
};
use cfel::prop_assert;
use cfel::scenario::{ChurnSpec, Scenario, Timeline, WorldEvent};
use cfel::util::proptest::{check, default_cases, int_biased};
use cfel::util::rng::Rng;

/// Timestamps on a 1/8-second grid so distinct devices collide on time
/// and the `(time, kind, id)` tie-break actually decides orderings.
fn coarse(rng: &mut Rng, hi: f64) -> f64 {
    (rng.f64() * hi * 8.0).floor() / 8.0
}

/// Adversarial system shape: m clusters, n = m·q + r devices with a
/// guaranteed remainder, so cluster sizes split unevenly.
fn uneven_split(rng: &mut Rng, max_m: usize, max_q: usize) -> (usize, usize) {
    let m = int_biased(rng, 2, max_m);
    let q = int_biased(rng, 1, max_q);
    let r = int_biased(rng, 1, m - 1);
    (n_of(m, q, r), m)
}

fn n_of(m: usize, q: usize, r: usize) -> usize {
    m * q + r
}

#[test]
fn churned_roster_pop_order_matches_single_heap() {
    check("sharded pop order == single heap", 0xC0DE, default_cases(), |rng| {
        let (n, m) = uneven_split(rng, 7, 5);
        let mut cfg = ExperimentConfig::quickstart();
        cfg.n_devices = n;
        cfg.n_clusters = m;
        let sizes = cfg.cluster_sizes();
        prop_assert!(sizes.iter().any(|&s| s != sizes[0]), "split must be uneven: {sizes:?}");
        let rosters = Scenario::contiguous_rosters(&sizes);
        let spec = ChurnSpec {
            p_leave: 0.3,
            p_join: 0.3,
            rounds: 4,
            seed: rng.below(1 << 20) as u64,
        };
        let timeline = Timeline::markov_churn(&rosters, &spec).unwrap();

        let mut active = vec![true; n];
        let mut cluster_of = vec![0usize; n];
        for (ci, roster) in rosters.iter().enumerate() {
            for &d in roster {
                cluster_of[d] = ci;
            }
        }

        let horizon = 100.0;
        for round in 0..spec.rounds {
            for te in timeline.at(round) {
                match te.event {
                    WorldEvent::Join { device, cluster } => {
                        active[device] = true;
                        cluster_of[device] = cluster;
                    }
                    WorldEvent::Leave { device } => active[device] = false,
                    _ => {}
                }
            }

            let mut heap = EventQueue::new();
            let shard_spec: Vec<(f64, usize)> =
                sizes.iter().map(|&s| (horizon, s * 2 + 1)).collect();
            let mut sharded = ShardedEventQueue::with_horizons(&shard_spec);
            for d in 0..n {
                if !active[d] {
                    continue;
                }
                let ev = Event {
                    time_s: coarse(rng, horizon),
                    kind: EventKind::ComputeDone,
                    id: round * n + d,
                };
                heap.schedule(ev);
                sharded.schedule(cluster_of[d], ev);
            }

            loop {
                match (heap.pop(), sharded.pop_merged()) {
                    (None, None) => break,
                    (Some(ea), Some((shard, eb))) => {
                        prop_assert!(ea == eb, "round {round}: pop mismatch {ea:?} vs {eb:?}");
                        prop_assert!(
                            shard == cluster_of[ea.id % n],
                            "round {round}: event {} popped from shard {shard}, home {}",
                            ea.id,
                            cluster_of[ea.id % n]
                        );
                        if ea.kind == EventKind::ComputeDone {
                            // Chain an upload, sometimes past the horizon
                            // (overflow-bucket path), sometimes at dt=0
                            // (same-time kind tie-break).
                            let dt = (ea.id % 17) as f64 * horizon / 64.0;
                            let up = Event {
                                time_s: ea.time_s + dt,
                                kind: EventKind::UploadDone,
                                id: ea.id,
                            };
                            heap.schedule(up);
                            sharded.schedule(shard, up);
                        }
                    }
                    (a, b) => {
                        prop_assert!(false, "round {round}: queue lengths diverged ({a:?} vs {b:?})");
                    }
                }
            }
            prop_assert!(
                heap.processed() == sharded.processed(),
                "round {round}: processed counts diverged"
            );
        }
        Ok(())
    });
}

fn f64_bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Bitwise PhaseTiming equality, field by field.
fn same_phase(a: &PhaseTiming, b: &PhaseTiming) -> bool {
    a.duration_s.to_bits() == b.duration_s.to_bits()
        && a.compute_s.to_bits() == b.compute_s.to_bits()
        && a.upload_s.to_bits() == b.upload_s.to_bits()
        && a.events == b.events
        && a.close_reason == b.close_reason
        && a.devices.device == b.devices.device
        && f64_bits(&a.devices.compute_s) == f64_bits(&b.devices.compute_s)
        && f64_bits(&a.devices.upload_s) == f64_bits(&b.devices.upload_s)
        && f64_bits(&a.devices.finish_s) == f64_bits(&b.devices.finish_s)
        && a.devices.verdict == b.devices.verdict
}

#[test]
fn batched_phases_match_per_cluster_bitwise() {
    check("simulate_phases == per-cluster simulate_phase", 0xFA57, default_cases(), |rng| {
        let (n, m) = uneven_split(rng, 6, 4);
        let mut net = NetworkModel::paper_defaults(n, 13.30e6, 50, 10_000);
        net.apply_heterogeneity(0.25, &Rng::new(rng.below(1 << 20) as u64));
        let mut cfg = ExperimentConfig::quickstart();
        cfg.n_devices = n;
        cfg.n_clusters = m;
        let rosters = Scenario::contiguous_rosters(&cfg.cluster_sizes());
        let work: Vec<Vec<(usize, usize)>> = rosters
            .iter()
            .map(|ro| ro.iter().map(|&d| (d, 1 + d % 5)).collect())
            .collect();
        let k = int_biased(rng, 1, n / m + 2);
        let policies: Vec<Box<dyn AggregationPolicy>> = vec![
            Box::new(FullBarrier),
            Box::new(SemiSync { k, timeout_s: 30.0, staleness_exp: 1.0 }),
        ];
        for policy in &policies {
            let batched = EventDrivenEstimator::simulate_phases(
                &net,
                &work,
                UploadChannel::DeviceEdge,
                policy.as_ref(),
            );
            prop_assert!(batched.len() == m, "one timing per cluster");
            for (ci, w) in work.iter().enumerate() {
                let solo = EventDrivenEstimator::simulate_phase(
                    &net,
                    w,
                    UploadChannel::DeviceEdge,
                    policy.as_ref(),
                );
                prop_assert!(
                    same_phase(&solo, &batched[ci]),
                    "cluster {ci} diverged under {}: solo {solo:?} vs batched {:?}",
                    batched[ci].close_reason.name(),
                    batched[ci]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_drain_matches_sequential_bitwise_under_churn() {
    check(
        "simulate_phases_threads(t) == sequential drain, t in {1,2,4}",
        0x7EAD,
        default_cases(),
        |rng| {
            let (n, m) = uneven_split(rng, 6, 4);
            let mut net = NetworkModel::paper_defaults(n, 13.30e6, 50, 10_000);
            net.apply_heterogeneity(0.25, &Rng::new(rng.below(1 << 20) as u64));
            let mut cfg = ExperimentConfig::quickstart();
            cfg.n_devices = n;
            cfg.n_clusters = m;
            let rosters = Scenario::contiguous_rosters(&cfg.cluster_sizes());
            let spec = ChurnSpec {
                p_leave: 0.3,
                p_join: 0.3,
                rounds: 3,
                seed: rng.below(1 << 20) as u64,
            };
            let timeline = Timeline::markov_churn(&rosters, &spec).unwrap();
            let mut active = vec![true; n];
            let mut cluster_of = vec![0usize; n];
            for (ci, roster) in rosters.iter().enumerate() {
                for &d in roster {
                    cluster_of[d] = ci;
                }
            }
            let k = int_biased(rng, 1, n / m + 2);
            let policies: Vec<Box<dyn AggregationPolicy>> = vec![
                Box::new(FullBarrier),
                Box::new(SemiSync { k, timeout_s: 30.0, staleness_exp: 1.0 }),
            ];
            for round in 0..spec.rounds {
                for te in timeline.at(round) {
                    match te.event {
                        WorldEvent::Join { device, cluster } => {
                            active[device] = true;
                            cluster_of[device] = cluster;
                        }
                        WorldEvent::Leave { device } => active[device] = false,
                        _ => {}
                    }
                }
                // Work lists in ascending device order per cluster (the
                // coordinator's sorted-participant convention); churn may
                // leave some clusters empty.
                let mut work: Vec<Vec<(usize, usize)>> = vec![Vec::new(); m];
                for d in 0..n {
                    if active[d] {
                        work[cluster_of[d]].push((d, 1 + d % 5));
                    }
                }
                for policy in &policies {
                    let sequential: Vec<PhaseTiming> = work
                        .iter()
                        .map(|w| {
                            EventDrivenEstimator::simulate_phase(
                                &net,
                                w,
                                UploadChannel::DeviceEdge,
                                policy.as_ref(),
                            )
                        })
                        .collect();
                    for threads in [1usize, 2, 4] {
                        let parallel = EventDrivenEstimator::simulate_phases_threads(
                            &net,
                            &work,
                            UploadChannel::DeviceEdge,
                            policy.as_ref(),
                            threads,
                        );
                        prop_assert!(parallel.len() == m, "one timing per cluster");
                        for (ci, (p, s)) in parallel.iter().zip(&sequential).enumerate() {
                            prop_assert!(
                                same_phase(p, s),
                                "round {round} cluster {ci} threads {threads}: parallel \
                                 drain diverged ({:?} vs {:?})",
                                p,
                                s
                            );
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
