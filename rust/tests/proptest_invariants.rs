//! Property-based tests over the coordinator's algebraic invariants
//! (util::proptest harness; seeds reported on failure for reproduction).

use cfel::aggregation::policy::{AggregationPolicy, FullBarrier, SemiSync};
use cfel::aggregation::{
    consensus_distance, global_average, gossip_mix, l2_distance, report_weights,
    weighted_average,
};
use cfel::data::partition;
use cfel::netsim::{EventDrivenEstimator, NetworkModel, StragglerSpec, UploadChannel};
use cfel::plan::{Plan, Step};
use cfel::prop_assert;
use cfel::topology::{Graph, MixingMatrix};
use cfel::util::proptest::{check, close, default_cases, int_biased, simplex, vec_f32};
use cfel::util::rng::Rng;

/// Random connected graph: ER(p) with p biased upward, falling back to a
/// ring when sampling fails.
fn random_graph(rng: &mut Rng) -> Graph {
    let m = int_biased(rng, 2, 12);
    let p = 0.2 + 0.7 * rng.f64();
    Graph::erdos_renyi(m, p, &rng.split(99)).unwrap_or_else(|_| Graph::ring(m).unwrap())
}

#[test]
fn prop_metropolis_doubly_stochastic_on_random_graphs() {
    check("metropolis-ds", 11, default_cases(), |rng| {
        let g = random_graph(rng);
        let h = MixingMatrix::metropolis(&g);
        h.validate().map_err(|e| e.to_string())?;
        // Any power must remain doubly stochastic.
        let pi = int_biased(rng, 1, 12) as u32;
        h.power(pi).validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_every_builder_connected_with_tightly_doubly_stochastic_metropolis() {
    // Every Graph builder — ring, complete, star, line, Erdős–Rényi —
    // must return a connected graph, and Metropolis–Hastings weights on
    // any of them must be symmetric and doubly stochastic with rows and
    // columns summing to 1 within 1e-12 (Assumption 4, at a tolerance
    // three decades tighter than MixingMatrix::validate's 1e-9).
    check("builders-connected-ds-1e12", 25, default_cases(), |rng| {
        let m = int_biased(rng, 2, 12);
        let p = 0.2 + 0.7 * rng.f64();
        let graphs = [
            Graph::ring(m).map_err(|e| e.to_string())?,
            Graph::complete(m).map_err(|e| e.to_string())?,
            Graph::star(m).map_err(|e| e.to_string())?,
            Graph::line(m).map_err(|e| e.to_string())?,
            Graph::erdos_renyi(m, p, &rng.split(41)).map_err(|e| e.to_string())?,
        ];
        for g in &graphs {
            prop_assert!(
                g.is_connected(),
                "builder {:?} returned a disconnected graph (m={m})",
                g.name()
            );
            let h = MixingMatrix::metropolis(g);
            for i in 0..m {
                let mut row = 0.0f64;
                let mut col = 0.0f64;
                for j in 0..m {
                    let v = h.get(i, j);
                    prop_assert!(
                        v >= -1e-15,
                        "{}: negative weight H[{i}][{j}] = {v}",
                        g.name()
                    );
                    prop_assert!(
                        (v - h.get(j, i)).abs() <= 1e-12,
                        "{}: asymmetric H at ({i},{j}): {v} vs {}",
                        g.name(),
                        h.get(j, i)
                    );
                    row += v;
                    col += h.get(j, i);
                }
                prop_assert!(
                    (row - 1.0).abs() <= 1e-12,
                    "{}: row {i} sums to {row}",
                    g.name()
                );
                prop_assert!(
                    (col - 1.0).abs() <= 1e-12,
                    "{}: column {i} sums to {col}",
                    g.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_zeta_bounds_and_monotone_contraction() {
    check("zeta-bounds", 12, default_cases(), |rng| {
        let g = random_graph(rng);
        let h = MixingMatrix::metropolis(&g);
        let z = h.zeta();
        prop_assert!((0.0..1.0 + 1e-9).contains(&z), "zeta {z} out of [0,1)");
        if g.is_connected() {
            prop_assert!(z < 1.0 - 1e-9, "connected graph with zeta {z}");
        }
        Ok(())
    });
}

#[test]
fn prop_gossip_preserves_equal_size_average() {
    // Eq. 12: the doubly-stochastic mix leaves the mean model invariant.
    check("gossip-mean", 13, default_cases(), |rng| {
        let g = random_graph(rng);
        let m = g.len();
        let d = int_biased(rng, 1, 300);
        let pi = int_biased(rng, 1, 6) as u32;
        let h = MixingMatrix::metropolis(&g).power(pi);
        let mut models: Vec<Vec<f32>> = (0..m).map(|_| vec_f32(rng, d)).collect();
        let before = global_average(&models, &vec![1; m]).unwrap();
        let mut scratch = Vec::new();
        gossip_mix(&mut models, &h, &mut scratch);
        let after = global_average(&models, &vec![1; m]).unwrap();
        let dist = l2_distance(&before, &after);
        let scale = before.iter().map(|v| v.abs() as f64).sum::<f64>() / d as f64;
        prop_assert!(
            dist < 1e-3 * (1.0 + scale) * (d as f64).sqrt(),
            "average moved by {dist} (scale {scale})"
        );
        Ok(())
    });
}

#[test]
fn prop_gossip_never_increases_consensus_distance() {
    check("gossip-contracts", 14, default_cases(), |rng| {
        let g = random_graph(rng);
        let m = g.len();
        let d = int_biased(rng, 1, 200);
        let h = MixingMatrix::metropolis(&g);
        let mut models: Vec<Vec<f32>> = (0..m).map(|_| vec_f32(rng, d)).collect();
        let mut scratch = Vec::new();
        let mut prev = consensus_distance(&models);
        for _ in 0..4 {
            gossip_mix(&mut models, &h, &mut scratch);
            let cur = consensus_distance(&models);
            prop_assert!(
                cur <= prev * (1.0 + 1e-5) + 1e-7,
                "consensus grew: {prev} -> {cur}"
            );
            prev = cur;
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_average_is_convex_combination() {
    check("wavg-convex", 15, default_cases(), |rng| {
        let n = int_biased(rng, 1, 10);
        let d = int_biased(rng, 1, 100);
        let rows_data: Vec<Vec<f32>> = (0..n).map(|_| vec_f32(rng, d)).collect();
        let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
        let w = simplex(rng, n);
        let avg = weighted_average(&rows, &w).unwrap();
        for j in 0..d {
            let lo = rows_data.iter().map(|r| r[j]).fold(f32::INFINITY, f32::min);
            let hi = rows_data
                .iter()
                .map(|r| r[j])
                .fold(f32::NEG_INFINITY, f32::max);
            let tol = 1e-4 * (1.0 + hi.abs().max(lo.abs()));
            prop_assert!(
                avg[j] >= lo - tol && avg[j] <= hi + tol,
                "coord {j}: {} outside [{lo}, {hi}]",
                avg[j]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_partitions_disjoint_and_exhaustive() {
    check("partition-invariants", 16, default_cases(), |rng| {
        let classes = int_biased(rng, 2, 12);
        let n_dev = int_biased(rng, 1, 24);
        let n = (classes * n_dev * int_biased(rng, 2, 12)).max(n_dev);
        let labels: Vec<u32> = (0..n).map(|i| (i % classes) as u32).collect();
        let prng = rng.split(5);

        let parts = partition::iid(n, n_dev, &prng);
        partition::validate_partition(&parts, n, true).map_err(|e| e.to_string())?;

        let alpha = 0.1 + rng.f64() * 5.0;
        let parts = partition::dirichlet(&labels, classes, n_dev, alpha, &prng);
        partition::validate_partition(&parts, n, true).map_err(|e| e.to_string())?;
        prop_assert!(
            parts.iter().all(|p| !p.is_empty()) || n < n_dev,
            "dirichlet left a device empty with n={n}"
        );
        Ok(())
    });
}

#[test]
fn prop_two_level_partitions_cover_everything() {
    check("two-level-partitions", 17, default_cases(), |rng| {
        let m = int_biased(rng, 2, 6);
        let dpc = int_biased(rng, 2, 6);
        let classes = int_biased(rng, 2, 10);
        let per_dev = int_biased(rng, 8, 40);
        let n = m * dpc * per_dev;
        let labels: Vec<u32> = (0..n).map(|i| (i % classes) as u32).collect();
        let prng = rng.split(6);
        let rosters: Vec<Vec<usize>> =
            (0..m).map(|ci| (ci * dpc..(ci + 1) * dpc).collect()).collect();
        let parts = partition::cluster_iid(&labels, &rosters, m * dpc, &prng)
            .map_err(|e| e.to_string())?;
        partition::validate_partition(&parts, n, true).map_err(|e| e.to_string())?;
        let c = int_biased(rng, 1, classes);
        let parts = partition::cluster_noniid(&labels, &rosters, m * dpc, c, &prng)
            .map_err(|e| e.to_string())?;
        partition::validate_partition(&parts, n, true).map_err(|e| e.to_string())?;
        // Uneven rosters (the scenario layout): move the last device of
        // cluster 0 into cluster 1 and re-partition — still disjoint and
        // exhaustive over the same device universe.
        let mut uneven = rosters.clone();
        let moved = uneven[0].pop().expect("dpc >= 2");
        uneven[1].push(moved);
        uneven[1].sort_unstable();
        let parts = partition::cluster_iid(&labels, &uneven, m * dpc, &prng)
            .map_err(|e| e.to_string())?;
        partition::validate_partition(&parts, n, true).map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_mixing_power_converges_to_uniform() {
    check("power-converges", 18, default_cases() / 2, |rng| {
        let g = random_graph(rng);
        let m = g.len();
        let h = MixingMatrix::metropolis(&g);
        if !g.is_connected() {
            return Ok(());
        }
        let hp = h.power(400);
        for i in 0..m {
            for j in 0..m {
                prop_assert!(
                    close(hp.get(i, j), 1.0 / m as f64, 1e-3),
                    "H^400[{i}][{j}] = {} != 1/{m}",
                    hp.get(i, j)
                );
            }
        }
        Ok(())
    });
}

/// Random fleet: paper defaults with random heterogeneity and (half the
/// time) a random heavy-tail straggler population.
fn random_fleet(rng: &mut Rng, n: usize) -> NetworkModel {
    let mut net = NetworkModel::paper_defaults(n, 1e6, 50, 100_000);
    net = net.with_heterogeneity(0.2 + 0.8 * rng.f64(), &rng.split(31));
    if rng.below(2) == 0 {
        let spec = StragglerSpec {
            fraction: (0.05 + 0.95 * rng.f64()).min(1.0),
            slowdown: 1.0 + rng.f64() * 1e4,
        };
        net = net.with_stragglers(spec, &rng.split(32));
    }
    net
}

#[test]
fn prop_semi_sync_close_monotone_in_k_and_bounded_by_barrier() {
    check("semisync-close-bounds", 21, default_cases(), |rng| {
        let n = int_biased(rng, 1, 12);
        let net = random_fleet(rng, n);
        let work: Vec<(usize, usize)> = (0..n).map(|d| (d, int_biased(rng, 1, 32))).collect();
        let barrier = EventDrivenEstimator::simulate_phase(
            &net,
            &work,
            UploadChannel::DeviceEdge,
            &FullBarrier,
        );
        // Close time is monotone non-decreasing in K and never exceeds
        // the full barrier; K = N closes exactly at the barrier.
        let mut prev = 0.0f64;
        for k in 1..=n {
            let pt = EventDrivenEstimator::simulate_phase(
                &net,
                &work,
                UploadChannel::DeviceEdge,
                &SemiSync { k, timeout_s: f64::INFINITY, staleness_exp: 1.0 },
            );
            prop_assert!(
                pt.duration_s >= prev,
                "close time shrank: K={k} gives {} after {prev}",
                pt.duration_s
            );
            prop_assert!(
                pt.duration_s <= barrier.duration_s,
                "K={k} close {} exceeds barrier {}",
                pt.duration_s,
                barrier.duration_s
            );
            prev = pt.duration_s;
        }
        prop_assert!(
            prev.to_bits() == barrier.duration_s.to_bits(),
            "K=N close {prev} != barrier {}",
            barrier.duration_s
        );
        // A finite timeout can only close earlier still.
        let k = int_biased(rng, 1, n);
        let timeout = (0.01 + rng.f64()) * barrier.duration_s.max(1e-9);
        let pt = EventDrivenEstimator::simulate_phase(
            &net,
            &work,
            UploadChannel::DeviceEdge,
            &SemiSync { k, timeout_s: timeout, staleness_exp: 1.0 },
        );
        prop_assert!(
            pt.duration_s <= barrier.duration_s + 1e-15,
            "timeout close {} exceeds barrier {}",
            pt.duration_s,
            barrier.duration_s
        );
        Ok(())
    });
}

#[test]
fn prop_staleness_weights_always_sum_to_one() {
    check("staleness-weights", 22, default_cases(), |rng| {
        let n = int_biased(rng, 1, 16);
        let ns: Vec<usize> = (0..n).map(|_| int_biased(rng, 1, 5000)).collect();
        let pol = SemiSync { k: 1, timeout_s: 1.0, staleness_exp: rng.f64() * 4.0 };
        let ds: Vec<f64> = (0..n).map(|_| pol.staleness_discount(rng.below(25) as u64)).collect();
        let w = report_weights(&ns, &ds).map_err(|e| e.to_string())?;
        let sum: f64 = w.iter().sum();
        prop_assert!((sum - 1.0).abs() < 1e-9, "weights sum to {sum}");
        prop_assert!(
            w.iter().all(|&x| (0.0..=1.0 + 1e-12).contains(&x)),
            "weight outside [0,1]: {w:?}"
        );
        Ok(())
    });
}

/// Random valid plan: a bounded-depth step tree over all four step
/// kinds, with a guaranteed executing edge phase so `validate` passes.
fn random_plan(rng: &mut Rng) -> Plan {
    fn step(rng: &mut Rng, depth: usize) -> Step {
        let pick = if depth == 0 { rng.below(3) } else { rng.below(4) };
        match pick {
            0 => Step::EdgePhase {
                epochs: int_biased(rng, 1, 8),
                channel: match rng.below(3) {
                    0 => UploadChannel::DeviceEdge,
                    1 => UploadChannel::DeviceCloud,
                    _ => UploadChannel::DeviceEdgeMasked,
                },
            },
            1 => Step::Gossip { pi: int_biased(rng, 1, 12) as u32 },
            2 => Step::CloudAggregate,
            _ => {
                let len = int_biased(rng, 1, 3);
                Step::Repeat {
                    n: int_biased(rng, 0, 4),
                    body: (0..len).map(|_| step(rng, depth - 1)).collect(),
                }
            }
        }
    }
    let len = int_biased(rng, 0, 4);
    let mut steps: Vec<Step> = (0..len).map(|_| step(rng, 2)).collect();
    steps.push(Step::EdgePhase {
        epochs: int_biased(rng, 1, 4),
        channel: UploadChannel::DeviceEdge,
    });
    Plan::from_steps(steps)
}

#[test]
fn prop_plan_grammar_roundtrips() {
    // parse(print(plan)) == plan for arbitrary valid plans: the text
    // grammar and the AST are two spellings of the same schedule.
    check("plan-roundtrip", 23, default_cases(), |rng| {
        let plan = random_plan(rng);
        plan.validate().map_err(|e| e.to_string())?;
        let spec = plan.to_string();
        let reparsed = Plan::parse(&spec).map_err(|e| e.to_string())?;
        prop_assert!(
            reparsed == plan,
            "round trip changed the plan: {spec:?} -> {reparsed:?}"
        );
        // Printing is a fixpoint (canonical form).
        prop_assert!(
            reparsed.to_string() == spec,
            "print not canonical: {spec:?} vs {:?}",
            reparsed.to_string()
        );
        Ok(())
    });
}

#[test]
fn prop_plans_with_aggregation_keep_report_weights_normalized() {
    // Any plan with at least one aggregation (edge) phase merges reports
    // through `report_weights`; whatever mix of fresh and stale reports
    // each of its phases sees, the Eq. 6 weights must stay a convex
    // combination — weights in [0,1] summing to 1.
    check("plan-weights", 24, default_cases(), |rng| {
        let plan = random_plan(rng);
        let phases = plan.edge_phases();
        prop_assert!(phases >= 1, "generator must produce an aggregation step");
        let pol = SemiSync {
            k: 1,
            timeout_s: 1.0,
            staleness_exp: rng.f64() * 4.0,
        };
        // One simulated merge per (bounded) edge phase of the plan.
        for _ in 0..phases.min(16) {
            let n = int_biased(rng, 1, 12);
            let ns: Vec<usize> = (0..n).map(|_| int_biased(rng, 1, 5000)).collect();
            let ds: Vec<f64> = (0..n)
                .map(|_| pol.staleness_discount(rng.below(25) as u64))
                .collect();
            let w = report_weights(&ns, &ds).map_err(|e| e.to_string())?;
            let sum: f64 = w.iter().sum();
            prop_assert!((sum - 1.0).abs() < 1e-9, "weights sum to {sum}");
            prop_assert!(
                w.iter().all(|&x| (0.0..=1.0 + 1e-12).contains(&x)),
                "weight outside [0,1]: {w:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_graph_removal_keeps_valid_structure() {
    check("node-removal", 19, default_cases(), |rng| {
        let g = random_graph(rng);
        if g.len() < 2 {
            return Ok(());
        }
        let victim = rng.below(g.len());
        let (sub, map) = g.remove_node(victim).map_err(|e| e.to_string())?;
        prop_assert!(sub.len() == g.len() - 1, "wrong size");
        prop_assert!(map[victim].is_none(), "victim still mapped");
        // Edges preserved among survivors.
        for i in 0..g.len() {
            if i == victim {
                continue;
            }
            for &j in g.neighbors(i) {
                if j == victim {
                    continue;
                }
                let (ni, nj) = (map[i].unwrap(), map[j].unwrap());
                prop_assert!(
                    sub.neighbors(ni).contains(&nj),
                    "edge ({i},{j}) lost in removal"
                );
            }
        }
        Ok(())
    });
}
