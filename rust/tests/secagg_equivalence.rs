//! Secure-aggregation equivalence suite — the pin for the secagg tier.
//!
//! Three contracts, all bitwise (docs/DETERMINISM.md):
//!
//! * **Lossless is a protocol identity.** `--secagg lossless` walks every
//!   pairwise seed derivation, masks and unmasks each upload's raw f32
//!   bit patterns — and must reproduce the plain run's history, digest
//!   and CSV bytes exactly, including under deadline drops.
//! * **Masked runs are deterministic.** `--secagg mask:<bits>` changes
//!   the trajectory (fixed-point quantization) but the masked trajectory
//!   itself is pinned across `CFEL_THREADS`, the executor seam
//!   ([`DistRunner`] over 1/2/4 [`LocalExecutor`]s), real cloud + edge
//!   processes on localhost TCP, and — with a reporting deadline —
//!   dropout recovery, where every dropped participant leaves dangling
//!   pair masks that the unmask step must re-derive and cancel.
//! * **Crypto costs are visible.** Mask mode charges nonzero mask
//!   compute and upload inflation in both latency estimators (the new
//!   `secagg_mask_s` / `secagg_extra_bits` CSV columns); lossless and
//!   plain runs charge exactly zero.

use std::io::{BufRead, BufReader, Read};
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;

use cfel::config::{AlgorithmKind, ExperimentConfig, LatencyMode, SecaggMode};
use cfel::coordinator::executor::partition_clusters;
use cfel::coordinator::{ClusterExecutor, Coordinator, DistRunner, LocalExecutor};
use cfel::metrics::{history_digest, CsvWriter, History, ROUND_HEADER};
use cfel::netsim::StragglerSpec;

/// `CFEL_THREADS` is process-global and the CSV helper reuses temp
/// paths, so every test serializes on this lock.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn base_cfg(alg: AlgorithmKind, latency: LatencyMode, secagg: SecaggMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.algorithm = alg;
    cfg.latency = latency;
    cfg.secagg = secagg;
    cfg.rounds = 3;
    cfg
}

/// The determinism-suite straggler scenario: a 0.1 s deadline with a
/// quarter of the fleet slowed 10^6× guarantees drops every edge phase.
fn with_deadline_drops(mut cfg: ExperimentConfig) -> ExperimentConfig {
    cfg.latency = LatencyMode::EventDriven;
    cfg.heterogeneity = Some(0.5);
    cfg.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e6 });
    cfg.deadline_s = Some(0.1);
    cfg
}

fn run_reference(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

fn run_with_threads(cfg: &ExperimentConfig, threads: &str) -> History {
    std::env::set_var("CFEL_THREADS", threads);
    let h = run_reference(cfg);
    std::env::remove_var("CFEL_THREADS");
    h
}

fn run_local_dist(cfg: &ExperimentConfig, n_executors: usize) -> History {
    let mut executors: Vec<Box<dyn ClusterExecutor>> = Vec::new();
    for part in partition_clusters(cfg.n_clusters, n_executors) {
        executors.push(Box::new(LocalExecutor::new(cfg, part).unwrap()));
    }
    let mut runner = DistRunner::new(cfg, executors).unwrap();
    runner.run().unwrap()
}

/// Render a history to CSV text with the wall-clock column zeroed.
fn csv_rows(series: &str, h: &History) -> String {
    let path = std::env::temp_dir()
        .join(format!("cfel_secagg_equiv_{}_{series}.csv", std::process::id()));
    {
        let mut w = CsvWriter::create(&path, ROUND_HEADER).unwrap();
        for rec in h {
            let mut r = rec.clone();
            r.wall_time_s = 0.0;
            w.round_row(series, &r).unwrap();
        }
    }
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    text
}

/// Zero the wall_time_s column (index 3) of a CSV produced by a child
/// process, so it compares against [`csv_rows`] output.
fn zero_wall_column(csv: &str) -> String {
    csv.lines()
        .enumerate()
        .map(|(i, line)| {
            if i == 0 {
                return line.to_string();
            }
            let mut fields: Vec<&str> = line.split(',').collect();
            if fields.len() > 3 {
                fields[3] = "0.000";
            }
            fields.join(",")
        })
        .collect::<Vec<_>>()
        .join("\n")
        + "\n"
}

fn assert_identical(label: &str, a: &History, b: &History) {
    assert_eq!(a.len(), b.len(), "{label}: history lengths differ");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label} r{r} loss");
        assert_eq!(x.test_accuracy.to_bits(), y.test_accuracy.to_bits(), "{label} r{r} acc");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{label} r{r} tloss");
        assert_eq!(x.consensus.to_bits(), y.consensus.to_bits(), "{label} r{r} consensus");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{label} r{r} sim");
        assert_eq!(x.compute_s.to_bits(), y.compute_s.to_bits(), "{label} r{r} compute");
        assert_eq!(x.upload_s.to_bits(), y.upload_s.to_bits(), "{label} r{r} upload");
        assert_eq!(x.backhaul_s.to_bits(), y.backhaul_s.to_bits(), "{label} r{r} backhaul");
        assert_eq!(
            x.secagg_mask_s.to_bits(),
            y.secagg_mask_s.to_bits(),
            "{label} r{r} mask_s"
        );
        assert_eq!(
            x.secagg_extra_bits.to_bits(),
            y.secagg_extra_bits.to_bits(),
            "{label} r{r} extra_bits"
        );
        assert_eq!(x.dropped_devices, y.dropped_devices, "{label} r{r} dropped");
        assert_eq!(x.on_time_devices, y.on_time_devices, "{label} r{r} on-time");
        assert_eq!(x.late_devices, y.late_devices, "{label} r{r} late");
        assert_eq!(x.stale_merged, y.stale_merged, "{label} r{r} stale");
        assert_eq!(x.close_reason, y.close_reason, "{label} r{r} close");
        assert_eq!(x.steps, y.steps, "{label} r{r} steps");
    }
}

fn assert_zero_overhead(label: &str, h: &History) {
    for r in h {
        assert_eq!(r.secagg_mask_s, 0.0, "{label} r{}: mask compute charged", r.round);
        assert_eq!(r.secagg_extra_bits, 0.0, "{label} r{}: inflation charged", r.round);
    }
}

/// Lossless secagg masks and unmasks every device→edge upload in place —
/// a bit-level identity that must leave the whole run untouched: same
/// history, same digest, same CSV bytes, zero charged overhead.
#[test]
fn lossless_secagg_is_bitwise_identical_to_a_plain_run() {
    let _guard = env_guard();
    for threads in ["1", "4"] {
        for alg in [AlgorithmKind::CeFedAvg, AlgorithmKind::HierFAvg] {
            for latency in [LatencyMode::ClosedForm, LatencyMode::EventDriven] {
                let plain = base_cfg(alg, latency, SecaggMode::Off);
                let lossless = base_cfg(alg, latency, SecaggMode::Lossless);
                let label = format!("{}-{}-t{threads}", alg.name(), latency.name());
                let h_plain = run_with_threads(&plain, threads);
                let h_lossless = run_with_threads(&lossless, threads);
                assert_identical(&label, &h_plain, &h_lossless);
                assert_eq!(
                    history_digest(&h_plain),
                    history_digest(&h_lossless),
                    "{label}: digest diverged"
                );
                assert_eq!(
                    csv_rows("oracle", &h_plain),
                    csv_rows("oracle", &h_lossless),
                    "{label}: CSV rows diverged"
                );
                assert_zero_overhead(&label, &h_lossless);
            }
        }
    }
}

/// The identity must survive deadline drops: masking cannot perturb
/// which devices a close policy drops, and an upload that never merges
/// must not leave residue in anyone else's aggregate.
#[test]
fn lossless_identity_holds_under_deadline_drops() {
    let _guard = env_guard();
    let plain = with_deadline_drops(base_cfg(
        AlgorithmKind::CeFedAvg,
        LatencyMode::EventDriven,
        SecaggMode::Off,
    ));
    let lossless = with_deadline_drops(base_cfg(
        AlgorithmKind::CeFedAvg,
        LatencyMode::EventDriven,
        SecaggMode::Lossless,
    ));
    let h_plain = run_with_threads(&plain, "1");
    let h_lossless = run_with_threads(&lossless, "1");
    assert!(
        h_plain.iter().map(|r| r.dropped_devices).sum::<usize>() > 0,
        "the deadline scenario should actually drop devices"
    );
    assert_identical("lossless-drops", &h_plain, &h_lossless);
    assert_eq!(
        history_digest(&h_plain),
        history_digest(&h_lossless),
        "lossless-drops: digest diverged"
    );
}

/// Mask mode quantizes, so it is *not* plain-equivalent — instead its
/// trajectory is pinned across thread counts and the executor seam, and
/// both latency estimators must charge nonzero, identical crypto costs.
#[test]
fn masked_runs_are_bit_deterministic_across_threads_and_executors() {
    let _guard = env_guard();
    for latency in [LatencyMode::ClosedForm, LatencyMode::EventDriven] {
        let plain = base_cfg(AlgorithmKind::CeFedAvg, latency, SecaggMode::Off);
        let cfg = base_cfg(AlgorithmKind::CeFedAvg, latency, SecaggMode::Mask(24));
        let label = format!("mask24-{}", latency.name());
        let h_ref = run_with_threads(&cfg, "1");
        let h_t4 = run_with_threads(&cfg, "4");
        assert_identical(&format!("{label}-t4"), &h_ref, &h_t4);
        for n_ex in [1usize, 2, 4] {
            let h_dist = run_local_dist(&cfg, n_ex);
            let l = format!("{label}-x{n_ex}");
            assert_identical(&l, &h_ref, &h_dist);
            assert_eq!(
                history_digest(&h_ref),
                history_digest(&h_dist),
                "{l}: digest diverged"
            );
        }
        assert_eq!(
            csv_rows("oracle", &h_ref),
            csv_rows("oracle", &run_local_dist(&cfg, 2)),
            "{label}: CSV rows diverged"
        );

        // Both estimators charge the crypto: every round pays mask
        // compute and upload inflation, and the simulated round is
        // strictly slower than the plain run's (same workload, bigger
        // payload + PRG time).
        let h_plain = run_with_threads(&plain, "1");
        for (r, p) in h_ref.iter().zip(&h_plain) {
            assert!(
                r.secagg_mask_s > 0.0,
                "{label} r{}: mask compute not charged",
                r.round
            );
            assert!(
                r.secagg_extra_bits > 0.0,
                "{label} r{}: upload inflation not charged",
                r.round
            );
            assert!(
                r.sim_time_s > p.sim_time_s,
                "{label} r{}: masked round not slower ({} vs {})",
                r.round,
                r.sim_time_s,
                p.sim_time_s
            );
        }
        assert_zero_overhead(&format!("plain-{}", latency.name()), &h_plain);
    }
}

/// Dropout recovery: a reporting deadline drops stragglers after their
/// pair masks are already woven into the survivors' uploads. The unmask
/// step re-derives every dangling share deterministically, so the run
/// stays pinned across threads and the executor seam.
#[test]
fn dropout_recovery_is_deterministic_across_threads_and_executors() {
    let _guard = env_guard();
    let cfg = with_deadline_drops(base_cfg(
        AlgorithmKind::CeFedAvg,
        LatencyMode::EventDriven,
        SecaggMode::Mask(24),
    ));
    let h_ref = run_with_threads(&cfg, "1");
    assert!(
        h_ref.iter().map(|r| r.dropped_devices).sum::<usize>() > 0,
        "the deadline scenario should actually drop devices"
    );
    let h_t4 = run_with_threads(&cfg, "4");
    assert_identical("mask24-drops-t4", &h_ref, &h_t4);
    for n_ex in [1usize, 2, 4] {
        let h_dist = run_local_dist(&cfg, n_ex);
        assert_identical(&format!("mask24-drops-x{n_ex}"), &h_ref, &h_dist);
    }
}

/// Spawn `cfel-cloud` (+2 `cfel-edge`s) on `listen`, run `cfg`, and
/// return (digest hex, CSV text) from the child processes.
fn run_socket_dist(cfg: &ExperimentConfig, listen: &str, cloud_threads: &str) -> (String, String) {
    let tag = format!(
        "{}_{}_{}",
        std::process::id(),
        cfg.run_label().replace('@', "_"),
        cfg.secagg.name().replace(':', "_")
    );
    let cfg_path = std::env::temp_dir().join(format!("cfel_secagg_cfg_{tag}.json"));
    let csv_path = std::env::temp_dir().join(format!("cfel_secagg_csv_{tag}.csv"));
    std::fs::write(&cfg_path, cfg.to_json().to_string()).unwrap();

    let mut cloud = Command::new(env!("CARGO_BIN_EXE_cfel-cloud"))
        .arg("--config")
        .arg(&cfg_path)
        .arg("--listen")
        .arg(listen)
        .arg("--edges")
        .arg("2")
        .arg("--csv")
        .arg(&csv_path)
        .arg("--digest")
        .arg("--quiet")
        .env("CFEL_THREADS", cloud_threads)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn cfel-cloud");
    let mut reader = BufReader::new(cloud.stdout.take().unwrap());

    let mut addr = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("read cloud stdout");
        assert!(n > 0, "cfel-cloud exited before announcing its address");
        if let Some(rest) = line.trim().strip_prefix("[cfel-cloud] listening on ") {
            addr = rest.to_string();
            break;
        }
    }

    let edges: Vec<Child> = (0..2)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_cfel-edge"))
                .arg("--connect")
                .arg(&addr)
                .arg("--quiet")
                .env("CFEL_THREADS", "2")
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn cfel-edge")
        })
        .collect();

    let mut rest = String::new();
    reader.read_to_string(&mut rest).expect("drain cloud stdout");
    let status = cloud.wait().expect("wait cfel-cloud");
    assert!(status.success(), "cfel-cloud failed; stdout:\n{rest}");
    for mut e in edges {
        let st = e.wait().expect("wait cfel-edge");
        assert!(st.success(), "cfel-edge failed");
    }

    let digest = rest
        .lines()
        .find_map(|l| l.trim().strip_prefix("history_digest: "))
        .unwrap_or_else(|| panic!("no digest in cloud output:\n{rest}"))
        .to_string();
    let csv = std::fs::read_to_string(&csv_path).expect("child CSV");
    std::fs::remove_file(&cfg_path).ok();
    std::fs::remove_file(&csv_path).ok();
    (digest, csv)
}

/// Masked payloads over real sockets: the edge ships the encoded
/// [`MaskedPhaseDone`] sum, decodes it into its own mirror, and the
/// cloud's decode must land on the same bits — digest and CSV equal to
/// the in-process reference, in both latency modes and under drops.
#[test]
fn socket_processes_carry_masked_payloads_bit_identically() {
    let _guard = env_guard();
    let mut cfgs = vec![
        base_cfg(AlgorithmKind::CeFedAvg, LatencyMode::ClosedForm, SecaggMode::Mask(24)),
        base_cfg(AlgorithmKind::CeFedAvg, LatencyMode::EventDriven, SecaggMode::Mask(24)),
        with_deadline_drops(base_cfg(
            AlgorithmKind::CeFedAvg,
            LatencyMode::EventDriven,
            SecaggMode::Mask(24),
        )),
    ];
    for (i, cfg) in cfgs.drain(..).enumerate() {
        let h_ref = run_with_threads(&cfg, "1");
        let label = format!("socket-mask24-{i}-{}", cfg.latency.name());
        let (digest, csv) = run_socket_dist(&cfg, "127.0.0.1:0", "4");
        assert_eq!(
            digest,
            format!("{:016x}", history_digest(&h_ref)),
            "{label}: history digest diverged"
        );
        assert_eq!(
            zero_wall_column(&csv),
            csv_rows(&cfg.run_label(), &h_ref),
            "{label}: CSV rows diverged"
        );
    }

    // And the lossless identity end-to-end: a lossless socket run must
    // reproduce the *plain in-process* digest — masked channel on the
    // wire, plain bits in the history.
    let plain = base_cfg(AlgorithmKind::CeFedAvg, LatencyMode::EventDriven, SecaggMode::Off);
    let lossless = base_cfg(AlgorithmKind::CeFedAvg, LatencyMode::EventDriven, SecaggMode::Lossless);
    let h_plain = run_with_threads(&plain, "1");
    let (digest, csv) = run_socket_dist(&lossless, "127.0.0.1:0", "4");
    assert_eq!(
        digest,
        format!("{:016x}", history_digest(&h_plain)),
        "socket-lossless: digest diverged from the plain run"
    );
    assert_eq!(
        zero_wall_column(&csv),
        csv_rows(&lossless.run_label(), &h_plain),
        "socket-lossless: CSV rows diverged from the plain run"
    );
}
