//! Integration tests for the extension features: upload compression,
//! partial participation, checkpointing.

use cfel::compression::Compressor;
use cfel::config::{AlgorithmKind, ExperimentConfig};
use cfel::coordinator::Coordinator;
use cfel::metrics::{best_accuracy, History};
use cfel::model::checkpoint;

fn run(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

fn base(rounds: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::paper_system(AlgorithmKind::CeFedAvg);
    c.rounds = rounds;
    c
}

#[test]
fn compression_shrinks_simulated_time_per_round() {
    let h_raw = run(&base(3));
    let mut c = base(3);
    c.compression = Compressor::Quantize { bits: 8 };
    let h_q8 = run(&c);
    // Communication dominates Eq. 8 here, so 8-bit uploads must cut the
    // simulated clock by roughly 4x (compute share is unchanged).
    let (t_raw, t_q8) = (h_raw[2].sim_time_s, h_q8[2].sim_time_s);
    assert!(t_q8 < t_raw * 0.4, "quantize:8 {t_q8} !<< raw {t_raw}");
}

#[test]
fn quantized_training_still_learns() {
    let mut c = base(12);
    c.compression = Compressor::Quantize { bits: 8 };
    let h = run(&c);
    assert!(best_accuracy(&h) > 0.5, "{}", best_accuracy(&h));
    // And stays close to the uncompressed accuracy.
    let h_raw = run(&base(12));
    assert!(
        best_accuracy(&h) > best_accuracy(&h_raw) - 0.1,
        "q8 {} vs raw {}",
        best_accuracy(&h),
        best_accuracy(&h_raw)
    );
}

#[test]
fn aggressive_topk_degrades_but_runs() {
    let mut c = base(8);
    c.compression = Compressor::TopK { fraction: 0.05 };
    let h = run(&c);
    // Still trains (top-5% of a fresh model moves the loss), no NaNs.
    assert!(h.iter().all(|r| r.train_loss.is_finite()));
    assert!(best_accuracy(&h) > 0.2);
}

#[test]
fn participation_halves_steps_and_still_learns() {
    let full = run(&base(6));
    let mut c = base(6);
    c.participation = 0.5;
    let half = run(&c);
    let steps_full: usize = full.iter().map(|r| r.steps).sum();
    let steps_half: usize = half.iter().map(|r| r.steps).sum();
    assert!(
        steps_half * 2 <= steps_full + steps_full / 10,
        "sampling did not halve work: {steps_half} vs {steps_full}"
    );
    assert!(best_accuracy(&half) > 0.4, "{}", best_accuracy(&half));
}

#[test]
fn participation_is_deterministic() {
    let mut c = base(4);
    c.participation = 0.5;
    let a = run(&c);
    let b = run(&c);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.train_loss, y.train_loss);
        assert_eq!(x.steps, y.steps);
    }
}

#[test]
fn full_participation_unchanged_by_feature() {
    // participation = 1.0 must reproduce the original trajectory.
    let mut c = base(3);
    c.participation = 1.0;
    let a = run(&c);
    let b = run(&base(3));
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.train_loss, y.train_loss);
    }
}

#[test]
fn checkpoint_roundtrip_through_coordinator_models() {
    let mut coord = Coordinator::from_config(&base(2)).unwrap();
    coord.run().unwrap();
    let model = coord.clusters[0].model.clone();
    let path = std::env::temp_dir().join(format!("cfel_int_ckpt_{}.ckpt", std::process::id()));
    let state = cfel::model::ModelState::from_params(model.clone());
    checkpoint::save(&path, &state, "mock-mlp", 2).unwrap();
    let (loaded, meta) = checkpoint::load(&path, Some(model.len())).unwrap();
    assert_eq!(loaded.params, model);
    assert_eq!(meta.round, 2);
    std::fs::remove_file(&path).ok();
}

#[test]
fn config_json_roundtrips_extensions() {
    let mut c = base(2);
    c.compression = Compressor::TopK { fraction: 0.25 };
    c.participation = 0.75;
    let j = c.to_json();
    let c2 = ExperimentConfig::from_json(&j).unwrap();
    assert_eq!(c2.compression, c.compression);
    assert_eq!(c2.participation, c.participation);
    // Invalid participation rejected.
    let mut bad = base(2);
    bad.participation = 0.0;
    assert!(bad.validate().is_err());
}
