//! The event-driven latency simulator against its closed-form oracle.
//!
//! With homogeneous (per-device-constant) workloads, full participation
//! and no reporting deadline, the discrete-event simulation must reproduce
//! the closed-form Eq. 8 round latency to ≤1e-9 relative error for all
//! four algorithms — the closed form is exactly the sum of the per-phase
//! barriers in that regime (see `netsim::event` docs). Training itself is
//! identical in both modes (nobody is dropped), so the learning curves
//! must match bit-for-bit too.

use cfel::config::{AlgorithmKind, ExperimentConfig, LatencyMode};
use cfel::coordinator::Coordinator;
use cfel::metrics::History;
use cfel::netsim::StragglerSpec;

fn run(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

fn base(alg: AlgorithmKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.algorithm = alg;
    cfg.rounds = 3;
    cfg
}

fn assert_latency_close(alg: AlgorithmKind, closed: &History, event: &History) {
    assert_eq!(closed.len(), event.len());
    for (c, e) in closed.iter().zip(event) {
        let rel = (c.sim_time_s - e.sim_time_s).abs() / c.sim_time_s;
        assert!(
            rel <= 1e-9,
            "{alg:?} round {}: closed {} vs event {} (rel {rel:e})",
            c.round,
            c.sim_time_s,
            e.sim_time_s
        );
        // No deadline ⇒ no drops ⇒ the training trajectory is untouched.
        assert_eq!(c.train_loss.to_bits(), e.train_loss.to_bits());
        assert_eq!(c.test_accuracy.to_bits(), e.test_accuracy.to_bits());
        assert_eq!(e.dropped_devices, 0);
    }
}

#[test]
fn event_sim_matches_eq8_for_all_algorithms_homogeneous() {
    for alg in AlgorithmKind::all() {
        let cfg = base(alg);
        let mut event_cfg = cfg.clone();
        event_cfg.latency = LatencyMode::EventDriven;
        assert_latency_close(alg, &run(&cfg), &run(&event_cfg));
    }
}

#[test]
fn event_sim_matches_eq8_under_heterogeneity_full_participation() {
    // Per-device speeds differ but are constant across edge phases, so the
    // straggler of every phase is the same device and the per-phase
    // barriers still sum to the Eq. 8 max (see module docs).
    for alg in [AlgorithmKind::CeFedAvg, AlgorithmKind::FedAvg] {
        let mut cfg = base(alg);
        cfg.heterogeneity = Some(0.5);
        let mut event_cfg = cfg.clone();
        event_cfg.latency = LatencyMode::EventDriven;
        assert_latency_close(alg, &run(&cfg), &run(&event_cfg));
    }
}

#[test]
fn deadline_drops_stragglers_and_caps_round_latency() {
    // A quarter of the fleet is slowed ~10^6× (effectively stalled), the
    // rest report in ~8 ms (upload-dominated on the mock model). A 100 ms
    // deadline therefore drops exactly the stragglers, every edge phase.
    let mut cfg = base(AlgorithmKind::CeFedAvg);
    cfg.latency = LatencyMode::EventDriven;
    cfg.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e6 });
    cfg.rounds = 4;
    let mut with_dl = cfg.clone();
    with_dl.deadline_s = Some(0.1);
    let free = run(&cfg);
    let capped = run(&with_dl);
    let stragglers = (cfg.n_devices as f64 * 0.25).ceil() as usize;
    for rec in &capped {
        assert_eq!(
            rec.dropped_devices,
            stragglers * cfg.q,
            "round {}: expected every straggler dropped in each of q phases",
            rec.round
        );
        assert!(rec.test_accuracy.is_nan() || rec.test_accuracy.is_finite());
    }
    for rec in &free {
        assert_eq!(rec.dropped_devices, 0, "no deadline, nothing dropped");
    }
    // Dropping the stalled devices is the whole point: the deadline-capped
    // run must be much faster in virtual time.
    let (t_free, t_capped) = (
        free.last().unwrap().sim_time_s,
        capped.last().unwrap().sim_time_s,
    );
    assert!(
        t_capped < t_free / 10.0,
        "deadline did not cap latency: {t_capped} !<< {t_free}"
    );
}

#[test]
fn all_devices_dropped_keeps_models_and_does_not_panic() {
    // Regression companion to the aggregation empty-set bugfix: a deadline
    // shorter than any possible report drops *every* device of *every*
    // cluster; each cluster must keep its previous edge model (here: the
    // shared init), not panic.
    let mut cfg = base(AlgorithmKind::CeFedAvg);
    cfg.latency = LatencyMode::EventDriven;
    cfg.deadline_s = Some(1e-9);
    cfg.rounds = 3;
    let h = run(&cfg);
    for rec in &h {
        assert_eq!(rec.dropped_devices, cfg.n_devices * cfg.q);
        // All clusters stay at the identical init model.
        assert!(rec.consensus < 1e-30, "consensus {}", rec.consensus);
    }
    // The model never moves, so accuracy is frozen at its initial value.
    assert_eq!(
        h.first().unwrap().test_accuracy.to_bits(),
        h.last().unwrap().test_accuracy.to_bits()
    );
}

#[test]
fn per_round_breakdown_is_populated_and_consistent() {
    let mut cfg = base(AlgorithmKind::CeFedAvg);
    cfg.latency = LatencyMode::EventDriven;
    let h = run(&cfg);
    let mut prev = 0.0;
    for rec in &h {
        let round_total = rec.compute_s + rec.upload_s + rec.backhaul_s;
        let delta = rec.sim_time_s - prev;
        assert!(
            (round_total - delta).abs() <= 1e-9 * delta.max(1.0),
            "round {}: breakdown {round_total} != delta {delta}",
            rec.round
        );
        assert!(rec.compute_s > 0.0 && rec.upload_s > 0.0);
        assert!(rec.backhaul_s > 0.0, "CE-FedAvg gossips every round");
        prev = rec.sim_time_s;
    }
}
