//! The parallel cluster engine must be invisible in the results:
//! the same config + seed produce an identical `History` whether the
//! round runs on 1 worker thread or 4 (`CFEL_THREADS`). RNG streams are
//! derived per (round-phase, cluster/device) from the root seed and all
//! merges happen in deterministic order after the join, so this holds
//! bit-for-bit, not just approximately.

use cfel::config::{AggPolicyKind, AlgorithmKind, ExperimentConfig, LatencyMode};
use cfel::coordinator::Coordinator;
use cfel::metrics::History;
use cfel::netsim::StragglerSpec;

fn run(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

fn run_with_threads(cfg: &ExperimentConfig, threads: &str) -> History {
    std::env::set_var("CFEL_THREADS", threads);
    let h = run(cfg);
    std::env::remove_var("CFEL_THREADS");
    h
}

fn assert_bit_identical(alg: AlgorithmKind, a: &History, b: &History) {
    assert_eq!(a.len(), b.len(), "{alg:?}: history lengths differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round);
        // Bitwise f64 equality: the merge order after the parallel join
        // is fixed, so not even the float accumulation may differ.
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{alg:?} round {}: train_loss {} vs {}",
            x.round,
            x.train_loss,
            y.train_loss
        );
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{alg:?} round {}: test_accuracy {} vs {}",
            x.round,
            x.test_accuracy,
            y.test_accuracy
        );
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
        assert_eq!(x.consensus.to_bits(), y.consensus.to_bits());
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits());
        // The event-driven latency path must be thread-invariant too,
        // down to the per-round breakdown and which devices a deadline
        // dropped.
        assert_eq!(x.compute_s.to_bits(), y.compute_s.to_bits());
        assert_eq!(x.upload_s.to_bits(), y.upload_s.to_bits());
        assert_eq!(x.backhaul_s.to_bits(), y.backhaul_s.to_bits());
        assert_eq!(x.dropped_devices, y.dropped_devices);
        // Semi-sync bookkeeping must be thread-invariant too — including
        // which late uploads land (merge stale) in which round.
        assert_eq!(x.on_time_devices, y.on_time_devices);
        assert_eq!(x.late_devices, y.late_devices);
        assert_eq!(
            x.stale_merged,
            y.stale_merged,
            "{alg:?} round {}: stale merges landed in different rounds",
            x.round
        );
        assert_eq!(x.close_reason, y.close_reason);
        assert_eq!(x.steps, y.steps);
    }
}

/// Run under each thread count and pin all histories to the first.
fn assert_thread_invariant(alg: AlgorithmKind, cfg: &ExperimentConfig) -> History {
    let reference = run_with_threads(cfg, "1");
    for threads in ["2", "4"] {
        let h = run_with_threads(cfg, threads);
        assert_bit_identical(alg, &reference, &h);
    }
    reference
}

/// One test body: `CFEL_THREADS` is process-global, so the env-var
/// mutations must not race a concurrently running test.
#[test]
fn histories_identical_across_thread_counts() {
    for alg in [AlgorithmKind::CeFedAvg, AlgorithmKind::HierFAvg] {
        let mut cfg = ExperimentConfig::quickstart();
        cfg.algorithm = alg;
        cfg.rounds = 6;
        assert_thread_invariant(alg, &cfg);

        // Partial participation exercises the per-(cluster, phase)
        // sampling streams as well.
        let mut sampled = cfg.clone();
        sampled.participation = 0.5;
        sampled.rounds = 4;
        assert_thread_invariant(alg, &sampled);

        // Event-driven latency with stragglers and a reporting deadline:
        // the simulation runs post-join in deterministic cluster order,
        // so virtual timing and deadline drops are thread-invariant.
        let mut event = cfg.clone();
        event.latency = LatencyMode::EventDriven;
        event.heterogeneity = Some(0.5);
        event.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e6 });
        event.deadline_s = Some(0.1);
        event.rounds = 4;
        let e = assert_thread_invariant(alg, &event);
        assert!(
            e.iter().map(|r| r.dropped_devices).sum::<usize>() > 0,
            "{alg:?}: the deadline scenario should actually drop devices"
        );

        // Semi-sync K-of-N with a timeout: late reports are parked and
        // folded into later rounds — which round each one lands in is
        // part of the pinned state (assert_bit_identical compares the
        // per-round late/stale counts).
        let mut semi = cfg.clone();
        semi.latency = LatencyMode::EventDriven;
        semi.heterogeneity = Some(0.5);
        semi.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e4 });
        semi.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.02 };
        semi.staleness_exp = 1.0;
        semi.rounds = 4;
        let s = assert_thread_invariant(alg, &semi);
        assert!(
            s.iter().map(|r| r.late_devices).sum::<usize>() > 0,
            "{alg:?}: the semi-sync scenario should actually defer reports"
        );
        assert!(
            s.iter().map(|r| r.stale_merged).sum::<usize>() > 0,
            "{alg:?}: deferred reports should merge stale within the run"
        );
    }
}
