//! PJRT round-trip integration tests (artifact-gated: these run the real
//! AOT HLO artifacts through the runtime and skip cleanly when
//! `make artifacts` has not been run).

use cfel::config::{BackendKind, ExperimentConfig};
use cfel::coordinator::Coordinator;
use cfel::data::synthetic::{Prototypes, SyntheticSpec};
use cfel::data::{sampler::eval_batches, Batch};
use cfel::runtime::{Manifest, PjrtBackend, TrainBackend};
use cfel::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    // Artifact- AND feature-gated: without `--features xla` the stub
    // backend cannot execute HLO, so skip even when artifacts exist.
    if !cfg!(feature = "xla") {
        return None;
    }
    Manifest::load(&Manifest::default_dir()).ok()
}

fn backend(name: &str) -> Option<PjrtBackend> {
    manifest().map(|m| PjrtBackend::from_manifest(&m, name).expect("backend load"))
}

fn task_batch(be: &dyn TrainBackend, seed: u64) -> (cfel::data::Dataset, Batch) {
    let spec = SyntheticSpec {
        dim: be.flat_dim(),
        num_classes: be.num_classes(),
        ..SyntheticSpec::mlp_synth()
    };
    let protos = Prototypes::new(spec, &Rng::new(seed));
    let ds = protos.global_pool(be.batch_size() * 3, &Rng::new(seed + 1));
    let idx: Vec<usize> = (0..be.batch_size()).collect();
    let b = Batch::gather(&ds, &idx, be.batch_size());
    (ds, b)
}

#[test]
fn train_step_decreases_loss_on_every_model() {
    let Some(man) = manifest() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    for name in man.models.keys() {
        let be = PjrtBackend::from_manifest(&man, name).unwrap();
        let (_, batch) = task_batch(&be, 11);
        let mut state = be.init_state(&Rng::new(12));
        let l0 = be.train_step(&mut state, &batch, 0.05).unwrap();
        let mut last = l0;
        for _ in 0..4 {
            last = be.train_step(&mut state, &batch, 0.05).unwrap();
        }
        assert!(last < l0, "{name}: loss {l0} -> {last}");
        assert!(l0.is_finite() && last.is_finite());
    }
}

#[test]
fn initial_loss_matches_uniform_prediction() {
    // Fresh Glorot init ⇒ near-uniform softmax ⇒ loss ≈ ln(C). Validates
    // the whole literal-marshalling path (wrong parameter order or
    // transposed shapes would blow this up).
    let Some(be) = backend("mlp_synth") else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let (_, batch) = task_batch(&be, 21);
    let mut state = be.init_state(&Rng::new(22));
    let loss = be.train_step(&mut state, &batch, 0.0).unwrap();
    let ln_c = (be.num_classes() as f32).ln();
    assert!(
        (loss - ln_c).abs() < 0.35 * ln_c,
        "initial loss {loss} vs ln(C) {ln_c}"
    );
}

#[test]
fn zero_lr_step_keeps_params_but_fills_momentum() {
    let Some(be) = backend("mlp_synth") else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let (_, batch) = task_batch(&be, 31);
    let mut state = be.init_state(&Rng::new(32));
    let p0 = state.params.clone();
    be.train_step(&mut state, &batch, 0.0).unwrap();
    assert_eq!(state.params, p0, "params moved at lr=0");
    assert!(
        state.momentum.iter().any(|&v| v != 0.0),
        "momentum not written back"
    );
}

#[test]
fn eval_masks_padding_and_matches_manual_count() {
    let Some(be) = backend("mlp_synth") else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let (ds, _) = task_batch(&be, 41);
    let state = be.init_state(&Rng::new(42));
    // Full batches vs a short final batch: examples must add up.
    let batches = eval_batches(&ds, be.batch_size());
    let r = be.eval(&state.params, &batches).unwrap();
    assert_eq!(r.examples, ds.len());
    assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0);
    assert!(r.loss > 0.0);
    // Padded single-example batch.
    let short = Batch::gather(&ds, &[0], be.batch_size());
    let r1 = be.eval(&state.params, &[short]).unwrap();
    assert_eq!(r1.examples, 1);
}

#[test]
fn training_beats_chance_on_separable_task() {
    let Some(be) = backend("mlp_synth") else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let (ds, batch) = task_batch(&be, 51);
    let mut state = be.init_state(&Rng::new(52));
    for _ in 0..25 {
        be.train_step(&mut state, &batch, 0.1).unwrap();
    }
    let r = be
        .eval(&state.params, &eval_batches(&ds, be.batch_size()))
        .unwrap();
    let chance = 1.0 / be.num_classes() as f64;
    assert!(r.accuracy > 3.0 * chance, "accuracy {} vs chance {chance}", r.accuracy);
}

#[test]
fn full_ce_fedavg_round_on_pjrt() {
    if manifest().is_none() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let mut cfg = ExperimentConfig::quickstart();
    cfg.n_devices = 4;
    cfg.n_clusters = 2;
    cfg.rounds = 2;
    cfg.tau = 1;
    cfg.q = 1;
    cfg.samples_per_device = 110; // ~2 batches of 50
    cfg.data_noise = None;
    cfg.backend = BackendKind::Pjrt { model: "mlp_synth".into(), artifacts_dir: None };
    let mut coord = Coordinator::from_config(&cfg).unwrap();
    let h = coord.run().unwrap();
    assert_eq!(h.len(), 2);
    assert!(h[1].train_loss < h[0].train_loss);
    assert!(!h[1].test_accuracy.is_nan());
}

#[test]
fn rejects_wrong_batch_size() {
    let Some(be) = backend("mlp_synth") else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let mut state = be.init_state(&Rng::new(1));
    let bad = Batch { x: vec![0.0; 2 * be.flat_dim()], y: vec![0, 1], valid: 2 };
    assert!(be.train_step(&mut state, &bad, 0.1).is_err());
}
