//! Scenario-API equivalence suite.
//!
//! The world is now data: the coordinator builds exclusively from a
//! [`Scenario`] (rosters, capability profiles, links, timeline), and the
//! flat `ExperimentConfig` knobs are sugar that lowers into a static one
//! (`Scenario::from_flat`). These tests pin that redesign safe:
//!
//! * lowering `quickstart()` and `paper_system()` (all four algorithms)
//!   to an explicit static `Scenario` — including a JSON round trip of
//!   the scenario — reproduces the flat-config history and CSV rows
//!   *bit-identically*, under the closed-form and event-driven latency
//!   modes and under `CFEL_THREADS` 1 and 4;
//! * a churn timeline (Markov join/leave plus a handover, a capacity
//!   change and a link change mid-run) runs all four canned plans,
//!   learns well above chance, and is bit-deterministic across thread
//!   counts — in closed-form and event-driven mode.

use std::sync::Mutex;

use cfel::config::{AlgorithmKind, ExperimentConfig, LatencyMode};
use cfel::coordinator::Coordinator;
use cfel::metrics::{best_accuracy, CsvWriter, History, ROUND_HEADER};
use cfel::scenario::{
    ChurnSpec, LinkKind, Scenario, Timeline, TimelineEvent, WorldEvent,
};

/// `CFEL_THREADS` is process-global and the CSV helper reuses one temp
/// path, so every test that touches either serializes on this lock
/// (tests in one binary run on parallel threads).
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn run(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

/// Render a history to CSV text with the wall-clock column zeroed (real
/// time differs between any two runs; everything else must not).
fn csv_rows(series: &str, h: &History) -> String {
    let path = std::env::temp_dir().join(format!(
        "cfel_scenario_equiv_{}_{series}.csv",
        std::process::id()
    ));
    {
        let mut w = CsvWriter::create(&path, ROUND_HEADER).unwrap();
        for rec in h {
            let mut r = rec.clone();
            r.wall_time_s = 0.0;
            w.round_row(series, &r).unwrap();
        }
    }
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    text
}

fn assert_identical(label: &str, a: &History, b: &History) {
    assert_eq!(a.len(), b.len(), "{label}: history lengths differ");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label} r{r} loss");
        assert_eq!(x.test_accuracy.to_bits(), y.test_accuracy.to_bits(), "{label} r{r} acc");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{label} r{r} tloss");
        assert_eq!(x.consensus.to_bits(), y.consensus.to_bits(), "{label} r{r} consensus");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{label} r{r} sim");
        assert_eq!(x.compute_s.to_bits(), y.compute_s.to_bits(), "{label} r{r} compute");
        assert_eq!(x.upload_s.to_bits(), y.upload_s.to_bits(), "{label} r{r} upload");
        assert_eq!(x.backhaul_s.to_bits(), y.backhaul_s.to_bits(), "{label} r{r} backhaul");
        assert_eq!(x.dropped_devices, y.dropped_devices, "{label} r{r} dropped");
        assert_eq!(x.on_time_devices, y.on_time_devices, "{label} r{r} on-time");
        assert_eq!(x.late_devices, y.late_devices, "{label} r{r} late");
        assert_eq!(x.stale_merged, y.stale_merged, "{label} r{r} stale");
        assert_eq!(x.close_reason, y.close_reason, "{label} r{r} close");
        assert_eq!(x.steps, y.steps, "{label} r{r} steps");
    }
}

/// The flat configs the acceptance matrix names: the quickstart preset
/// plus the paper's §6.1 system under each of the four algorithms
/// (rounds trimmed so the 2-latency x 2-thread matrix stays fast).
fn flat_cases() -> Vec<ExperimentConfig> {
    let mut quick = ExperimentConfig::quickstart();
    quick.rounds = 4;
    let mut cases = vec![quick];
    for alg in AlgorithmKind::all() {
        let mut c = ExperimentConfig::paper_system(alg);
        c.rounds = 3;
        cases.push(c);
    }
    cases
}

/// One test body: `CFEL_THREADS` is process-global, so the matrix runs
/// sequentially instead of racing parallel test threads over the env var.
#[test]
fn flat_configs_lower_to_static_scenarios_bit_identically() {
    let _guard = env_guard();
    for threads in ["1", "4"] {
        std::env::set_var("CFEL_THREADS", threads);
        for base in flat_cases() {
            for latency in [LatencyMode::ClosedForm, LatencyMode::EventDriven] {
                let mut flat = base.clone();
                flat.latency = latency;
                // The lowering, sent through the JSON round trip the
                // `--scenario` path uses.
                let lowered = Scenario::from_flat(&flat);
                let reparsed = Scenario::from_json(&lowered.to_json()).unwrap();
                assert_eq!(reparsed, lowered, "scenario JSON round trip drifted");
                let mut scenic = flat.clone();
                scenic.scenario = Some(reparsed);
                scenic.validate().unwrap();
                let label = format!("{}-{}-t{threads}", flat.name, latency.name());
                let h_flat = run(&flat);
                let h_scenic = run(&scenic);
                assert_identical(&label, &h_flat, &h_scenic);
                assert_eq!(
                    csv_rows("oracle", &h_flat),
                    csv_rows("oracle", &h_scenic),
                    "{label}: CSV rows diverged"
                );
            }
        }
        std::env::remove_var("CFEL_THREADS");
    }
}

#[test]
fn heterogeneous_straggler_knobs_lower_bit_identically_too() {
    // The capability-profile half of the lowering: heterogeneity and
    // stragglers must reproduce the exact same capability draws when
    // routed through Derived profiles.
    let _guard = env_guard();
    for threads in ["1", "4"] {
        std::env::set_var("CFEL_THREADS", threads);
        let mut flat = ExperimentConfig::quickstart();
        flat.rounds = 3;
        flat.latency = LatencyMode::EventDriven;
        flat.heterogeneity = Some(0.5);
        flat.stragglers =
            Some(cfel::netsim::StragglerSpec { fraction: 0.25, slowdown: 1e4 });
        let mut scenic = flat.clone();
        scenic.scenario = Some(Scenario::from_flat(&flat));
        // The lowering owns the capability knobs; the flat fields clear.
        scenic.heterogeneity = None;
        scenic.stragglers = None;
        scenic.validate().unwrap();
        let label = format!("hetero-stragglers-t{threads}");
        assert_identical(&label, &run(&flat), &run(&scenic));
        std::env::remove_var("CFEL_THREADS");
    }
}

/// Where `device` is (home cluster or after replaying `timeline`) at the
/// start of round `round` — events of that round included, as the
/// coordinator applies them at the boundary before training.
fn cluster_at(
    timeline: &Timeline,
    rosters: &[Vec<usize>],
    device: usize,
    round: usize,
) -> Option<usize> {
    let mut cur = rosters.iter().position(|r| r.contains(&device));
    for ev in &timeline.events {
        if ev.round > round {
            continue;
        }
        match ev.event {
            WorldEvent::Join { device: d, cluster } if d == device => cur = Some(cluster),
            WorldEvent::Leave { device: d } if d == device => cur = None,
            WorldEvent::Handover { device: d, to, .. } if d == device => cur = Some(to),
            _ => {}
        }
    }
    cur
}

/// Markov churn over the quickstart rosters plus one handover, one
/// capacity change and one link change — the full event vocabulary.
fn churn_scenario(cfg: &ExperimentConfig) -> Scenario {
    let mut s = Scenario::from_flat(cfg);
    s.name = "churn".into();
    let spec = ChurnSpec { p_leave: 0.2, p_join: 0.6, rounds: cfg.rounds, seed: 7 };
    let mut tl = Timeline::markov_churn(&s.rosters, &spec).unwrap();
    assert!(!tl.is_empty(), "churn spec produced a static world");
    // Hand over the first device that is still active at round 2.
    let (dev, from) = (0..cfg.n_devices)
        .find_map(|d| cluster_at(&tl, &s.rosters, d, 2).map(|c| (d, c)))
        .expect("some device survives to round 2");
    tl.events.push(TimelineEvent {
        round: 2,
        event: WorldEvent::Handover {
            device: dev,
            from,
            to: (from + 1) % s.rosters.len(),
        },
    });
    tl.events.push(TimelineEvent {
        round: 3,
        event: WorldEvent::CapacityChange { device: dev, factor: 0.5 },
    });
    tl.events.push(TimelineEvent {
        round: 3,
        event: WorldEvent::LinkChange { link: LinkKind::EdgeEdge, bps: 2.5e7 },
    });
    s.timeline = tl;
    s
}

#[test]
fn churn_timeline_runs_all_plans_learns_and_is_thread_deterministic() {
    let _guard = env_guard();
    for alg in AlgorithmKind::all() {
        let mut cfg = ExperimentConfig::quickstart();
        cfg.algorithm = alg;
        cfg.rounds = 8;
        let scenario = churn_scenario(&cfg);
        // The time-varying scenario survives the JSON round trip intact.
        assert_eq!(
            Scenario::from_json(&scenario.to_json()).unwrap(),
            scenario,
            "churn scenario JSON round trip drifted"
        );
        cfg.scenario = Some(scenario);
        cfg.validate().unwrap();
        assert_eq!(cfg.run_label(), format!("{}@churn", alg.name()));

        let mut histories = Vec::new();
        for threads in ["1", "4"] {
            std::env::set_var("CFEL_THREADS", threads);
            histories.push(run(&cfg));
            std::env::remove_var("CFEL_THREADS");
        }
        let label = format!("churn-{}", alg.name());
        assert_identical(&label, &histories[0], &histories[1]);
        assert_eq!(
            csv_rows("oracle", &histories[0]),
            csv_rows("oracle", &histories[1]),
            "{label}: CSV rows diverged across thread counts"
        );
        let best = best_accuracy(&histories[0]);
        assert!(best > 0.25, "{label} failed to learn under churn: {best}");
    }
}

#[test]
fn churn_is_deterministic_under_the_event_simulator_too() {
    // Membership churn interleaved with per-device event timing: the
    // virtual clocks, close verdicts and latency breakdowns must stay
    // bit-identical across thread counts.
    let _guard = env_guard();
    let mut cfg = ExperimentConfig::quickstart();
    cfg.rounds = 6;
    cfg.latency = LatencyMode::EventDriven;
    let scenario = churn_scenario(&cfg);
    cfg.scenario = Some(scenario);
    cfg.validate().unwrap();
    let mut histories = Vec::new();
    for threads in ["1", "4"] {
        std::env::set_var("CFEL_THREADS", threads);
        histories.push(run(&cfg));
        std::env::remove_var("CFEL_THREADS");
    }
    assert_identical("churn-event", &histories[0], &histories[1]);
    assert!(best_accuracy(&histories[0]) > 0.2);
    // The round-3 capacity + link changes actually moved the simulated
    // clock: per-round latency differs from the static world's.
    let mut static_cfg = ExperimentConfig::quickstart();
    static_cfg.rounds = 6;
    static_cfg.latency = LatencyMode::EventDriven;
    let h_static = run(&static_cfg);
    let churn_total = histories[0].last().unwrap().sim_time_s;
    let static_total = h_static.last().unwrap().sim_time_s;
    assert_ne!(
        churn_total.to_bits(),
        static_total.to_bits(),
        "the timeline should change the simulated runtime"
    );
}

#[test]
fn uneven_split_keeps_learning_and_the_flat_path_stays_default() {
    // Satellite: n need not divide m anymore. 18 devices over 4 clusters
    // (5/5/4/4) trains end to end through the same lowering.
    let mut cfg = ExperimentConfig::quickstart();
    cfg.n_devices = 18;
    cfg.rounds = 6;
    cfg.validate().unwrap();
    assert_eq!(cfg.cluster_sizes(), vec![5, 5, 4, 4]);
    let h = run(&cfg);
    assert!(best_accuracy(&h) > 0.25, "uneven split failed to learn");
    // No explicit scenario => plain label (CSV schema unchanged).
    assert_eq!(cfg.run_label(), "ce-fedavg");
}
