//! Pathological `Timeline`s and boundary faults (satellite of the
//! multi-process runtime; lives beside `scenario_equivalence.rs`).
//!
//! * A mass simultaneous leave — 15 of 16 devices gone at one round
//!   boundary, three clusters emptied outright — must not panic, and the
//!   history must be bit-identical across worker-thread counts.
//! * Killing the aggregator cluster (or any cluster) exactly at a round
//!   boundary is equally deterministic, for every canned plan.
//! * The same pathological scenario run through the distributed driver
//!   ([`DistRunner`] over [`LocalExecutor`]s) reproduces the in-process
//!   history bit for bit — empty rosters cross the executor seam too.

use std::sync::Mutex;

use cfel::config::{AlgorithmKind, ExperimentConfig, FaultSpec, LatencyMode};
use cfel::coordinator::executor::partition_clusters;
use cfel::coordinator::{ClusterExecutor, Coordinator, DistRunner, LocalExecutor};
use cfel::metrics::{history_digest, History};
use cfel::scenario::{Scenario, Timeline, TimelineEvent, WorldEvent};

/// `CFEL_THREADS` is process-global; every test serializes on this lock.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn run_reference(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

fn run_under_threads(cfg: &ExperimentConfig, threads: &str) -> History {
    std::env::set_var("CFEL_THREADS", threads);
    let h = run_reference(cfg);
    std::env::remove_var("CFEL_THREADS");
    h
}

fn assert_identical(label: &str, a: &History, b: &History) {
    assert_eq!(a.len(), b.len(), "{label}: history lengths differ");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label} r{r} loss");
        assert_eq!(x.test_accuracy.to_bits(), y.test_accuracy.to_bits(), "{label} r{r} acc");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{label} r{r} tloss");
        assert_eq!(x.consensus.to_bits(), y.consensus.to_bits(), "{label} r{r} consensus");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{label} r{r} sim");
        assert_eq!(x.compute_s.to_bits(), y.compute_s.to_bits(), "{label} r{r} compute");
        assert_eq!(x.upload_s.to_bits(), y.upload_s.to_bits(), "{label} r{r} upload");
        assert_eq!(x.backhaul_s.to_bits(), y.backhaul_s.to_bits(), "{label} r{r} backhaul");
        assert_eq!(x.dropped_devices, y.dropped_devices, "{label} r{r} dropped");
        assert_eq!(x.on_time_devices, y.on_time_devices, "{label} r{r} on-time");
        assert_eq!(x.late_devices, y.late_devices, "{label} r{r} late");
        assert_eq!(x.stale_merged, y.stale_merged, "{label} r{r} stale");
        assert_eq!(x.close_reason, y.close_reason, "{label} r{r} close");
        assert_eq!(x.steps, y.steps, "{label} r{r} steps");
    }
}

/// 15 of 16 devices leave at the round-1 boundary: clusters 1–3 empty
/// out entirely and cluster 0 keeps a single device. At round 2 the
/// cluster-1 roster rejoins and one cluster-3 device defects to
/// cluster 0 (a cross-cluster join); clusters 2–3 stay empty for the
/// rest of the run.
fn mass_leave_scenario(cfg: &ExperimentConfig) -> Scenario {
    let mut s = Scenario::from_flat(cfg);
    s.name = "mass-leave".into();
    let mut events = Vec::new();
    for roster in &s.rosters[1..] {
        for &device in roster {
            events.push(TimelineEvent { round: 1, event: WorldEvent::Leave { device } });
        }
    }
    for &device in &s.rosters[0][1..] {
        events.push(TimelineEvent { round: 1, event: WorldEvent::Leave { device } });
    }
    for &device in &s.rosters[1] {
        events.push(TimelineEvent { round: 2, event: WorldEvent::Join { device, cluster: 1 } });
    }
    let refugee = s.rosters[3][0];
    events.push(TimelineEvent {
        round: 2,
        event: WorldEvent::Join { device: refugee, cluster: 0 },
    });
    s.timeline = Timeline { events };
    s
}

fn scenic_cfg(alg: AlgorithmKind, latency: LatencyMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.algorithm = alg;
    cfg.latency = latency;
    cfg.rounds = 4;
    // Sampling over one-device and freshly-rejoined rosters is exactly
    // where a participation-clamp bug would hide.
    cfg.participation = 0.5;
    let scenario = mass_leave_scenario(&cfg);
    cfg.scenario = Some(scenario);
    cfg.validate().unwrap();
    cfg
}

#[test]
fn mass_simultaneous_leave_keeps_every_plan_deterministic() {
    let _guard = env_guard();
    for alg in AlgorithmKind::all() {
        for latency in [LatencyMode::ClosedForm, LatencyMode::EventDriven] {
            let cfg = scenic_cfg(alg, latency);
            let label = format!("{}-{}", alg.name(), latency.name());
            let h1 = run_under_threads(&cfg, "1");
            assert_eq!(h1.len(), cfg.rounds, "{label}: truncated history");
            let h4 = run_under_threads(&cfg, "4");
            assert_identical(&label, &h1, &h4);
        }
    }
}

#[test]
fn aggregator_cluster_death_at_the_round_boundary_is_deterministic() {
    let _guard = env_guard();
    let faults = [
        FaultSpec::KillAggregator { at_round: 1 },
        FaultSpec::KillCluster { at_round: 1, cluster: 0 },
    ];
    for alg in AlgorithmKind::all() {
        for fault in faults {
            for latency in [LatencyMode::ClosedForm, LatencyMode::EventDriven] {
                let mut cfg = ExperimentConfig::quickstart();
                cfg.algorithm = alg;
                cfg.latency = latency;
                cfg.rounds = 3;
                cfg.fault = Some(fault);
                cfg.validate().unwrap();
                let label = format!("{}-{}-{fault:?}", alg.name(), latency.name());
                let h1 = run_under_threads(&cfg, "1");
                assert_eq!(h1.len(), cfg.rounds, "{label}: truncated history");
                let h4 = run_under_threads(&cfg, "4");
                assert_identical(&label, &h1, &h4);
            }
        }
    }
}

#[test]
fn pathological_timeline_survives_the_distributed_driver_bit_for_bit() {
    let _guard = env_guard();
    std::env::set_var("CFEL_THREADS", "1");
    let cfg = scenic_cfg(AlgorithmKind::CeFedAvg, LatencyMode::EventDriven);
    let h_ref = run_reference(&cfg);

    let mut executors: Vec<Box<dyn ClusterExecutor>> = Vec::new();
    for part in partition_clusters(cfg.n_clusters, 2) {
        executors.push(Box::new(LocalExecutor::new(&cfg, part).unwrap()));
    }
    let mut runner = DistRunner::new(&cfg, executors).unwrap();
    let h_dist = runner.run().unwrap();
    std::env::remove_var("CFEL_THREADS");

    assert_identical("dist-mass-leave", &h_ref, &h_dist);
    assert_eq!(history_digest(&h_ref), history_digest(&h_dist), "digest diverged");
}
