//! Wire-codec property suite (satellite of the multi-process runtime).
//!
//! The protocol carries f64 training state, so the codec must be exact
//! on every value a run can produce — NaN payloads, negative zero,
//! subnormals, infinities — and must reject malformed bytes with a typed
//! [`CfelError::Codec`] instead of panicking or over-allocating. Message
//! equality is checked by re-encoding: the encoding is deterministic, so
//! `encode(decode(encode(m))) == encode(m)` pins every field bit for bit
//! without needing `PartialEq` on NaN-bearing structs.

use cfel::aggregation::policy::{CloseReason, ReportVerdict};
use cfel::coordinator::ClusterPhase;
use cfel::netsim::{DeviceTimings, PhaseTiming, UploadChannel};
use cfel::prop_assert;
use cfel::rpc::codec::{read_frame, write_frame, MAGIC, MAX_FRAME, PROTO_VERSION};
use cfel::rpc::wire::Msg;
use cfel::secagg::MaskedSum;
use cfel::util::proptest::{check, default_cases, int_biased};
use cfel::util::rng::Rng;
use cfel::CfelError;

/// Adversarial f64s: every special encoding plus ordinary magnitudes.
fn f64_adv(rng: &mut Rng) -> f64 {
    match rng.below(10) {
        0 => f64::NAN,
        1 => f64::from_bits(0x7FF8_DEAD_BEEF_0001), // NaN with payload
        2 => -0.0,
        3 => f64::from_bits(1), // smallest subnormal
        4 => f64::INFINITY,
        5 => f64::NEG_INFINITY,
        6 => 0.0,
        7 => f64::MAX,
        _ => rng.normal() as f64 * 1e3,
    }
}

fn f32_adv(rng: &mut Rng) -> f32 {
    match rng.below(8) {
        0 => f32::NAN,
        1 => -0.0,
        2 => f32::from_bits(1),
        3 => f32::INFINITY,
        4 => f32::NEG_INFINITY,
        _ => rng.normal(),
    }
}

fn vec_f64_adv(rng: &mut Rng, len: usize) -> Vec<f64> {
    (0..len).map(|_| f64_adv(rng)).collect()
}

fn gen_timing(rng: &mut Rng) -> PhaseTiming {
    let n = int_biased(rng, 0, 5);
    let verdicts = [ReportVerdict::OnTime, ReportVerdict::Late, ReportVerdict::Dropped];
    PhaseTiming {
        duration_s: f64_adv(rng),
        compute_s: f64_adv(rng),
        upload_s: f64_adv(rng),
        devices: DeviceTimings {
            device: (0..n).map(|_| rng.below(1 << 20)).collect(),
            compute_s: vec_f64_adv(rng, n),
            upload_s: vec_f64_adv(rng, n),
            finish_s: vec_f64_adv(rng, n),
            verdict: (0..n).map(|_| verdicts[rng.below(3)]).collect(),
        },
        events: rng.below(1 << 16),
        close_reason: CloseReason::ALL[rng.below(CloseReason::ALL.len())],
    }
}

fn gen_phase(rng: &mut Rng) -> ClusterPhase {
    let nr = int_biased(rng, 0, 6);
    ClusterPhase {
        cluster: rng.below(64),
        reports: (0..nr)
            .map(|_| (rng.below(1 << 16), rng.below(1 << 10), f64_adv(rng)))
            .collect(),
        model: (0..int_biased(rng, 0, 32)).map(|_| f32_adv(rng)).collect(),
        clock_s: f64_adv(rng),
        timing: if rng.below(2) == 0 {
            Some(gen_timing(rng))
        } else {
            None
        },
        stale_merged: rng.below(100),
        pending_after: rng.below(100),
        masked: None,
        secagg_mask_s: f64_adv(rng),
        secagg_extra_bits: f64_adv(rng),
    }
}

/// A phase as a masked edge ships it: empty plain model, the aggregate
/// carried as wrapped fixed-point words (any u64 is a legal word — masks
/// make the payload uniform noise).
fn gen_masked_phase(rng: &mut Rng) -> ClusterPhase {
    let mut p = gen_phase(rng);
    if rng.below(4) > 0 {
        p.model.clear();
        p.masked = Some(MaskedSum {
            words: (0..int_biased(rng, 0, 32)).map(|_| rng.next_u64()).collect(),
            total_weight: rng.next_u64(),
        });
    }
    p
}

fn gen_policies(rng: &mut Rng) -> Vec<(usize, String)> {
    let specs = ["full", "deadline:12.5", "kofn:3:45.25", "kofn:1:inf"];
    (0..int_biased(rng, 0, 4))
        .map(|_| (rng.below(32), specs[rng.below(specs.len())].to_string()))
        .collect()
}

fn gen_state(rng: &mut Rng) -> (Vec<(usize, Vec<f32>)>, Vec<(usize, f64)>) {
    let nm = int_biased(rng, 0, 4);
    let models = (0..nm)
        .map(|_| {
            let len = int_biased(rng, 0, 16);
            (rng.below(32), (0..len).map(|_| f32_adv(rng)).collect())
        })
        .collect();
    let nc = int_biased(rng, 0, 4);
    let clocks = (0..nc).map(|_| (rng.below(32), f64_adv(rng))).collect();
    (models, clocks)
}

fn gen_msg(rng: &mut Rng) -> Msg {
    match rng.below(13) {
        0 => Msg::Hello { proto: rng.next_u64() as u16 },
        1 => {
            let (models, clocks) = gen_state(rng);
            Msg::Init {
                config_json: "{\"n_devices\": 16, \"weird\": \"\u{1F30D} utf8\"}".into(),
                clusters: (0..int_biased(rng, 0, 5)).collect(),
                rounds_applied: rng.below(100),
                models,
                clocks,
                policies: gen_policies(rng),
            }
        }
        2 => Msg::InitOk,
        3 => Msg::BeginRound {
            round: rng.below(1 << 20),
            policies: gen_policies(rng),
        },
        4 => Msg::RoundBegun,
        5 => Msg::RunPhase {
            phase: rng.next_u64(),
            epochs: rng.below(16),
            channel: match rng.below(3) {
                0 => UploadChannel::DeviceEdge,
                1 => UploadChannel::DeviceCloud,
                _ => UploadChannel::DeviceEdgeMasked,
            },
        },
        6 => Msg::PhaseDone {
            phases: (0..int_biased(rng, 0, 3)).map(|_| gen_phase(rng)).collect(),
        },
        7 => {
            let (models, clocks) = gen_state(rng);
            Msg::SetState { models, clocks }
        }
        8 => Msg::StateSet,
        9 => Msg::Shutdown,
        10 => Msg::Bye,
        11 => Msg::MaskedPhaseDone {
            phases: (0..int_biased(rng, 0, 3)).map(|_| gen_masked_phase(rng)).collect(),
        },
        _ => Msg::Error { message: "edge exploded: \u{2620} non-ascii".into() },
    }
}

#[test]
fn messages_roundtrip_bit_exactly_through_frames() {
    check("wire-roundtrip", 0xC0DEC, default_cases(), |rng| {
        let msg = gen_msg(rng);
        let (kind, payload) = msg.encode();
        let mut framed = Vec::new();
        write_frame(&mut framed, kind, &payload)
            .map_err(|e| format!("{}: write failed: {e}", msg.name()))?;
        let (kind2, payload2) = read_frame(&mut &framed[..])
            .map_err(|e| format!("{}: read failed: {e}", msg.name()))?;
        prop_assert!(kind2 == kind, "{}: frame kind drifted", msg.name());
        prop_assert!(payload2 == payload, "{}: frame payload drifted", msg.name());
        let decoded = Msg::decode(kind2, &payload2)
            .map_err(|e| format!("{}: decode failed: {e}", msg.name()))?;
        prop_assert!(decoded.name() == msg.name(), "decoded as {}", decoded.name());
        let (kind3, payload3) = decoded.encode();
        prop_assert!(kind3 == kind, "{}: re-encoded kind drifted", msg.name());
        prop_assert!(
            payload3 == payload,
            "{}: re-encode differs — some field (a NaN bit? a subnormal?) did not survive",
            msg.name()
        );
        Ok(())
    });
}

#[test]
fn truncated_frames_are_typed_errors_never_panics() {
    check("wire-truncation", 0x7A7A, default_cases(), |rng| {
        let msg = gen_msg(rng);
        let (kind, payload) = msg.encode();
        let mut framed = Vec::new();
        write_frame(&mut framed, kind, &payload).map_err(|e| e.to_string())?;
        // Cut anywhere, including inside the header and at zero bytes.
        let cut = rng.below(framed.len());
        let err = match read_frame(&mut &framed[..cut]) {
            Ok(_) => return Err(format!("{}: truncation at {cut} decoded", msg.name())),
            Err(e) => e,
        };
        prop_assert!(
            matches!(err, CfelError::Codec(_)),
            "{}: cut at {cut} gave a non-codec error: {err}",
            msg.name()
        );
        Ok(())
    });
}

#[test]
fn truncated_payloads_fail_decode_without_panicking() {
    check("payload-truncation", 0xBADBED, default_cases(), |rng| {
        let msg = gen_msg(rng);
        let (kind, payload) = msg.encode();
        if payload.is_empty() {
            return Ok(());
        }
        let cut = rng.below(payload.len());
        prop_assert!(
            Msg::decode(kind, &payload[..cut]).is_err(),
            "{}: payload cut to {cut}/{} bytes still decoded",
            msg.name(),
            payload.len()
        );
        // Trailing garbage must be rejected too (layout disagreement).
        let mut padded = payload.clone();
        padded.push(0x5A);
        prop_assert!(
            Msg::decode(kind, &padded).is_err(),
            "{}: trailing byte accepted",
            msg.name()
        );
        Ok(())
    });
}

#[test]
fn oversized_and_corrupt_headers_are_rejected() {
    // A length field beyond MAX_FRAME must be refused before allocation.
    let mut head = Vec::new();
    head.extend_from_slice(&MAGIC);
    head.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    head.extend_from_slice(&1u16.to_le_bytes());
    head.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
    let err = read_frame(&mut &head[..]).unwrap_err();
    assert!(err.to_string().contains("exceeds cap"), "{err}");

    // Unknown frame kind: typed, not a panic.
    let err = Msg::decode(0xFFFF, &[]).unwrap_err();
    assert!(matches!(err, CfelError::Codec(_)), "{err}");
}

#[test]
fn exotic_floats_survive_a_full_message() {
    let specials = [
        f64::NAN,
        f64::from_bits(0x7FF8_DEAD_BEEF_0001),
        -0.0,
        f64::from_bits(1),
        f64::INFINITY,
        f64::NEG_INFINITY,
    ];
    let phases = vec![ClusterPhase {
        cluster: 3,
        reports: specials.iter().enumerate().map(|(d, &l)| (d, d + 1, l)).collect(),
        model: vec![f32::NAN, -0.0, f32::from_bits(1)],
        clock_s: -0.0,
        timing: None,
        stale_merged: 0,
        pending_after: 0,
        masked: None,
        secagg_mask_s: -0.0,
        secagg_extra_bits: f64::from_bits(1),
    }];
    let msg = Msg::PhaseDone { phases };
    let (kind, payload) = msg.encode();
    let decoded = Msg::decode(kind, &payload).unwrap();
    let Msg::PhaseDone { phases } = decoded else {
        panic!("decoded as {}", msg.name());
    };
    assert_eq!(phases.len(), 1);
    for ((_, _, got), want) in phases[0].reports.iter().zip(&specials) {
        assert_eq!(got.to_bits(), want.to_bits(), "loss bits drifted");
    }
    assert_eq!(phases[0].clock_s.to_bits(), (-0.0f64).to_bits());
    assert_eq!(phases[0].model[0].to_bits(), f32::NAN.to_bits());
    assert_eq!(phases[0].model[1].to_bits(), (-0.0f32).to_bits());
    assert_eq!(phases[0].model[2].to_bits(), 1);
    assert_eq!(phases[0].secagg_mask_s.to_bits(), (-0.0f64).to_bits());
    assert_eq!(phases[0].secagg_extra_bits.to_bits(), 1);
}

#[test]
fn masked_phase_payloads_roundtrip_word_exactly() {
    let words = vec![0u64, u64::MAX, 0x8000_0000_0000_0000, 1, 0xDEAD_BEEF_CAFE_F00D];
    let phases = vec![ClusterPhase {
        cluster: 7,
        reports: vec![(0, 3, 0.5), (2, 3, 0.25)],
        model: Vec::new(),
        clock_s: 1.5,
        timing: None,
        stale_merged: 0,
        pending_after: 0,
        masked: Some(MaskedSum { words: words.clone(), total_weight: 96 }),
        secagg_mask_s: 0.125,
        secagg_extra_bits: 2048.0,
    }];
    let msg = Msg::MaskedPhaseDone { phases };
    let (kind, payload) = msg.encode();
    let decoded = Msg::decode(kind, &payload).unwrap();
    let Msg::MaskedPhaseDone { phases } = decoded else {
        panic!("decoded as {}", decoded.name());
    };
    let sum = phases[0].masked.as_ref().expect("masked sum survived");
    assert_eq!(sum.words, words);
    assert_eq!(sum.total_weight, 96);
    assert!(phases[0].model.is_empty());

    // Truncating inside the masked suffix must fail typed, not panic.
    for cut in payload.len() - 20..payload.len() {
        assert!(
            Msg::decode(kind, &payload[..cut]).is_err(),
            "masked payload cut to {cut}/{} bytes still decoded",
            payload.len()
        );
    }
}

#[test]
fn version_mismatch_is_rejected_with_both_versions_named() {
    // A frame stamped with a different protocol version — e.g. a
    // pre-secagg peer — must be refused at the header, naming both sides.
    let mut framed = Vec::new();
    write_frame(&mut framed, 1, b"x").unwrap();
    let old = PROTO_VERSION - 1;
    framed[4..6].copy_from_slice(&old.to_le_bytes());
    let err = read_frame(&mut &framed[..]).unwrap_err();
    let text = err.to_string();
    assert!(matches!(err, CfelError::Codec(_)), "{text}");
    assert!(
        text.contains(&format!("version {old}")) && text.contains(&PROTO_VERSION.to_string()),
        "both versions should be named: {text}"
    );
}
