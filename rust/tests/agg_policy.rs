//! Oracle-equivalence suite for the edge-round close policies.
//!
//! The degenerate semi-sync policy — K = every participant, no timeout,
//! zero staleness exponent — must be *indistinguishable* from the full
//! barrier: same models, same virtual latencies, same CSV rows, for all
//! four algorithms, bit for bit. Likewise the deadline-drop policy
//! expressed through the new trait must reproduce the legacy `deadline_s`
//! path exactly. These pins are what let the semi-sync machinery ship
//! inside the default code path without perturbing the paper's numbers.

use cfel::config::{AggPolicyKind, AlgorithmKind, ExperimentConfig, LatencyMode};
use cfel::coordinator::Coordinator;
use cfel::metrics::{CsvWriter, History, ROUND_HEADER};
use cfel::netsim::StragglerSpec;

fn run(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

fn base(alg: AlgorithmKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.algorithm = alg;
    cfg.rounds = 4;
    cfg.latency = LatencyMode::EventDriven;
    cfg
}

fn csv_rows(series: &str, h: &History) -> String {
    let path = std::env::temp_dir().join(format!(
        "cfel_agg_policy_{}_{series}.csv",
        std::process::id()
    ));
    {
        let mut w = CsvWriter::create(&path, ROUND_HEADER).unwrap();
        for rec in h {
            w.round_row(series, rec).unwrap();
        }
    }
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    text
}

fn assert_identical(alg: AlgorithmKind, a: &History, b: &History) {
    assert_eq!(a.len(), b.len(), "{alg:?}: history lengths differ");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{alg:?} r{r} loss");
        assert_eq!(x.test_accuracy.to_bits(), y.test_accuracy.to_bits(), "{alg:?} r{r} acc");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{alg:?} r{r}");
        assert_eq!(x.consensus.to_bits(), y.consensus.to_bits(), "{alg:?} r{r}");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{alg:?} r{r} sim");
        assert_eq!(x.compute_s.to_bits(), y.compute_s.to_bits(), "{alg:?} r{r}");
        assert_eq!(x.upload_s.to_bits(), y.upload_s.to_bits(), "{alg:?} r{r}");
        assert_eq!(x.backhaul_s.to_bits(), y.backhaul_s.to_bits(), "{alg:?} r{r}");
        assert_eq!(x.dropped_devices, y.dropped_devices, "{alg:?} r{r}");
        assert_eq!(x.on_time_devices, y.on_time_devices, "{alg:?} r{r}");
        assert_eq!(x.late_devices, y.late_devices, "{alg:?} r{r}");
        assert_eq!(x.stale_merged, y.stale_merged, "{alg:?} r{r}");
        assert_eq!(x.close_reason, y.close_reason, "{alg:?} r{r}");
        assert_eq!(x.steps, y.steps, "{alg:?} r{r}");
    }
}

#[test]
fn semi_sync_degenerate_case_is_the_full_barrier_for_all_algorithms() {
    for alg in AlgorithmKind::all() {
        // Heterogeneous speeds so report order is nontrivial.
        let mut barrier = base(alg);
        barrier.heterogeneity = Some(0.5);
        let mut degenerate = barrier.clone();
        degenerate.agg_policy = AggPolicyKind::SemiSync {
            k: degenerate.devices_per_cluster(),
            timeout_s: f64::INFINITY,
        };
        degenerate.staleness_exp = 0.0;
        let hb = run(&barrier);
        let hd = run(&degenerate);
        assert_identical(alg, &hb, &hd);
        // Degenerate semi-sync never defers or drops anything...
        for rec in &hd {
            assert_eq!(rec.dropped_devices + rec.late_devices + rec.stale_merged, 0);
            assert_eq!(rec.close_reason, "all-reported");
        }
        // ...and the emitted CSV rows are byte-identical too.
        assert_eq!(
            csv_rows("oracle", &hb),
            csv_rows("oracle", &hd),
            "{alg:?}: CSV rows diverged"
        );
    }
}

#[test]
fn semi_sync_degenerate_case_survives_stragglers() {
    // Same pin under a heavy-tail fleet: k = N still waits for everyone,
    // so even 10⁶× stragglers cannot distinguish it from the barrier.
    for alg in [AlgorithmKind::CeFedAvg, AlgorithmKind::FedAvg] {
        let mut barrier = base(alg);
        barrier.rounds = 3;
        barrier.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e6 });
        let mut degenerate = barrier.clone();
        degenerate.agg_policy = AggPolicyKind::SemiSync {
            k: degenerate.devices_per_cluster(),
            timeout_s: f64::INFINITY,
        };
        degenerate.staleness_exp = 0.0;
        assert_identical(alg, &run(&barrier), &run(&degenerate));
    }
}

#[test]
fn deadline_policy_via_trait_matches_the_legacy_deadline_path() {
    // The PR 2 `--deadline` behavior, now routed through the policy
    // trait: `deadline_s = Some(T)` (the sugar) and an explicit
    // `DeadlineDrop { T }` policy must be bit-identical runs — models,
    // latencies, drop counts, CSV rows — for all four algorithms.
    for alg in AlgorithmKind::all() {
        let mut sugar = base(alg);
        sugar.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e6 });
        sugar.deadline_s = Some(0.1);
        let mut explicit = sugar.clone();
        explicit.deadline_s = None;
        explicit.agg_policy = AggPolicyKind::DeadlineDrop { deadline_s: 0.1 };
        let hs = run(&sugar);
        let he = run(&explicit);
        assert!(
            hs.iter().map(|r| r.dropped_devices).sum::<usize>() > 0,
            "{alg:?}: the deadline scenario should actually drop devices"
        );
        assert_identical(alg, &hs, &he);
        assert_eq!(csv_rows("deadline", &hs), csv_rows("deadline", &he));
    }
}

#[test]
fn timeout_before_any_report_keeps_the_model_then_catches_up() {
    // Empty-on-time-set regression: a semi-sync timeout shorter than any
    // possible report closes every phase with zero on-time reports. The
    // cluster must keep its previous model (the same empty-participant
    // contract the deadline path established — no panic, no corruption),
    // and because semi-sync *keeps* the late reports, they drain into
    // later rounds once the virtual clock passes their arrival times.
    let mut cfg = base(AlgorithmKind::CeFedAvg);
    cfg.rounds = 5;
    cfg.agg_policy = AggPolicyKind::SemiSync { k: 1, timeout_s: 1e-9 };
    cfg.staleness_exp = 1.0;
    let h = run(&cfg);
    let first = &h[0];
    assert_eq!(first.on_time_devices, 0, "nothing can report within 1 ns");
    assert_eq!(first.stale_merged, 0, "nothing stale exists yet in round 1");
    assert_eq!(first.close_reason, "timeout");
    assert_eq!(first.dropped_devices, 0, "semi-sync never drops");
    // Round 1 aggregated nothing: every cluster still holds the shared
    // init model, so the consensus distance is exactly zero.
    assert!(first.consensus < 1e-30, "consensus {}", first.consensus);
    // The late reports fold in once the backhaul hops advance the clock
    // past their ~8 ms arrivals — the run catches up instead of freezing.
    let stale: usize = h.iter().map(|r| r.stale_merged).sum();
    assert!(stale > 0, "late reports never merged");
    let late: usize = h.iter().map(|r| r.late_devices).sum();
    assert_eq!(late, cfg.n_devices * cfg.q * cfg.rounds, "every report deferred");
}

#[test]
fn semi_sync_differs_from_barrier_when_k_is_partial() {
    // Sanity inverse of the oracle pin: with K < N under stragglers the
    // two runs must *not* coincide (otherwise the suite proves nothing).
    let mut barrier = base(AlgorithmKind::CeFedAvg);
    barrier.stragglers = Some(StragglerSpec { fraction: 0.25, slowdown: 1e4 });
    let mut partial = barrier.clone();
    partial.agg_policy = AggPolicyKind::SemiSync { k: 3, timeout_s: 0.02 };
    let hb = run(&barrier);
    let hp = run(&partial);
    assert!(
        hp.last().unwrap().sim_time_s < hb.last().unwrap().sim_time_s,
        "partial K should close rounds earlier"
    );
    assert!(hp.iter().map(|r| r.late_devices).sum::<usize>() > 0);
}
