//! Cross-process equivalence suite — the pin for the multi-process
//! runtime.
//!
//! The distributed interpreter (cloud + N edges) must produce the same
//! round history as the in-process interpreter, *bit for bit*, on all
//! four canned plans under both latency modes:
//!
//! * in-process: [`DistRunner`] over [`LocalExecutor`]s — the driver and
//!   the executor seam without sockets — under `CFEL_THREADS` 1 and 4;
//! * across real OS processes: one `cfel-cloud` + two `cfel-edge`
//!   binaries on localhost TCP (and once over a Unix socket), comparing
//!   the wall-clock-free history digest and the CSV rows.
//!
//! Wall-clock time is the one nondeterministic column; every comparison
//! excludes it (`history_digest` skips it, CSVs have it zeroed).

use std::io::{BufRead, BufReader, Read};
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;

use cfel::config::{AlgorithmKind, ExperimentConfig, LatencyMode};
use cfel::coordinator::executor::partition_clusters;
use cfel::coordinator::{ClusterExecutor, Coordinator, DistRunner, LocalExecutor};
use cfel::metrics::{history_digest, CsvWriter, History, ROUND_HEADER};

/// `CFEL_THREADS` is process-global and the CSV helper reuses temp
/// paths, so every test serializes on this lock.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn base_cfg(alg: AlgorithmKind, latency: LatencyMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart();
    cfg.algorithm = alg;
    cfg.latency = latency;
    cfg.rounds = 3;
    cfg
}

fn run_reference(cfg: &ExperimentConfig) -> History {
    let mut coord = Coordinator::from_config(cfg).unwrap();
    coord.run().unwrap()
}

fn run_local_dist(cfg: &ExperimentConfig, n_executors: usize) -> History {
    let mut executors: Vec<Box<dyn ClusterExecutor>> = Vec::new();
    for part in partition_clusters(cfg.n_clusters, n_executors) {
        executors.push(Box::new(LocalExecutor::new(cfg, part).unwrap()));
    }
    let mut runner = DistRunner::new(cfg, executors).unwrap();
    runner.run().unwrap()
}

/// Render a history to CSV text with the wall-clock column zeroed.
fn csv_rows(series: &str, h: &History) -> String {
    let path =
        std::env::temp_dir().join(format!("cfel_dist_equiv_{}_{series}.csv", std::process::id()));
    {
        let mut w = CsvWriter::create(&path, ROUND_HEADER).unwrap();
        for rec in h {
            let mut r = rec.clone();
            r.wall_time_s = 0.0;
            w.round_row(series, &r).unwrap();
        }
    }
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    text
}

/// Zero the wall_time_s column (index 3) of a CSV produced by a child
/// process, so it compares against [`csv_rows`] output.
fn zero_wall_column(csv: &str) -> String {
    csv.lines()
        .enumerate()
        .map(|(i, line)| {
            if i == 0 {
                return line.to_string();
            }
            let mut fields: Vec<&str> = line.split(',').collect();
            if fields.len() > 3 {
                fields[3] = "0.000";
            }
            fields.join(",")
        })
        .collect::<Vec<_>>()
        .join("\n")
        + "\n"
}

fn assert_identical(label: &str, a: &History, b: &History) {
    assert_eq!(a.len(), b.len(), "{label}: history lengths differ");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label} r{r} loss");
        assert_eq!(x.test_accuracy.to_bits(), y.test_accuracy.to_bits(), "{label} r{r} acc");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{label} r{r} tloss");
        assert_eq!(x.consensus.to_bits(), y.consensus.to_bits(), "{label} r{r} consensus");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{label} r{r} sim");
        assert_eq!(x.compute_s.to_bits(), y.compute_s.to_bits(), "{label} r{r} compute");
        assert_eq!(x.upload_s.to_bits(), y.upload_s.to_bits(), "{label} r{r} upload");
        assert_eq!(x.backhaul_s.to_bits(), y.backhaul_s.to_bits(), "{label} r{r} backhaul");
        assert_eq!(x.dropped_devices, y.dropped_devices, "{label} r{r} dropped");
        assert_eq!(x.on_time_devices, y.on_time_devices, "{label} r{r} on-time");
        assert_eq!(x.late_devices, y.late_devices, "{label} r{r} late");
        assert_eq!(x.stale_merged, y.stale_merged, "{label} r{r} stale");
        assert_eq!(x.close_reason, y.close_reason, "{label} r{r} close");
        assert_eq!(x.steps, y.steps, "{label} r{r} steps");
    }
}

#[test]
fn local_executor_driver_matches_the_interpreter_bit_for_bit() {
    let _guard = env_guard();
    for threads in ["1", "4"] {
        std::env::set_var("CFEL_THREADS", threads);
        for alg in AlgorithmKind::all() {
            for latency in [LatencyMode::ClosedForm, LatencyMode::EventDriven] {
                let cfg = base_cfg(alg, latency);
                let label = format!("{}-{}-t{threads}", alg.name(), latency.name());
                let h_ref = run_reference(&cfg);
                // 2 executors is the canonical split; 1 and 4 (one per
                // cluster) exercise the partition boundaries.
                for n_ex in [1usize, 2, 4] {
                    let h_dist = run_local_dist(&cfg, n_ex);
                    let l = format!("{label}-x{n_ex}");
                    assert_identical(&l, &h_ref, &h_dist);
                    assert_eq!(
                        history_digest(&h_ref),
                        history_digest(&h_dist),
                        "{l}: digest diverged"
                    );
                }
                let h_dist = run_local_dist(&cfg, 2);
                assert_eq!(
                    csv_rows("oracle", &h_ref),
                    csv_rows("oracle", &h_dist),
                    "{label}: CSV rows diverged"
                );
            }
        }
        std::env::remove_var("CFEL_THREADS");
    }
}

/// Spawn `cfel-cloud` (+2 `cfel-edge`s) on `listen`, run `cfg`, and
/// return (digest hex, CSV text) from the child processes.
fn run_socket_dist(cfg: &ExperimentConfig, listen: &str, cloud_threads: &str) -> (String, String) {
    let tag = format!("{}_{}", std::process::id(), cfg.run_label().replace('@', "_"));
    let cfg_path = std::env::temp_dir().join(format!("cfel_dist_cfg_{tag}.json"));
    let csv_path = std::env::temp_dir().join(format!("cfel_dist_csv_{tag}.csv"));
    std::fs::write(&cfg_path, cfg.to_json().to_string()).unwrap();

    let mut cloud = Command::new(env!("CARGO_BIN_EXE_cfel-cloud"))
        .arg("--config")
        .arg(&cfg_path)
        .arg("--listen")
        .arg(listen)
        .arg("--edges")
        .arg("2")
        .arg("--csv")
        .arg(&csv_path)
        .arg("--digest")
        .arg("--quiet")
        .env("CFEL_THREADS", cloud_threads)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn cfel-cloud");
    let mut reader = BufReader::new(cloud.stdout.take().unwrap());

    // The cloud announces its resolved address first — parse it so
    // ephemeral ports (127.0.0.1:0) work.
    let mut addr = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("read cloud stdout");
        assert!(n > 0, "cfel-cloud exited before announcing its address");
        if let Some(rest) = line.trim().strip_prefix("[cfel-cloud] listening on ") {
            addr = rest.to_string();
            break;
        }
    }

    // Edges run at a fixed, different thread count: the history must not
    // depend on any process's parallelism.
    let edges: Vec<Child> = (0..2)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_cfel-edge"))
                .arg("--connect")
                .arg(&addr)
                .arg("--quiet")
                .env("CFEL_THREADS", "2")
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawn cfel-edge")
        })
        .collect();

    let mut rest = String::new();
    reader.read_to_string(&mut rest).expect("drain cloud stdout");
    let status = cloud.wait().expect("wait cfel-cloud");
    assert!(status.success(), "cfel-cloud failed; stdout:\n{rest}");
    for mut e in edges {
        let st = e.wait().expect("wait cfel-edge");
        assert!(st.success(), "cfel-edge failed");
    }

    let digest = rest
        .lines()
        .find_map(|l| l.trim().strip_prefix("history_digest: "))
        .unwrap_or_else(|| panic!("no digest in cloud output:\n{rest}"))
        .to_string();
    let csv = std::fs::read_to_string(&csv_path).expect("child CSV");
    std::fs::remove_file(&cfg_path).ok();
    std::fs::remove_file(&csv_path).ok();
    (digest, csv)
}

#[test]
fn cloud_and_edge_processes_reproduce_the_run_over_tcp() {
    let _guard = env_guard();
    for alg in AlgorithmKind::all() {
        for latency in [LatencyMode::ClosedForm, LatencyMode::EventDriven] {
            let cfg = base_cfg(alg, latency);
            std::env::set_var("CFEL_THREADS", "1");
            let h_ref = run_reference(&cfg);
            std::env::remove_var("CFEL_THREADS");
            let want_digest = format!("{:016x}", history_digest(&h_ref));
            let want_csv = csv_rows(&cfg.run_label(), &h_ref);
            for cloud_threads in ["1", "4"] {
                let label = format!("{}-{}-ct{cloud_threads}", alg.name(), latency.name());
                let (digest, csv) = run_socket_dist(&cfg, "127.0.0.1:0", cloud_threads);
                assert_eq!(digest, want_digest, "{label}: history digest diverged");
                assert_eq!(zero_wall_column(&csv), want_csv, "{label}: CSV rows diverged");
            }
        }
    }
}

#[cfg(unix)]
#[test]
fn unix_domain_sockets_carry_the_same_bits() {
    let _guard = env_guard();
    let cfg = base_cfg(AlgorithmKind::CeFedAvg, LatencyMode::EventDriven);
    std::env::set_var("CFEL_THREADS", "1");
    let h_ref = run_reference(&cfg);
    std::env::remove_var("CFEL_THREADS");
    let sock = std::env::temp_dir().join(format!("cfel_dist_{}.sock", std::process::id()));
    let listen = format!("unix:{}", sock.display());
    let (digest, csv) = run_socket_dist(&cfg, &listen, "4");
    assert_eq!(digest, format!("{:016x}", history_digest(&h_ref)), "unix-socket digest");
    assert_eq!(zero_wall_column(&csv), csv_rows(&cfg.run_label(), &h_ref), "unix-socket CSV");
}
